"""Forwarder for ``python -m launch.serve`` (see ``repro.launch.serve``)."""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
