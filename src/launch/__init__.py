"""Alias package: ``python -m launch.train`` → ``repro.launch.train``.

The canonical drivers live under ``repro.launch``; this forwarding package
keeps the shorter ``-m launch.<driver>`` spelling working when ``src`` is
on PYTHONPATH.
"""
