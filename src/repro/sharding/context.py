"""Global sharding context.

Model code stays mesh-agnostic; step builders (train/serve/dryrun) install a
``ShardingContext`` so the few places that need explicit distribution —
the MoE expert-parallel dispatch, activation sharding constraints — can
query the active mesh and policies.  With no context installed, everything
degrades to single-device semantics (CPU tests).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardingContext:
    mesh: Mesh
    shard_heads: bool = True        # kv_heads % tp == 0
    seq_shard_cache: bool = False   # long-context decode: KV seq over 'data'
    batch_axes: Tuple[str, ...] = ("data",)
    num_heads: int = 0              # arch Q heads (attention TP policy)
    num_kv_heads: int = 0

    @property
    def tp(self) -> int:
        return self.mesh.shape["model"]

    def dp_degree(self) -> int:
        d = 1
        for a in self.batch_axes:
            d *= self.mesh.shape[a]
        return d


_CTX: Optional[ShardingContext] = None


def get_context() -> Optional[ShardingContext]:
    return _CTX


@contextlib.contextmanager
def use_mesh(ctx: ShardingContext):
    global _CTX
    prev = _CTX
    _CTX = ctx
    try:
        with ctx.mesh:
            yield ctx
    finally:
        _CTX = prev


@contextlib.contextmanager
def suspend():
    """Temporarily clear the active context (trace-time).

    Inside a ``shard_map`` region every mesh axis is *manual*, so the
    context's ``with_sharding_constraint`` calls (e.g. the attention head
    TP constraint) are illegal there — wrap the shard_map trace in
    ``suspend()`` and the constraints degrade to identity."""
    global _CTX
    prev = _CTX
    _CTX = None
    try:
        yield
    finally:
        _CTX = prev


def make_context(mesh: Mesh, *, num_kv_heads: int = 16, num_heads: int = 0,
                 seq_shard_cache: bool = False) -> ShardingContext:
    tp = mesh.shape["model"]
    return ShardingContext(
        mesh=mesh,
        shard_heads=(num_kv_heads % tp == 0),
        seq_shard_cache=seq_shard_cache,
        batch_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names),
        num_heads=num_heads or num_kv_heads,
        num_kv_heads=num_kv_heads,
    )


def constrain(x, *parts):
    """with_sharding_constraint if a context is active, else identity."""
    ctx = _CTX
    if ctx is None:
        return x
    resolved = []
    for p in parts:
        if p == "BATCH":
            resolved.append(ctx.batch_axes)
        else:
            resolved.append(p)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*resolved)))
