"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

The production dry-run mesh uses DP×TP (+pod) as specified in the brief;
pipelining is provided as an optional composable axis for deployments where
layer counts outgrow TP (e.g. 1000+-node fleets): stages are stacked layer
groups sharded over 'pipe', microbatches stream through a
``collective_permute`` ring with the classic (num_microbatches + num_stages
- 1)-tick schedule.  Bubble fraction = (S-1)/(M+S-1).

``pipeline_apply`` is jit-able, differentiable (the permutes are linear),
and mesh-agnostic; tests/test_pipeline.py checks exact equivalence with the
sequential composition on an 8-device host mesh.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable,          # (stage_params, x_mb) -> y_mb
    stage_params,                # pytree stacked on axis 0 = num_stages
    x: jax.Array,                # (num_microbatches, mb, ...)
    mesh: Mesh,
    *,
    axis: str = "pipe",
) -> jax.Array:
    """Returns stage_{S-1}(...stage_0(x)) with shapes preserved."""
    num_stages = mesh.shape[axis]
    num_mb = x.shape[0]
    ticks = num_mb + num_stages - 1

    def local_fn(params_local, x_all):
        # params_local: this rank's stage (leading axis 1) — squeeze it.
        params_local = jax.tree.map(lambda a: a[0], params_local)
        rank = jax.lax.axis_index(axis)
        # jax.lax.axis_size only exists on newer jax; psum(1) is equivalent.
        n = (jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size")
             else jax.lax.psum(1, axis))
        mb_shape = x_all.shape[1:]

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (zeros once drained)
            mb_idx = jnp.clip(t, 0, num_mb - 1)
            fresh = jax.lax.dynamic_index_in_dim(x_all, mb_idx, 0,
                                                 keepdims=False)
            inp = jnp.where(rank == 0, fresh, state)
            out = stage_fn(params_local, inp)
            # last stage banks its result for microbatch t - (n - 1)
            out_idx = jnp.clip(t - (n - 1), 0, num_mb - 1)
            take = (rank == n - 1) & (t >= n - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(take, out,
                          jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                                       keepdims=False)),
                out_idx, 0)
            # ring-shift activations to the next stage
            state = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n) for i in range(n)])
            return (state, outputs), None

        state0 = jnp.zeros(mb_shape, x_all.dtype)
        outputs0 = jnp.zeros((num_mb,) + mb_shape, x_all.dtype)
        (_, outputs), _ = jax.lax.scan(tick, (state0, outputs0),
                                       jnp.arange(ticks))
        # broadcast the last rank's outputs to everyone (replicated result);
        # ppermute is a strict permutation, so mask + psum instead
        outputs = jax.lax.psum(
            jnp.where(rank == n - 1, outputs, jnp.zeros_like(outputs)), axis)
        return outputs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_rep=False,
    )(stage_params, x)
