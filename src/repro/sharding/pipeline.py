"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

The production dry-run mesh uses DP×TP (+pod) as specified in the brief;
pipelining is provided as an optional composable axis for deployments where
layer counts outgrow TP (e.g. 1000+-node fleets): stages are stacked layer
groups sharded over 'pipe', microbatches stream through a
``collective_permute`` ring with the classic (num_microbatches + num_stages
- 1)-tick schedule.  Bubble fraction = (S-1)/(M+S-1).

``pipeline_apply`` is jit-able, differentiable (the permutes are linear),
and mesh-agnostic; tests/test_pipeline.py checks exact equivalence with the
sequential composition on an 8-device host mesh.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable,          # (stage_params, x_mb) -> y_mb
    stage_params,                # pytree stacked on axis 0 = num_stages
    x: jax.Array,                # (num_microbatches, mb, ...)
    mesh: Mesh,
    *,
    axis: str = "pipe",
) -> jax.Array:
    """Returns stage_{S-1}(...stage_0(x)) with shapes preserved."""
    num_stages = mesh.shape[axis]
    num_mb = x.shape[0]
    ticks = num_mb + num_stages - 1

    def local_fn(params_local, x_all):
        # params_local: this rank's stage (leading axis 1) — squeeze it.
        params_local = jax.tree.map(lambda a: a[0], params_local)
        rank = jax.lax.axis_index(axis)
        # jax.lax.axis_size only exists on newer jax; psum(1) is equivalent.
        n = (jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size")
             else jax.lax.psum(1, axis))
        mb_shape = x_all.shape[1:]

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (zeros once drained)
            mb_idx = jnp.clip(t, 0, num_mb - 1)
            fresh = jax.lax.dynamic_index_in_dim(x_all, mb_idx, 0,
                                                 keepdims=False)
            inp = jnp.where(rank == 0, fresh, state)
            out = stage_fn(params_local, inp)
            # last stage banks its result for microbatch t - (n - 1)
            out_idx = jnp.clip(t - (n - 1), 0, num_mb - 1)
            take = (rank == n - 1) & (t >= n - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(take, out,
                          jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                                       keepdims=False)),
                out_idx, 0)
            # ring-shift activations to the next stage
            state = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n) for i in range(n)])
            return (state, outputs), None

        state0 = jnp.zeros(mb_shape, x_all.dtype)
        outputs0 = jnp.zeros((num_mb,) + mb_shape, x_all.dtype)
        (_, outputs), _ = jax.lax.scan(tick, (state0, outputs0),
                                       jnp.arange(ticks))
        # broadcast the last rank's outputs to everyone (replicated result);
        # ppermute is a strict permutation, so mask + psum instead
        outputs = jax.lax.psum(
            jnp.where(rank == n - 1, outputs, jnp.zeros_like(outputs)), axis)
        return outputs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_rep=False,
    )(stage_params, x)


def pipeline_apply_stateful(
    stage_fn: Callable,          # (params, state, x_mb, aux_mb, mb_idx)
                                 #   -> (y_mb, new_state)
    stage_params,                # pytree stacked on axis 0 = num_stages
    stage_state,                 # pytree stacked on axis 0 = num_stages
    x: jax.Array,                # (num_microbatches, mb, ...)
    mesh: Mesh,
    *,
    axis: str = "pipe",
    aux=None,                    # pytree, leaves (num_microbatches, ...)
):
    """:func:`pipeline_apply` for stage functions that carry *state* — the
    microbatched decode step, where each stage owns the KV caches of its
    layer group and must thread their updates out of the pipeline.

    Each stage applies each microbatch exactly once in the classic schedule
    (stage ``s`` sees microbatch ``m`` at tick ``m + s``); on warm-up/drain
    ticks where a stage holds no live microbatch the ``stage_fn`` still runs
    (SPMD — every rank executes every tick) but its state update is
    discarded with a validity mask, so bubble ticks cannot corrupt caches.

    ``aux`` carries per-microbatch side inputs every stage needs at its own
    schedule offset (e.g. decode positions): leaves are indexed with the
    stage's current microbatch id and handed to ``stage_fn`` as ``aux_mb``.

    Returns ``(y, new_stage_state)`` with ``y.shape == x.shape`` and
    ``new_stage_state`` matching ``stage_state``.
    """
    num_stages = mesh.shape[axis]
    num_mb = x.shape[0]
    ticks = num_mb + num_stages - 1
    aux = {} if aux is None else aux

    def local_fn(params_local, state_local, x_all, aux_all):
        params_local = jax.tree.map(lambda a: a[0], params_local)
        st0 = jax.tree.map(lambda a: a[0], state_local)
        rank = jax.lax.axis_index(axis)
        n = (jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size")
             else jax.lax.psum(1, axis))
        mb_shape = x_all.shape[1:]

        def tick(carry, t):
            ring, st, outputs = carry
            # stage 0 ingests microbatch t; later stages take the ring
            fresh = jax.lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, num_mb - 1), 0, keepdims=False)
            inp = jnp.where(rank == 0, fresh, ring)
            # this stage's live microbatch at tick t (clamped on bubbles)
            my_mb = jnp.clip(t - rank, 0, num_mb - 1)
            valid = (t >= rank) & (t - rank < num_mb)
            aux_mb = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, my_mb, 0,
                                                       keepdims=False),
                aux_all)
            out, st_new = stage_fn(params_local, st, inp, aux_mb, my_mb)
            st = jax.tree.map(lambda new, old: jnp.where(valid, new, old),
                              st_new, st)
            # last stage banks its result for microbatch t - (n - 1)
            out_idx = jnp.clip(t - (n - 1), 0, num_mb - 1)
            take = (rank == n - 1) & (t >= n - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(take, out,
                          jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                                       keepdims=False)),
                out_idx, 0)
            ring = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n) for i in range(n)])
            return (ring, st, outputs), None

        ring0 = jnp.zeros(mb_shape, x_all.dtype)
        outputs0 = jnp.zeros((num_mb,) + mb_shape, x_all.dtype)
        (_, st, outputs), _ = jax.lax.scan(
            tick, (ring0, st0, outputs0), jnp.arange(ticks))
        outputs = jax.lax.psum(
            jnp.where(rank == n - 1, outputs, jnp.zeros_like(outputs)), axis)
        # restore the leading (local) stage axis for the P(axis) out_spec
        return outputs, jax.tree.map(lambda a: a[None], st)

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    sspec = jax.tree.map(lambda _: P(axis), stage_state)
    aspec = jax.tree.map(lambda _: P(), aux)
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspec, sspec, P(), aspec), out_specs=(P(), sspec),
        check_rep=False,
    )(stage_params, stage_state, x, aux)
