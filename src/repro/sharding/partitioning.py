"""Partitioning rules: param-path patterns → PartitionSpec.

Megatron-style TP over the 'model' axis, DP over ('pod', 'data') for the
batch, EP for expert tensors, and a head-dim fallback for archs whose KV
head count does not divide the TP degree (DESIGN.md §5).

Rules are matched on the '/'-joined param path (first match wins), so the
same rule set serves every architecture family.  ``_sparse_*`` static
metadata and scalar leaves get a fully-replicated spec.

ZeRO-1: optimizer-state specs are derived from the param specs by sharding
the largest replicated dimension over 'data' (opt_state_specs).
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sparsity import LAYOUT_BLOCK, PackedWeight
from repro.core.treeutil import key_path_str as _path_str


# (regex on path, spec builder(ndim) -> PartitionSpec)
# 'M' = model axis, 'D' = data axes tuple ('pod','data') or ('data',)
#
# Rules address the *linear's* dense weight path (".../w").  Packed sparse
# weights are not matched by leaf-name regexes: PackedWeight nodes are
# handled structurally (isinstance) in ``param_specs``, which classifies the
# node's module path as col/row-parallel via the same rules and shards the
# values/indices children by their known (O, G, Ne) geometry.

def _rules():
    return [
        # embeddings / unembedding: vocab-sharded
        (r"(embed|unembed)/table", lambda nd: P("model", None)),
        # MoE expert tensors (E, in, out): EP over model
        (r"moe/w_(gate|up|down)", lambda nd: P("model", None, None)),
        (r"moe/router/w", lambda nd: P(None, None)),
        # attention projections: column-parallel q/k/v, row-parallel o
        (r"(attn|xattn)/w[qkv]/w", "col"),
        (r"(attn|xattn)/wo/w", "row"),
        # MLP: column-parallel gate/up, row-parallel down
        (r"mlp/(gate|up)/w", "col"),
        (r"mlp/down/w", "row"),
        # mamba: column-parallel in_proj, row-parallel out_proj
        (r"mamba/in_proj/w", "col"),
        (r"mamba/out_proj/w", "row"),
        (r"mamba/conv_w", lambda nd: P(None, "model")),
        (r"mamba/(A_log|D|dt_bias)", lambda nd: P("model",)),
        # xlstm blocks
        (r"(blk)/(up|wq|wk|wv|w_in)/w", "col"),
        (r"(blk)/(down)/w", "row"),
        (r"blk/w_if/w", lambda nd: P(None, None)),
        (r"blk/r$", lambda nd: P(None, None, None)),  # tiny sLSTM recurrent
        # frontends / misc projections: column-parallel
        (r"(patch_proj|frame_proj)/w", "col"),
        # norms, biases, scalars: replicated
        (r".*", lambda nd: P(*([None] * nd))),
    ]


def _col_spec(ndim: int) -> P:
    """Column-parallel: output dim (axis 0 of (out, in) weights) sharded.
    Packed sparse tensors (O, G, N) shard the same axis 0."""
    return P(*(["model"] + [None] * (ndim - 1)))


def _row_spec(ndim: int) -> P:
    """Row-parallel: contraction dim sharded.  Dense (out, in) -> axis 1;
    packed (O, G, N) -> the group axis 1 (groups tile the contraction dim,
    and choose_group aligned M to the shard size)."""
    if ndim == 1:
        return P(None)
    return P(*([None, "model"] + [None] * (ndim - 2)))


def spec_for_path(path: str, ndim: int) -> P:
    for pat, builder in _rules():
        if re.search(pat, path):
            if builder == "col":
                return _col_spec(ndim)
            if builder == "row":
                return _row_spec(ndim)
            spec = builder(ndim)
            # pad/truncate to ndim
            parts = list(spec) + [None] * (ndim - len(spec))
            return P(*parts[:ndim])
    return P(*([None] * ndim))




def _stacked_offset(leaf_ndim: int, spec_ndim: int) -> int:
    """Layer-stacked params have a leading (L,) axis (or (P, n_m) for xlstm
    periods): specs shift right by the extra leading dims."""
    return leaf_ndim - spec_ndim


def _linear_kind_impl(path: str, *, attn_kv_replicated: bool = False) -> str:
    probe = path.rstrip("/") + "/w"
    if attn_kv_replicated and re.search(r"(attn|xattn)/w[kv]/w", probe):
        return "replicated"
    for pat, builder in _rules():
        if re.search(pat, probe):
            return builder if builder in ("col", "row") else "replicated"
    return "replicated"


def linear_kind(path: str, **_kw) -> str:
    """Removed — the classifier lives on the plan object."""
    raise ValueError(
        "repro.sharding.partitioning.linear_kind was removed (PR 8 "
        "deprecation); use ShardingPlan(attn_kv_replicated=...)"
        ".linear_kind(path) — the plan carries the KV policy and per-node "
        "kind overrides")


def _packed_spec(kind: str, extra: int) -> P:
    """values/indices are (*stack, O, G, Ne): column-parallel shards the
    output axis O; row-parallel shards the group axis G (groups tile the
    contraction dim, and choose_group aligned M to the shard size); stack
    dims are replicated."""
    if kind == "col":
        core = ["model", None, None]
    elif kind == "row":
        core = [None, "model", None]
    else:
        core = [None, None, None]
    return P(*([None] * extra + core))


def _block_packed_specs(kind: str, extra: int):
    """Specs for the block layout: values/indices are
    (*stack, RB, A_max, block_r, Ne) and active_groups (*stack, RB, A_max).
    Column-parallel shards the row-block axis RB (row blocks tile the output
    dim, so each TP shard owns whole row blocks and their address streams).
    Row-parallel would shard the contraction dim, but the active-group ids
    address *global* M-groups — a *non-renumbered* row-parallel block weight
    therefore stays replicated.  To genuinely shard it, run the renumbering
    pass (``core.sparsity.shard_packed_row_parallel``, applied by
    ``ShardingPlan.renumber_params``): the shard-stacked result is handled
    structurally in :func:`packed_weight_specs` via ``pw.shard_axis``."""
    if kind == "col":
        core, ag = ["model", None, None, None], ["model", None]
    else:
        core, ag = [None] * 4, [None] * 2
    return (P(*([None] * extra + core)), P(*([None] * extra + ag)))


def _shard_stacked_specs(pw: PackedWeight) -> PackedWeight:
    """Specs for the renumbered shard-stacked form: every child carries the
    shard dim at index ``len(stack_dims)``, placed on ``pw.shard_axis`` so
    each mesh device holds exactly its locally-renumbered slice (the
    shard_map island in kernels/ops.py consumes them in place)."""
    extra = len(pw.stack_dims)
    ax = pw.shard_axis

    def spec(child):
        return P(*([None] * extra + [ax] + [None] * (child.ndim - extra - 1)))

    repl = {"values": spec(pw.values), "indices": spec(pw.indices)}
    if pw.layout == LAYOUT_BLOCK:
        repl["active_groups"] = spec(pw.active_groups)
    if pw.qdtype is not None:
        repl["scales"] = spec(pw.scales)
    return pw.replace(**repl)


def packed_weight_specs(pw: PackedWeight, kind: str) -> PackedWeight:
    """Structural PartitionSpecs for a PackedWeight node, returned in the
    same PackedWeight container so spec/sharding trees mirror the params.

    Quantized nodes (``repro.quant``) shard the ``scales`` child alongside
    ``values``: the scale axes are a prefix of the value axes (per output
    row or per group for xwT, per row-block × group × row for block), so
    column-parallel shards the same leading output axis; row-parallel
    shards per-group xwT scales on their group axis (it tiles the
    contraction dim exactly like the values' group axis) and leaves per-row
    scales replicated (no group axis to split).

    A renumbered shard-stacked node (``pw.shard_axis`` set) is placed on its
    own shard dim regardless of ``kind`` — the renumbering pass only ever
    produces row-parallel weights, and the shard dim *is* the contraction
    partition."""
    if pw.shard_axis is not None:
        return _shard_stacked_specs(pw)
    extra = len(pw.stack_dims)
    if pw.layout == LAYOUT_BLOCK:
        spec, ag_spec = _block_packed_specs(kind, extra)
        repl = {"values": spec, "indices": spec, "active_groups": ag_spec}
        if pw.qdtype is not None:
            core = (["model", None, None] if kind == "col" else [None] * 3)
            repl["scales"] = P(*([None] * extra + core))
        return pw.replace(**repl)
    spec = _packed_spec(kind, extra)
    repl = {"values": spec, "indices": spec}
    if pw.qdtype is not None:
        per_group = (getattr(pw.scales, "ndim", extra + 1) - extra) == 2
        if per_group:
            core = {"col": ["model", None], "row": [None, "model"]}.get(
                kind, [None, None])
        else:
            core = ["model"] if kind == "col" else [None]
        repl["scales"] = P(*([None] * extra + core))
    return pw.replace(**repl)


def _is_legacy_packed(node) -> bool:
    return isinstance(node, dict) and "values" in node and "shape" in node


def _param_specs_impl(params, *, attn_kv_replicated: bool = False,
                      kind_fn=None) -> dict:
    """PartitionSpec pytree matching ``params``.

    Handles layer stacking: rule specs are defined for the *unstacked*
    2-D/3-D weights; extra leading axes (scan stacking) are replicated.
    PackedWeight nodes are handled structurally: the module path picks
    col/row-parallel and the (O, G, Ne) geometry places the axes.

    ``attn_kv_replicated``: for archs whose KV head count does not divide
    TP (but whose Q heads do), K/V projection weights are replicated so the
    projected K/V tensors need no gather (DESIGN.md §5).

    ``kind_fn`` (path -> "col" | "row" | "replicated") overrides the rule
    table for PackedWeight nodes — the hook ShardingPlan.kind_overrides
    plugs into.
    """
    if kind_fn is None:
        def kind_fn(p):
            return _linear_kind_impl(p, attn_kv_replicated=attn_kv_replicated)

    def one(path, leaf):
        p = _path_str(path)
        if isinstance(leaf, PackedWeight):
            return packed_weight_specs(leaf, kind_fn(p))
        if _is_legacy_packed(leaf):
            raise ValueError(
                f"legacy packed {{values, indices, shape}} dict at {p!r} is "
                "no longer supported; pack with launch.pack_tree to get "
                "PackedWeight nodes")
        if not hasattr(leaf, "ndim"):
            return P()  # Static metadata
        nd = leaf.ndim
        # how many leading stack dims? infer from known rule arity:
        base_nd = _base_ndim(p, nd)
        extra = nd - base_nd
        if attn_kv_replicated and re.search(r"(attn|xattn)/w[kv]/w", p):
            base = P(*([None] * base_nd))
        else:
            base = spec_for_path(p, base_nd)
        return P(*([None] * extra + list(base)))

    return jax.tree_util.tree_map_with_path(
        one, params,
        is_leaf=lambda x: isinstance(x, PackedWeight) or _is_legacy_packed(x))


def param_specs(params, **_kw) -> dict:
    """Removed — spec derivation lives on the plan object."""
    raise ValueError(
        "repro.sharding.partitioning.param_specs was removed (PR 8 "
        "deprecation); use ShardingPlan(attn_kv_replicated=...)"
        ".param_specs(params) — the plan carries the KV policy, per-node "
        "kind overrides, and the renumber policy in one serializable "
        "object")


def _base_ndim(path: str, nd: int) -> int:
    """Arity of the unstacked tensor for this path."""
    if re.search(r"moe/w_(gate|up|down)", path):
        return 3
    if re.search(r"blk/r$", path):
        return 3
    if re.search(r"conv_w", path):
        return 2
    if re.search(r"(embed|unembed)/table", path):
        return 2
    if re.search(r"/w$", path):
        return 2
    if re.search(r"(scale|bias|A_log|D$|dt_bias)", path):
        return 1
    return min(nd, 2)


def opt_state_specs(pspecs, param_shapes=None, data_degree: int = 16) -> dict:
    """ZeRO-1: shard optimizer moments over 'data' on a still-replicated
    axis whose size divides the data degree (grads are reduce-scattered onto
    the shard, updates all-gathered back — SPMD inserts both).

    ``param_shapes`` (same structure) enables divisibility checks; without
    it, only the first None axis is used unchecked (legacy behaviour)."""

    def one(spec, shape=None):
        if not isinstance(spec, P):
            return spec
        parts = list(spec)
        candidates = [i for i, s in enumerate(parts) if s is None]
        if shape is not None:
            dims = shape.shape if hasattr(shape, "shape") else shape
            candidates = [i for i in candidates
                          if i < len(dims) and dims[i] % data_degree == 0]
            # prefer the largest divisible axis (best shard balance)
            candidates.sort(key=lambda i: -dims[i])
        if candidates:
            parts[candidates[0]] = "data"
            return P(*parts)
        return spec

    if param_shapes is None:
        return jax.tree_util.tree_map(
            one, pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_s, treedef = jax.tree_util.tree_flatten(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_p = treedef.flatten_up_to(param_shapes)
    return treedef.unflatten([one(s, p) for s, p in zip(flat_s, flat_p)])


def shardings_for(mesh: Mesh, specs) -> dict:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else
        NamedSharding(mesh, P()),
        specs, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation/batch specs
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh):
    """The data-parallel axes present in this mesh ('pod' included)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def batch_spec(mesh: Mesh, ndim: int, *, seq_axis: Optional[int] = None,
               seq_shard: bool = False) -> P:
    """Batch tensors: leading axis over DP axes; optionally shard a sequence
    axis over 'data' (long-context decode)."""
    parts = [batch_axes(mesh)] + [None] * (ndim - 1)
    if seq_shard and seq_axis is not None:
        parts[0] = "pod" if "pod" in mesh.axis_names else None
        parts[seq_axis] = "data"
    return P(*parts)


def cache_spec(mesh: Mesh, ndim: int, *, batch_axis: int = 1,
               head_axis: int = 3, seq_axis: int = 2,
               shard_heads: bool, seq_shard: bool = False) -> P:
    """KV caches (L, B, S, H, Dh): batch over DP, heads over model (when the
    arch's KV heads divide TP), optionally sequence over 'data'."""
    parts = [None] * ndim
    if seq_shard:
        parts[seq_axis] = "data"
        if "pod" in mesh.axis_names:
            parts[batch_axis] = "pod"
    else:
        parts[batch_axis] = batch_axes(mesh)
    if shard_heads:
        parts[head_axis] = "model"
    return P(*parts)
