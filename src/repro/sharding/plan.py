"""ShardingPlan: one serializable object describing how a model is placed.

Previously the distribution story was spread over loose knobs —
``param_specs(attn_kv_replicated=...)``, ``linear_kind``, ad-hoc mesh
construction in launch scripts — none of which survived a checkpoint
round-trip.  A :class:`ShardingPlan` gathers them:

  * parallelism degrees (``tp`` / ``pp`` / ``dp``) and their mesh axis names,
  * the KV-replication policy for archs whose KV head count does not
    divide TP (DESIGN.md §5),
  * per-node kind overrides (regex → col/row/replicated) for weights the
    rule table misclassifies,
  * the renumber policy for row-parallel *block*-layout packed weights,
    whose active-group ids address global M-groups and therefore cannot be
    sharded by GSPMD alone (see ``core.sparsity.shard_packed_row_parallel``).

Plans are frozen/hashable (they ride on ``ExecPolicy``, a jit static arg)
and JSON round-trip (they ride in the checkpoint manifest, so a restore
knows the geometry its packed weights were renumbered for).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.sparsity import (
    LAYOUT_BLOCK,
    PackedWeight,
    shard_packed_row_parallel,
)
from repro.core.treeutil import key_path_str as _path_str
from repro.sharding import context as shctx
from repro.sharding.partitioning import (
    _linear_kind_impl,
    _param_specs_impl,
    shardings_for,
)

RENUMBER = "renumber"      # shard row-parallel packed weights for real
REPLICATE = "replicate"    # keep them replicated (shard_map-free fallback)

_PLAN_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """How a model's params, decode state, and collectives are laid out.

    ``kind_overrides`` is a tuple of ``(path_regex, kind)`` pairs checked
    before the rule table (first match wins); kinds are ``"col"`` /
    ``"row"`` / ``"replicated"``.

    ``renumber`` selects what happens to row-parallel packed weights when
    ``tp > 1``: :data:`RENUMBER` runs the per-shard active-group
    renumbering pass so the contraction dim genuinely shards (required for
    block layout; also packs xwT into the shard-stacked form consumed by
    the shard_map island), :data:`REPLICATE` leaves them whole on every
    device (correct, memory-hungry, no collective on the packed matmul).
    """

    tp: int = 1
    pp: int = 1
    dp: int = 1
    tp_axis: str = "model"
    pp_axis: str = "pipe"
    dp_axis: str = "data"
    attn_kv_replicated: bool = False
    renumber: str = RENUMBER
    kind_overrides: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self):
        for name in ("tp", "pp", "dp"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1")
        if self.renumber not in (RENUMBER, REPLICATE):
            raise ValueError(
                f"renumber must be {RENUMBER!r} or {REPLICATE!r}, "
                f"got {self.renumber!r}")
        if self.tp_axis != "model":
            # The partitioning rule table and ShardingContext.tp hard-code
            # the 'model' axis name; renaming it is not yet supported.
            raise ValueError("tp_axis must be 'model'")
        # tuple-of-tuples form (lists sneak in via from_json callers)
        object.__setattr__(
            self, "kind_overrides",
            tuple((str(p), str(k)) for p, k in self.kind_overrides))
        for _, k in self.kind_overrides:
            if k not in ("col", "row", "replicated"):
                raise ValueError(f"bad kind override {k!r}")

    # -- geometry -----------------------------------------------------------

    def device_degree(self) -> int:
        return self.tp * self.pp * self.dp

    def make_mesh(self, devices=None) -> Optional[Mesh]:
        """Build the ``(dp, pp, tp)`` mesh, or None for a single device.

        The TP axis is always present (degree 1 included) so downstream
        ``mesh.shape['model']`` lookups hold; pp/dp axes appear only when
        their degree exceeds 1.
        """
        n = self.device_degree()
        if n == 1:
            return None
        devices = list(jax.devices() if devices is None else devices)
        if len(devices) < n:
            raise ValueError(
                f"plan needs {n} devices (tp={self.tp} pp={self.pp} "
                f"dp={self.dp}), only {len(devices)} available")
        shape, names = [], []
        if self.dp > 1:
            shape.append(self.dp)
            names.append(self.dp_axis)
        if self.pp > 1:
            shape.append(self.pp)
            names.append(self.pp_axis)
        shape.append(self.tp)
        names.append(self.tp_axis)
        dev = np.array(devices[:n]).reshape(shape)
        return Mesh(dev, tuple(names))

    def context(self, mesh: Mesh, *, num_kv_heads: int = 16,
                num_heads: int = 0) -> shctx.ShardingContext:
        """The ShardingContext to install (``shctx.use_mesh``) around jit
        trace and execution for this plan."""
        return shctx.make_context(
            mesh, num_kv_heads=num_kv_heads, num_heads=num_heads)

    # -- classification / specs --------------------------------------------

    def linear_kind(self, path: str) -> str:
        """col/row/replicated for a linear module path — overrides first,
        then the shared rule table."""
        for pat, kind in self.kind_overrides:
            if re.search(pat, path):
                return kind
        return _linear_kind_impl(
            path, attn_kv_replicated=self.attn_kv_replicated)

    def _axis_degree(self, name) -> int:
        if isinstance(name, (tuple, list)):
            d = 1
            for n in name:
                d *= self._axis_degree(n)
            return d
        return {self.tp_axis: self.tp, self.pp_axis: self.pp,
                self.dp_axis: self.dp}.get(name, 1)

    def param_specs(self, params):
        """PartitionSpec pytree for ``params`` under this plan.

        Call on the *renumbered* tree (:meth:`renumber_params`) — the
        shard-stacked PackedWeight form carries its own specs.

        Specs are sanitized against the actual leaf shapes: a dim the rule
        table would shard whose size does not divide the axis degree falls
        back to replicated (e.g. a block weight packed into a single row
        block under TP=2), instead of failing inside ``device_put``.
        """
        specs = _param_specs_impl(
            params, attn_kv_replicated=self.attn_kv_replicated,
            kind_fn=self.linear_kind)

        def sane(spec, leaf):
            if not isinstance(spec, P) or not hasattr(leaf, "shape"):
                return spec
            parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
            parts = [ax if ax is None or
                     leaf.shape[i] % self._axis_degree(ax) == 0 else None
                     for i, ax in enumerate(parts)]
            return P(*parts)

        is_p = lambda x: isinstance(x, P)
        flat_s, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_p)
        flat_p = treedef.flatten_up_to(params)
        return treedef.unflatten(
            [sane(s, p) for s, p in zip(flat_s, flat_p)])

    # -- packed-weight renumbering -----------------------------------------

    def renumber_params(self, params):
        """Rewrite row-parallel PackedWeights into the shard-stacked,
        locally-renumbered form (``core.sparsity.shard_packed_row_parallel``)
        so their contraction dim genuinely shards over ``tp_axis``.

        No-op when ``tp == 1`` or ``renumber == 'replicate'``.  Nodes the
        pass cannot shard are left whole (replicated): group count not a
        multiple of ``tp``, already-sharded nodes, and int8 *block* nodes
        (their zero-value validity probe is unreliable — see
        ``_block_shard_arrays``).  Run on concrete (non-tracer) params.
        """
        if self.tp == 1 or self.renumber == REPLICATE:
            return params

        def one(path, leaf):
            if not isinstance(leaf, PackedWeight):
                return leaf
            pw = leaf
            if pw.shard_axis is not None:
                return pw
            if self.linear_kind(_path_str(path)) != "row":
                return pw
            if pw.groups % self.tp != 0:
                return pw
            if pw.layout == LAYOUT_BLOCK and pw.qdtype is not None:
                return pw
            return shard_packed_row_parallel(pw, self.tp, axis=self.tp_axis)

        return jax.tree_util.tree_map_with_path(
            one, params, is_leaf=lambda x: isinstance(x, PackedWeight))

    def shard_params(self, params, mesh: Optional[Mesh] = None):
        """Renumber + device_put ``params`` onto ``mesh`` per this plan.
        Returns the placed tree (identity when the plan is single-device)."""
        mesh = mesh if mesh is not None else self.make_mesh()
        params = self.renumber_params(params)
        if mesh is None:
            return params
        shardings = shardings_for(mesh, self.param_specs(params))
        return jax.device_put(params, shardings)

    # -- decode state -------------------------------------------------------

    def decode_state_specs(self, state, *, num_kv_heads: int):
        """PartitionSpec tree for a decode state: KV tensors (contiguous
        caches (L, B, S, Hkv, Dh) and paged arenas (L, Np, P, Hkv, Dh) —
        both ndim-5 with heads at axis 3) shard the head axis over
        ``tp_axis`` when the head count divides TP; everything else
        (positions, lengths, block tables) is replicated."""
        shard_heads = self.tp > 1 and num_kv_heads % self.tp == 0

        def one(leaf):
            nd = getattr(leaf, "ndim", None)
            if shard_heads and nd == 5 and leaf.shape[3] == num_kv_heads:
                return P(None, None, None, self.tp_axis, None)
            return P()

        return jax.tree_util.tree_map(one, state)

    def shard_decode_state(self, state, mesh: Optional[Mesh], *,
                           num_kv_heads: int):
        """device_put a freshly initialised decode state per
        :meth:`decode_state_specs` (identity without a mesh)."""
        if mesh is None:
            return state
        specs = self.decode_state_specs(state, num_kv_heads=num_kv_heads)
        return jax.device_put(state, shardings_for(mesh, specs))

    # -- serialization ------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "version": _PLAN_VERSION,
            "tp": self.tp, "pp": self.pp, "dp": self.dp,
            "tp_axis": self.tp_axis, "pp_axis": self.pp_axis,
            "dp_axis": self.dp_axis,
            "attn_kv_replicated": self.attn_kv_replicated,
            "renumber": self.renumber,
            "kind_overrides": [list(kv) for kv in self.kind_overrides],
        }

    @classmethod
    def from_json(cls, d: dict) -> "ShardingPlan":
        v = d.get("version", _PLAN_VERSION)
        if v > _PLAN_VERSION:
            raise ValueError(f"unknown ShardingPlan version {v}")
        return cls(
            tp=int(d.get("tp", 1)), pp=int(d.get("pp", 1)),
            dp=int(d.get("dp", 1)),
            tp_axis=d.get("tp_axis", "model"),
            pp_axis=d.get("pp_axis", "pipe"),
            dp_axis=d.get("dp_axis", "data"),
            attn_kv_replicated=bool(d.get("attn_kv_replicated", False)),
            renumber=d.get("renumber", RENUMBER),
            kind_overrides=tuple(
                (p, k) for p, k in d.get("kind_overrides", [])),
        )


def single_device_plan() -> ShardingPlan:
    """The trivial plan (tp=pp=dp=1): make_mesh() is None and every
    transform is the identity."""
    return ShardingPlan()
