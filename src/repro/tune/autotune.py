"""Measured autotuning of DeMM kernel variants.

Pipeline (per problem):

  1. **Enumerate** — every supported registered variant × the cartesian grid
     of its declared tile-candidate values (plus its heuristic default).
  2. **Prune** — drop candidates whose per-grid-step VMEM working set
     exceeds the budget (the TPU has ~16 MiB/core and the Pallas pipeline
     double-buffers every block), then rank the survivors with the
     first-order DeMM schedule model (:func:`repro.core.perfmodel
     .demm_tile_cycles`) and keep the ``max_measure`` most promising.
  3. **Measure** — run each survivor with ``warmup`` untimed iterations
     (compile + cache warm) followed by ``iters`` timed calls, each fenced
     with ``block_until_ready``; the score is the minimum (least-noise
     estimator for a deterministic kernel).  Every dispatchable candidate is
     measured under ``jax.jit`` — the regime production dispatch runs in —
     so eager-dispatch overhead never mis-ranks variants.
  4. **Select & persist** — the fastest *dispatchable* candidate is written
     to the tuning cache keyed by the full problem description.  The
     heuristic default is always measured, so the tuned choice is never
     slower than the default on the measured host.

``measure_only`` variants (the spmm-orientation block_spmm, which repacks
flat packed operands on the host) are measured and reported in the result
table but never selected for dispatch — they cannot be invoked from inside a
jit trace.  The ``xwT_block`` op has no such restriction: its operands are
packed ahead of time by ``core.sparsity.pack_block``, so the block kernel is
a first-class dispatch target (see :func:`autotune_xwT_block`).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.perfmodel import demm_tile_cycles
from repro.core.sparsity import SparsityConfig
from repro.tune.cache import TuneCache, TunedConfig, default_cache
from repro.tune.registry import KernelVariant, Problem, variants_for

# ~16 MiB/core on current TPUs; leave headroom for semaphores/scalars.
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024
_DOUBLE_BUFFER = 2


def _dtype_bytes(dtype: str) -> int:
    return jnp.dtype(dtype).itemsize


def vmem_bytes(problem: Problem, variant: str, params: Dict[str, int]) -> int:
    """Per-grid-step VMEM working set of a Pallas candidate (bytes).

    Counts the double-buffered input/output blocks plus the materialized
    (rows, M) scatter matrix S.  Non-Pallas variants (no tile params) have
    no VMEM footprint to check — returns 0.
    """
    if not params:
        return 0
    eb = _dtype_bytes(problem.dtype)
    n, m, _ = problem.sparsity
    ne = problem.cfg.n_effective
    # quantized ops stream int8 values (+ a small fp32 scale per row) while
    # activations/scatter stay in the activation dtype (w8a16)
    quant = problem.op.endswith("_q8")
    vb = 1 if quant else eb
    if problem.op in ("xwT", "xwT_q8"):
        bb = params.get("block_b", 128)
        bo = params.get("block_o", 128)
        x_blk = bb * m * eb
        w_blk = bo * ne * (vb + 4)          # values + int32 indices
        if quant:
            w_blk += bo * 4                 # per-row scales
        out_blk = bb * bo * 4               # fp32 accumulator
        scatter = bo * m * eb
    elif problem.op in ("xwT_block", "xwT_block_q8"):
        # block_r is pack-time geometry (Problem.block_r), not a tile param.
        br = problem.block_r or 128
        bc = params.get("cd_block", 256)
        x_blk = m * bc * eb                 # gathered B (= xᵀ) block
        w_blk = br * ne * (vb + 4)
        if quant:
            w_blk += br * 4                 # per-(group, row) scales
        out_blk = br * bc * 4
        scatter = br * m * eb
    else:  # spmm / block_spmm
        br = params.get("block_r", 128)
        bc = params.get("block_c", params.get("cd_block", 256))
        x_blk = m * bc * eb                 # resident B block
        w_blk = br * ne * (eb + 4)
        out_blk = br * bc * 4
        scatter = br * m * eb
    return _DOUBLE_BUFFER * (x_blk + w_blk + out_blk) + scatter


@functools.lru_cache(maxsize=512)
def _schedule_cycles(problem: Problem, block_cols: int) -> int:
    # The perfmodel schedule depends only on (problem, block_cols); dozens of
    # tile candidates share a block_cols, and the representative mask draw is
    # expensive for big shapes — memoize.
    return demm_tile_cycles(problem.out, problem.k, problem.rows,
                            problem.cfg, block_cols)


def estimate_cycles(problem: Problem, params: Dict[str, int]) -> int:
    """Rank a tile candidate with the perfmodel DeMM schedule + a per-grid-
    step dispatch overhead (favors fewer, fatter tiles at equal schedule)."""
    if problem.op in ("xwT", "xwT_q8"):
        block_cols = params.get("block_b", 128)
        row_tiles = -(-problem.out // max(1, params.get("block_o", 128)))
        col_tiles = -(-problem.rows // max(1, block_cols))
        inner = problem.groups
    elif problem.op in ("xwT_block", "xwT_block_q8"):
        block_cols = params.get("cd_block", 256)
        row_tiles = -(-problem.out // max(1, problem.block_r or 128))
        col_tiles = -(-problem.rows // max(1, block_cols))
        # the inner grid dim visits only the active groups — the decoupled
        # address stream's whole point.
        inner = max(1, problem.a_max)
    else:
        block_cols = params.get("block_c", params.get("cd_block", 256))
        row_tiles = -(-problem.out // max(1, params.get("block_r", 128)))
        col_tiles = -(-problem.rows // max(1, block_cols))
        inner = problem.groups
    base = _schedule_cycles(problem, block_cols)
    grid_steps = row_tiles * col_tiles * inner
    return int(base + 50 * grid_steps)


def measure(thunk: Callable[[], jax.Array], *, warmup: int = 2,
            iters: int = 5) -> float:
    """Wall-time a jax thunk: ``warmup`` untimed calls (compile), then the
    min over ``iters`` fenced timings, in seconds."""
    for _ in range(max(1, warmup)):
        thunk().block_until_ready()
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        thunk().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


@dataclasses.dataclass
class Candidate:
    backend: str
    params: Dict[str, int]
    vmem: int = 0
    est_cycles: Optional[int] = None
    measured_s: Optional[float] = None
    status: str = "enumerated"   # pruned_vmem | pruned_rank | measured | error
    note: str = ""

    def row(self) -> dict:
        return {"backend": self.backend, "params": dict(self.params),
                "vmem_bytes": self.vmem, "est_cycles": self.est_cycles,
                "measured_us": (None if self.measured_s is None
                                else self.measured_s * 1e6),
                "status": self.status, "note": self.note}


@dataclasses.dataclass
class TuneResult:
    problem: Problem
    best: TunedConfig
    candidates: List[Candidate]

    @property
    def best_us(self) -> float:
        return self.best.measured_us

    def table(self) -> List[dict]:
        return [c.row() for c in self.candidates]


def _param_grid(variant: KernelVariant, problem: Problem) -> List[Dict[str, int]]:
    space = variant.param_space(problem)
    if not space:
        return [{}]
    names = sorted(space)
    grids = [space[n] for n in names]
    out = [dict(zip(names, vals)) for vals in itertools.product(*grids)]
    default = variant.default_params(problem)
    if default not in out:
        out.append(default)
    return out


def enumerate_candidates(problem: Problem,
                         include_measure_only: bool = True) -> List[Candidate]:
    cands = []
    for v in variants_for(problem.op, problem,
                          include_measure_only=include_measure_only):
        for params in _param_grid(v, problem):
            cands.append(Candidate(backend=v.name, params=params))
    return cands


def prune_candidates(problem: Problem, cands: List[Candidate], *,
                     vmem_budget: int = DEFAULT_VMEM_BUDGET,
                     max_measure: int = 8) -> List[Candidate]:
    """VMEM-budget check, then perfmodel ranking; keeps the defaults of each
    variant unconditionally so tuned-vs-default is always a measured pair."""
    defaults = {v.name: v.default_params(problem)
                for v in variants_for(problem.op, problem,
                                      include_measure_only=True)}
    survivors = []
    for c in cands:
        c.vmem = vmem_bytes(problem, c.backend, c.params)
        if c.vmem > vmem_budget:
            c.status = "pruned_vmem"
            continue
        c.est_cycles = (estimate_cycles(problem, c.params)
                        if c.params else None)
        survivors.append(c)
    keep = [c for c in survivors if defaults.get(c.backend) == c.params]
    rest = sorted((c for c in survivors if c not in keep),
                  key=lambda c: (c.est_cycles is None, c.est_cycles or 0))
    limit = max(max_measure, len(keep))
    for c in rest:
        if len(keep) < limit:
            keep.append(c)
        else:
            c.status = "pruned_rank"
    return keep


def _autotune(problem: Problem,
              make_thunk: Callable[[Candidate], Callable[[], jax.Array]],
              *, vmem_budget: int, max_measure: int, warmup: int, iters: int,
              cache: Optional[TuneCache], persist: bool) -> TuneResult:
    from repro import obs

    m = obs.metrics()
    measurements = m.counter("tune_autotune_measurements_total",
                             help="candidate kernels timed by autotune",
                             op=problem.op)
    cands = enumerate_candidates(problem)
    keep = prune_candidates(problem, cands, vmem_budget=vmem_budget,
                            max_measure=max_measure)
    measure_only = {v.name for v in variants_for(
        problem.op, problem, include_measure_only=True) if v.measure_only}
    for c in keep:
        try:
            c.measured_s = measure(make_thunk(c), warmup=warmup, iters=iters)
            c.status = "measured"
            measurements.inc()
        except Exception as e:  # noqa: BLE001 — an unmeasurable candidate
            c.status = "error"  # (e.g. unsupported tiling) is skipped, not fatal
            c.note = f"{type(e).__name__}: {e}"[:200]
        # one trace event per candidate: the autotune audit trail a tuned
        # cache entry can be traced back to
        m.trace.event("autotune_measure", op=problem.op, backend=c.backend,
                      params=dict(c.params), status=c.status,
                      us=(None if c.measured_s is None
                          else c.measured_s * 1e6))
    measured = [c for c in keep if c.status == "measured"
                and c.backend not in measure_only]
    if not measured:
        raise RuntimeError(
            f"autotune: no dispatchable candidate measured for {problem}; "
            f"statuses: {[(c.backend, c.status, c.note) for c in keep]}")
    best_c = min(measured, key=lambda c: c.measured_s)
    best = TunedConfig(backend=best_c.backend, params=dict(best_c.params),
                       measured_us=best_c.measured_s * 1e6, source="tuned")
    m.trace.event("autotune_select", op=problem.op, backend=best.backend,
                  params=dict(best.params), us=best.measured_us)
    cache = cache or default_cache()
    cache.put(problem, best, persist=persist)
    return TuneResult(problem=problem, best=best, candidates=cands)


def autotune_xwT(x: jax.Array, values: jax.Array, indices: jax.Array,
                 cfg: SparsityConfig, w_shape: Tuple[int, int], *,
                 vmem_budget: int = DEFAULT_VMEM_BUDGET, max_measure: int = 8,
                 warmup: int = 2, iters: int = 5,
                 cache: Optional[TuneCache] = None,
                 persist: bool = True) -> TuneResult:
    """Tune ``y = x @ W_sparseᵀ`` for the concrete operands given."""
    from repro.tune.registry import get_variant

    problem = Problem.for_xwT(x.shape, w_shape, cfg, x.dtype)

    def make_thunk(c: Candidate):
        v = get_variant("xwT", c.backend)
        # Production dispatch runs inside jit-compiled steps: measure every
        # candidate in that regime (the Pallas entry points are themselves
        # jitted; timing the reference eagerly would compare eager-dispatch
        # XLA against compiled Pallas and mis-rank them).
        if v.measure_only:
            return lambda: v.call(x, values, indices, cfg, tuple(w_shape),
                                  **c.params)
        jf = jax.jit(lambda xx, vv, ii: v.call(
            xx, vv, ii, cfg, tuple(w_shape), **c.params))
        return lambda: jf(x, values, indices)

    return _autotune(problem, make_thunk, vmem_budget=vmem_budget,
                     max_measure=max_measure, warmup=warmup, iters=iters,
                     cache=cache, persist=persist)


def autotune_xwT_q8(x: jax.Array, values: jax.Array, indices: jax.Array,
                    scales: jax.Array, cfg: SparsityConfig,
                    w_shape: Tuple[int, int], *,
                    vmem_budget: int = DEFAULT_VMEM_BUDGET,
                    max_measure: int = 8, warmup: int = 2, iters: int = 5,
                    cache: Optional[TuneCache] = None,
                    persist: bool = True) -> TuneResult:
    """Tune ``y = x @ W_q8ᵀ`` (int8 values + per-output-row scales); keyed
    under the distinct ``xwT_q8`` op so float entries are never shadowed."""
    from repro.tune.registry import get_variant

    problem = Problem.for_xwT(x.shape, w_shape, cfg, x.dtype, quantized=True)

    def make_thunk(c: Candidate):
        v = get_variant("xwT_q8", c.backend)
        jf = jax.jit(lambda xx, vv, ii, ss: v.call(
            xx, vv, ii, ss, cfg, tuple(w_shape), **c.params))
        return lambda: jf(x, values, indices, scales)

    return _autotune(problem, make_thunk, vmem_budget=vmem_budget,
                     max_measure=max_measure, warmup=warmup, iters=iters,
                     cache=cache, persist=persist)


def autotune_xwT_block(x: jax.Array, pw, *,
                       vmem_budget: int = DEFAULT_VMEM_BUDGET,
                       max_measure: int = 8, warmup: int = 2, iters: int = 5,
                       cache: Optional[TuneCache] = None,
                       persist: bool = True) -> TuneResult:
    """Tune ``y = x @ W^T`` for a block-layout
    :class:`~repro.core.sparsity.PackedWeight` (geometry, pattern, and
    quantization come from the type's static aux data — a quantized node
    tunes the ``xwT_block_q8`` op).  All block variants are dispatchable, so
    the winner is directly selectable by ``backend="auto"``.
    """
    from repro.tune.registry import get_variant

    problem = Problem.for_xwT_block(x.shape, pw, x.dtype)
    cfg, w_shape = pw.cfg, tuple(pw.dense_shape)
    values, indices, active_groups = pw.values, pw.indices, pw.active_groups
    scales = pw.scales

    def make_thunk(c: Candidate):
        v = get_variant(problem.op, c.backend)
        if scales is not None:
            jf = jax.jit(lambda xx, vv, ii, ag, ss: v.call(
                xx, vv, ii, ag, ss, cfg, w_shape, **c.params))
            return lambda: jf(x, values, indices, active_groups, scales)
        jf = jax.jit(lambda xx, vv, ii, ag: v.call(
            xx, vv, ii, ag, cfg, w_shape, **c.params))
        return lambda: jf(x, values, indices, active_groups)

    return _autotune(problem, make_thunk, vmem_budget=vmem_budget,
                     max_measure=max_measure, warmup=warmup, iters=iters,
                     cache=cache, persist=persist)


def autotune_spmm(values: jax.Array, indices: jax.Array, b: jax.Array,
                  cfg: SparsityConfig, a_shape: Tuple[int, int], *,
                  vmem_budget: int = DEFAULT_VMEM_BUDGET, max_measure: int = 8,
                  warmup: int = 2, iters: int = 5,
                  cache: Optional[TuneCache] = None,
                  persist: bool = True) -> TuneResult:
    """Tune ``C = A_sparse @ B`` for the concrete operands given."""
    from repro.tune.registry import get_variant

    problem = Problem.for_spmm(a_shape, b.shape, cfg, b.dtype)

    def make_thunk(c: Candidate):
        v = get_variant("spmm", c.backend)
        if v.measure_only:   # host-side repacking cannot trace under jit
            return lambda: v.call(values, indices, b, cfg, tuple(a_shape),
                                  **c.params)
        jf = jax.jit(lambda vv, ii, bb: v.call(
            vv, ii, bb, cfg, tuple(a_shape), **c.params))
        return lambda: jf(values, indices, b)

    return _autotune(problem, make_thunk, vmem_budget=vmem_budget,
                     max_measure=max_measure, warmup=warmup, iters=iters,
                     cache=cache, persist=persist)
