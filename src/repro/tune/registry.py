"""Kernel variant registry — the dispatch layer of ``repro.tune``.

Every DeMM matmul implementation (the pure-jnp reference, the fused Pallas
TPU kernel, its interpret-mode twin, and the scalar-prefetch block-spmm) is
registered here as a :class:`KernelVariant` declaring

  * how to *call* it with a uniform signature per op,
  * which *tunable parameters* it exposes (tile sizes) and their candidate
    values for a given problem,
  * on which *platforms / problems* it is supported,
  * its *default* (heuristic) parameters.

``kernels/ops.py`` dispatches through this registry instead of matching raw
backend strings, so a new kernel variant (a GPU backend, a different tiling
strategy) plugs in with one ``register_variant`` call and is immediately
visible to the autotuner, the benchmark harness, and ``backend="auto"``.

Ops and uniform signatures
--------------------------
``xwT``       : call(x, values, indices, cfg, w_shape, **params) -> (B, O)
``spmm``      : call(values, indices, b, cfg, a_shape, **params) -> (R, Cd)
``xwT_block`` : call(x, values, indices, active_groups, cfg, w_shape,
                **params) -> (B, O) — the two-level block layout packed ahead
                of time by ``core.sparsity.pack_block`` (values/indices
                (RB, A_max, block_r, Ne) + active_groups (RB, A_max)), fully
                dispatchable under jit (no host repacking).
``xwT_q8``    : call(x, values, indices, scales, cfg, w_shape, **params)
                -> (B, O) — int8 values + per-output-row scales (O,)
                (repro.quant); kernels dequantize in-register (w8a16).
``xwT_block_q8``: call(x, values, indices, active_groups, scales, cfg,
                w_shape, **params) -> (B, O) — the quantized two-level
                layout, scales (RB, A_max, block_r).

A :class:`Problem` is the static description of one matmul instance — shapes,
dtype, sparsity pattern, platform — and is everything a variant needs to
decide support, defaults, and candidate tiles (no concrete arrays).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax

from repro.core.sparsity import SparsityConfig

OPS = ("xwT", "spmm", "xwT_block", "xwT_q8", "xwT_block_q8")


def current_platform() -> str:
    """'tpu' | 'gpu' | 'cpu' of the default JAX backend."""
    return jax.default_backend()


@dataclasses.dataclass(frozen=True)
class Problem:
    """Static description of one sparse-matmul instance.

    ``rows``  — rows of the dense operand (batch tokens for xwT/xwT_block,
                output columns Cd for spmm's B).
    ``out``   — rows of the sparse operand (O for xwT, R for spmm).
    ``k``     — contraction dim (== groups * cfg.m).
    ``block_r``/``a_max`` — static block geometry of the two-level layout
                (``xwT_block`` only; 0 otherwise).  Fixed at pack time, so
                it is part of the problem, not a tunable parameter.
    ``shards`` — contraction-sharding degree when this is the *per-shard*
                problem of a renumbered row-parallel weight (``k``/``a_max``
                are then shard-local).  Part of the cache key so a tuned
                entry for the global shape is never silently reused for its
                TP slices (and vice versa).
    """

    op: str
    rows: int
    out: int
    k: int
    dtype: str                      # canonical jnp dtype name, e.g. "float32"
    sparsity: Tuple[int, int, int]  # (n, m, k_reconfig)
    platform: str = "cpu"
    block_r: int = 0
    a_max: int = 0
    shards: int = 1

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r}; expected one of {OPS}")

    @property
    def cfg(self) -> SparsityConfig:
        n, m, k = self.sparsity
        return SparsityConfig(n, m, k)

    @property
    def groups(self) -> int:
        return self.k // self.sparsity[1]

    @property
    def dense_flops(self) -> int:
        return 2 * self.rows * self.out * self.k

    @classmethod
    def for_xwT(cls, x_shape, w_shape, cfg: SparsityConfig, dtype,
                platform: Optional[str] = None, *,
                quantized: bool = False, shards: int = 1) -> "Problem":
        """``dtype`` is the *activation* dtype; quantized problems (int8
        weights, w8a16 kernels) are a distinct op — and therefore distinct
        tuning-cache keys — from their float twins."""
        return cls(op="xwT_q8" if quantized else "xwT",
                   rows=int(x_shape[0]), out=int(w_shape[0]),
                   k=int(x_shape[1]), dtype=jax.numpy.dtype(dtype).name,
                   sparsity=(cfg.n, cfg.m, cfg.k),
                   platform=platform or current_platform(),
                   shards=int(shards))

    @classmethod
    def for_spmm(cls, a_shape, b_shape, cfg: SparsityConfig, dtype,
                 platform: Optional[str] = None) -> "Problem":
        return cls(op="spmm", rows=int(b_shape[1]), out=int(a_shape[0]),
                   k=int(b_shape[0]), dtype=jax.numpy.dtype(dtype).name,
                   sparsity=(cfg.n, cfg.m, cfg.k),
                   platform=platform or current_platform())

    @classmethod
    def for_xwT_block(cls, x_shape, pw, dtype,
                      platform: Optional[str] = None) -> "Problem":
        """Problem for a block-layout PackedWeight serving matmul; geometry,
        pattern, and quantization are read from the type's static aux data
        (a quantized node is the distinct ``xwT_block_q8`` op)."""
        o, k = pw.dense_shape
        block_r, a_max = pw.block_geom
        cfg = pw.cfg
        op = "xwT_block_q8" if pw.qdtype is not None else "xwT_block"
        return cls(op=op, rows=int(x_shape[0]), out=int(o),
                   k=int(k), dtype=jax.numpy.dtype(dtype).name,
                   sparsity=(cfg.n, cfg.m, cfg.k),
                   platform=platform or current_platform(),
                   block_r=int(block_r), a_max=int(a_max),
                   shards=int(getattr(pw, "shards", 1)))


@dataclasses.dataclass(frozen=True)
class KernelVariant:
    """One registered implementation of a DeMM op."""

    op: str
    name: str
    call: Callable
    # Problem -> {param: (candidate, ...)}; empty dict = nothing to tune.
    param_space: Callable[[Problem], Dict[str, Tuple[int, ...]]]
    # Problem -> {param: value}
    default_params: Callable[[Problem], Dict[str, int]]
    # Problem -> bool
    supported: Callable[[Problem], bool]
    # Variants that need host-side repacking of concrete arrays (cannot be
    # dispatched inside a jit trace); the autotuner may still measure them.
    measure_only: bool = False
    description: str = ""


_REGISTRY: Dict[Tuple[str, str], KernelVariant] = {}


def register_variant(variant: KernelVariant, *, overwrite: bool = False):
    key = (variant.op, variant.name)
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"variant {key} already registered")
    _REGISTRY[key] = variant
    return variant


def get_variant(op: str, name: str) -> KernelVariant:
    try:
        return _REGISTRY[(op, name)]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r} for op {op!r}; registered: "
            f"{sorted(n for (o, n) in _REGISTRY if o == op)}") from None


def variants_for(op: str, problem: Optional[Problem] = None,
                 include_measure_only: bool = False) -> Sequence[KernelVariant]:
    """All registered variants of ``op``, optionally filtered to the ones
    supporting ``problem`` and dispatchable from inside a jit trace."""
    out = []
    for (o, _), v in sorted(_REGISTRY.items()):
        if o != op:
            continue
        if v.measure_only and not include_measure_only:
            continue
        if problem is not None and not v.supported(problem):
            continue
        out.append(v)
    return out


def backend_names(op: str) -> Tuple[str, ...]:
    return tuple(sorted(n for (o, n) in _REGISTRY if o == op))


# ---------------------------------------------------------------------------
# Tile-candidate helpers shared by the built-in variants
# ---------------------------------------------------------------------------

def _pow2_candidates(dim: int, lo: int, hi: int) -> Tuple[int, ...]:
    """Powers of two in [lo, hi] clipped to ``dim`` (always non-empty)."""
    cands = []
    v = lo
    while v <= hi:
        cands.append(min(v, dim))
        if v >= dim:
            break
        v *= 2
    return tuple(dict.fromkeys(cands)) or (min(dim, lo),)


# Interpret mode emulates the TPU kernel on CPU; above this dense-FLOP size
# measuring it is pointless (minutes per call) so the tuner skips it.
_INTERPRET_FLOP_LIMIT = 2 ** 26


def _register_builtin_variants():
    # Imported lazily so `repro.tune.registry` never forces Pallas at import.
    from repro.kernels import ref as kref
    from repro.kernels.demm_spmm import demm_spmm_pallas, demm_xwT_pallas

    def xwT_ref_call(x, values, indices, cfg, w_shape, **_):
        return kref.xwT_ref(x, values, indices, cfg, w_shape)

    def xwT_pallas_call(x, values, indices, cfg, w_shape, *,
                        interpret, block_b=128, block_o=128, **_):
        return demm_xwT_pallas(x, values, indices, cfg, block_b=block_b,
                               block_o=block_o, interpret=interpret)

    def xwT_tiles(p: Problem):
        return {
            "block_b": _pow2_candidates(p.rows, 8, 512),
            "block_o": _pow2_candidates(p.out, 8, 512),
        }

    def xwT_defaults(p: Problem):
        return {"block_b": min(128, p.rows), "block_o": min(128, p.out)}

    register_variant(KernelVariant(
        op="xwT", name="reference", call=xwT_ref_call,
        param_space=lambda p: {}, default_params=lambda p: {},
        supported=lambda p: True,
        description="pure-jnp decompress+matmul (XLA path)"))
    register_variant(KernelVariant(
        op="xwT", name="pallas",
        call=lambda *a, **kw: xwT_pallas_call(*a, interpret=False, **kw),
        param_space=xwT_tiles, default_params=xwT_defaults,
        supported=lambda p: p.platform == "tpu",
        description="fused Pallas TPU kernel"))
    register_variant(KernelVariant(
        op="xwT", name="pallas_interpret",
        call=lambda *a, **kw: xwT_pallas_call(*a, interpret=True, **kw),
        param_space=xwT_tiles, default_params=xwT_defaults,
        supported=lambda p: p.dense_flops <= _INTERPRET_FLOP_LIMIT,
        description="Pallas kernel in interpret mode (CPU checks)"))

    def spmm_ref_call(values, indices, b, cfg, a_shape, **_):
        return kref.spmm_ref(values, indices, b, cfg, a_shape)

    def spmm_pallas_call(values, indices, b, cfg, a_shape, *,
                         interpret, block_r=128, block_c=256, **_):
        return demm_spmm_pallas(values, indices, b, cfg, block_r=block_r,
                                block_c=block_c, interpret=interpret)

    def spmm_tiles(p: Problem):
        return {
            "block_r": _pow2_candidates(p.out, 8, 512),
            "block_c": _pow2_candidates(p.rows, 8, 512),
        }

    def spmm_defaults(p: Problem):
        return {"block_r": min(128, p.out), "block_c": min(256, p.rows)}

    register_variant(KernelVariant(
        op="spmm", name="reference", call=spmm_ref_call,
        param_space=lambda p: {}, default_params=lambda p: {},
        supported=lambda p: True,
        description="pure-jnp decompress+matmul (XLA path)"))
    register_variant(KernelVariant(
        op="spmm", name="pallas",
        call=lambda *a, **kw: spmm_pallas_call(*a, interpret=False, **kw),
        param_space=spmm_tiles, default_params=spmm_defaults,
        supported=lambda p: p.platform == "tpu",
        description="fused Pallas TPU kernel"))
    register_variant(KernelVariant(
        op="spmm", name="pallas_interpret",
        call=lambda *a, **kw: spmm_pallas_call(*a, interpret=True, **kw),
        param_space=spmm_tiles, default_params=spmm_defaults,
        supported=lambda p: p.dense_flops <= _INTERPRET_FLOP_LIMIT,
        description="Pallas kernel in interpret mode (CPU checks)"))

    def spmm_block_call(values, indices, b, cfg, a_shape, *,
                        block_r=128, cd_block=256, **_):
        # Host-side repack into the two-level block-sparse format: only
        # callable on concrete arrays (measure_only), never under jit.
        import numpy as np

        from repro.core.sparsity import unpack
        from repro.kernels.demm_block_spmm import (
            demm_block_spmm_pallas, pack_block_sparse)

        r = int(a_shape[0])
        block_r = min(block_r, r)
        if r % block_r:
            raise ValueError(f"block_spmm needs r % block_r == 0, got "
                             f"{r} % {block_r}")
        dense = np.asarray(unpack(values, indices, cfg, tuple(a_shape)))
        ag, vals, idxs, _ = pack_block_sparse(dense, cfg, block_r=block_r)
        interp = current_platform() != "tpu"
        return demm_block_spmm_pallas(
            jax.numpy.asarray(ag), jax.numpy.asarray(vals),
            jax.numpy.asarray(idxs), b, cfg, r=r, cd_block=cd_block,
            interpret=interp)

    register_variant(KernelVariant(
        op="spmm", name="block_spmm", call=spmm_block_call,
        param_space=lambda p: {
            "block_r": tuple(c for c in _pow2_candidates(p.out, 8, 256)
                             if p.out % c == 0),
            "cd_block": tuple(c for c in _pow2_candidates(p.rows, 8, 256)
                              if p.rows % c == 0),
        },
        default_params=lambda p: {
            "block_r": max((c for c in _pow2_candidates(p.out, 8, 128)
                            if p.out % c == 0), default=p.out),
            "cd_block": max((c for c in _pow2_candidates(p.rows, 8, 256)
                             if p.rows % c == 0), default=p.rows),
        },
        supported=lambda p: (p.platform == "tpu"
                             or p.dense_flops <= _INTERPRET_FLOP_LIMIT),
        measure_only=True,
        description="scalar-prefetch block-gather kernel (host repack of the "
                    "flat spmm packing; ahead-of-time conversion dispatches "
                    "through the xwT_block op instead)"))

    # ---- xwT_block: the two-level AOT block layout (serving orientation) --
    # Operands come pre-packed by core.sparsity.pack_block, so both variants
    # are dispatchable from inside a jit trace (no measure_only flag).

    def xwT_block_ref_call(x, values, indices, active_groups, cfg, w_shape,
                           **_):
        o, _k = w_shape
        return kref.block_spmm_ref(active_groups, values, indices, x.T, cfg,
                                   int(o)).T

    def xwT_block_pallas_call(x, values, indices, active_groups, cfg,
                              w_shape, *, interpret, cd_block=256, **_):
        from repro.kernels.demm_block_spmm import demm_block_spmm_pallas

        o, _k = w_shape
        b = x.T                                   # (K, B): paper orientation
        cd = b.shape[1]
        cd_block = min(cd_block, cd)
        if cd % cd_block:
            cd_block = cd                         # ragged batch: one tile
        return demm_block_spmm_pallas(active_groups, values, indices, b, cfg,
                                      r=int(o), cd_block=int(cd_block),
                                      interpret=interpret).T

    def xwT_block_tiles(p: Problem):
        return {"cd_block": tuple(
            c for c in _pow2_candidates(p.rows, 8, 256) if p.rows % c == 0
        ) or (p.rows,)}

    def xwT_block_defaults(p: Problem):
        return {"cd_block": max(
            (c for c in _pow2_candidates(p.rows, 8, 256) if p.rows % c == 0),
            default=p.rows)}

    register_variant(KernelVariant(
        op="xwT_block", name="reference", call=xwT_block_ref_call,
        param_space=lambda p: {}, default_params=lambda p: {},
        supported=lambda p: True,
        description="pure-jnp two-level scatter-add + matmul (XLA path)"))
    register_variant(KernelVariant(
        op="xwT_block", name="block_spmm",
        call=lambda *a, **kw: xwT_block_pallas_call(
            *a, interpret=current_platform() != "tpu", **kw),
        param_space=xwT_block_tiles, default_params=xwT_block_defaults,
        supported=lambda p: (p.platform == "tpu"
                             or p.dense_flops <= _INTERPRET_FLOP_LIMIT),
        description="scalar-prefetch block-gather Pallas kernel over the "
                    "ahead-of-time two-level packing (interpret on CPU)"))

    # ---- int8 quantized ops (repro.quant): w8a16 dequant-in-register ------
    # Variant names mirror the float ops so heuristic_default's platform
    # preferences ("pallas" / "block_spmm" on TPU) apply unchanged.
    from repro.kernels.demm_q8 import (demm_block_spmm_q8_pallas,
                                       demm_xwT_q8_pallas)

    def xwT_q8_ref_call(x, values, indices, scales, cfg, w_shape, **_):
        return kref.xwT_q8_ref(x, values, indices, scales, cfg, w_shape)

    def xwT_q8_pallas_call(x, values, indices, scales, cfg, w_shape, *,
                           interpret, block_b=128, block_o=128, **_):
        return demm_xwT_q8_pallas(x, values, indices, scales, cfg,
                                  block_b=block_b, block_o=block_o,
                                  interpret=interpret)

    register_variant(KernelVariant(
        op="xwT_q8", name="reference", call=xwT_q8_ref_call,
        param_space=lambda p: {}, default_params=lambda p: {},
        supported=lambda p: True,
        description="pure-jnp dequantize + decompress + matmul (XLA path)"))
    register_variant(KernelVariant(
        op="xwT_q8", name="pallas",
        call=lambda *a, **kw: xwT_q8_pallas_call(*a, interpret=False, **kw),
        param_space=xwT_tiles, default_params=xwT_defaults,
        supported=lambda p: p.platform == "tpu",
        description="fused Pallas TPU kernel, int8 weights dequantized "
                    "in-register (w8a16)"))
    register_variant(KernelVariant(
        op="xwT_q8", name="pallas_interpret",
        call=lambda *a, **kw: xwT_q8_pallas_call(*a, interpret=True, **kw),
        param_space=xwT_tiles, default_params=xwT_defaults,
        supported=lambda p: p.dense_flops <= _INTERPRET_FLOP_LIMIT,
        description="int8 Pallas kernel in interpret mode (CPU checks)"))

    def xwT_block_q8_ref_call(x, values, indices, active_groups, scales,
                              cfg, w_shape, **_):
        o, _k = w_shape
        return kref.block_spmm_q8_ref(active_groups, values, indices,
                                      scales, x.T, cfg, int(o)).T

    def xwT_block_q8_pallas_call(x, values, indices, active_groups, scales,
                                 cfg, w_shape, *, interpret, cd_block=256,
                                 **_):
        o, _k = w_shape
        b = x.T                                   # (K, B): paper orientation
        cd = b.shape[1]
        cd_block = min(cd_block, cd)
        if cd % cd_block:
            cd_block = cd                         # ragged batch: one tile
        return demm_block_spmm_q8_pallas(active_groups, values, indices,
                                         scales, b, cfg, r=int(o),
                                         cd_block=int(cd_block),
                                         interpret=interpret).T

    register_variant(KernelVariant(
        op="xwT_block_q8", name="reference", call=xwT_block_q8_ref_call,
        param_space=lambda p: {}, default_params=lambda p: {},
        supported=lambda p: True,
        description="pure-jnp dequantize + two-level scatter-add + matmul"))
    register_variant(KernelVariant(
        op="xwT_block_q8", name="block_spmm",
        call=lambda *a, **kw: xwT_block_q8_pallas_call(
            *a, interpret=current_platform() != "tpu", **kw),
        param_space=xwT_block_tiles, default_params=xwT_block_defaults,
        supported=lambda p: (p.platform == "tpu"
                             or p.dense_flops <= _INTERPRET_FLOP_LIMIT),
        description="scalar-prefetch block-gather Pallas kernel over the "
                    "quantized two-level packing (w8a16; interpret on CPU)"))


_register_builtin_variants()
