"""Persistent tuning cache + heuristic defaults.

Tuning results are keyed by the full static problem description —
``(op, rows, out, k, dtype, n:m:k_reconfig, platform)`` — and stored

  * in-memory (process-lifetime memoization, zero-cost on the dispatch path),
  * on disk as JSON (survives processes; a serving job starts with the tile
    configs its benchmark run measured).

When no measurement exists for a key the cache answers with the registry's
heuristic default for the best-supported variant, so ``backend="auto"`` is
always resolvable — tuning only ever *improves* the choice.

The JSON file carries a schema version; a version bump (or any key-scheme
change) invalidates stale entries instead of misreading them.

Observability (``repro.obs``, DESIGN.md §12): every :meth:`TuneCache.resolve`
increments ``tune_cache_hits_total`` / ``tune_cache_misses_total`` (labeled
by op) on the default registry, and :meth:`TuneCache.load` counts file loads
and entries — so a serving run's ``--metrics-out`` snapshot shows exactly
how its ``backend="auto"`` decisions were sourced.  Saves are atomic via a
*uniquely named* temp file + ``os.replace``, so concurrent bench/CI runs
sharing one cache path cannot interleave partial writes.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
from typing import Dict, Optional, Tuple

from repro.tune.registry import Problem, variants_for


def _counter(name: str, help_text: str = "", **labels):
    from repro import obs

    return obs.metrics().counter(name, help=help_text, **labels)

SCHEMA_VERSION = 1

_ENV_PATH = "REPRO_TUNE_CACHE"
_DEFAULT_PATH = os.path.join("results", "tune_cache.json")


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One resolved dispatch decision for a Problem."""

    backend: str
    params: Dict[str, int]
    measured_us: Optional[float] = None   # None => heuristic, not measured
    source: str = "heuristic"             # "heuristic" | "tuned" | "cache"

    def to_json(self) -> dict:
        return {"backend": self.backend, "params": dict(self.params),
                "measured_us": self.measured_us, "source": self.source}

    @classmethod
    def from_json(cls, d: dict) -> "TunedConfig":
        return cls(backend=d["backend"], params=dict(d.get("params", {})),
                   measured_us=d.get("measured_us"),
                   source=d.get("source", "cache"))


def problem_key(p: Problem) -> str:
    n, m, kr = p.sparsity
    key = (f"{p.op}|r{p.rows}|o{p.out}|k{p.k}|{p.dtype}"
           f"|{n}:{m}:{kr}|{p.platform}")
    if p.block_r:
        # block geometry is fixed at pack time, so two packings of the same
        # weight are distinct dispatch problems (pre-block keys unchanged).
        key += f"|b{p.block_r}x{p.a_max}"
    if p.shards > 1:
        # Shard-local problem of a renumbered row-parallel weight (k and
        # a_max above are already the per-shard values): keep TP slices
        # from aliasing a same-shape single-device entry, whose measured
        # tile choice ran without the collective (single-device keys
        # unchanged).
        key += f"|s{p.shards}"
    return key


def heuristic_default(p: Problem) -> TunedConfig:
    """Best unmeasured guess: a real Pallas kernel with MXU-aligned tiles on
    TPU (the fused ``pallas`` variant, or ``block_spmm`` for the two-level
    block layout), the XLA reference path everywhere else (interpret mode is
    a debug backend and never a heuristic winner)."""
    preferred = ("pallas", "block_spmm") if p.platform == "tpu" else ()
    for name in preferred + ("reference",):
        for v in variants_for(p.op, p):
            if v.name == name:
                return TunedConfig(name, v.default_params(p))
    raise RuntimeError(f"no supported variant for {p}")


class TuneCache:
    """Two-level (memory + JSON file) cache of :class:`TunedConfig`."""

    def __init__(self, path: Optional[str] = None):
        self.path = path if path is not None else os.environ.get(
            _ENV_PATH, _DEFAULT_PATH)
        self._mem: Dict[str, TunedConfig] = {}
        self._lock = threading.Lock()
        self._loaded = False

    # -- persistence --------------------------------------------------------

    def load(self) -> int:
        """Merge on-disk entries into memory; returns #entries loaded."""
        with self._lock:
            self._loaded = True
            if not self.path or not os.path.exists(self.path):
                return 0
            try:
                with open(self.path) as f:
                    blob = json.load(f)
            except (OSError, json.JSONDecodeError):
                return 0
            if blob.get("version") != SCHEMA_VERSION:
                return 0
            n = 0
            for key, entry in blob.get("entries", {}).items():
                try:
                    self._mem.setdefault(key, TunedConfig.from_json(entry))
                    n += 1
                except (KeyError, TypeError):
                    continue
        _counter("tune_cache_loads_total",
                 "cache files loaded from disk").inc()
        _counter("tune_cache_entries_loaded_total",
                 "tuning entries merged from disk").inc(n)
        return n

    def save(self):
        with self._lock:
            if not self.path:
                return
            d = os.path.dirname(self.path) or "."
            os.makedirs(d, exist_ok=True)
            blob = {"version": SCHEMA_VERSION,
                    "entries": {k: v.to_json() for k, v in self._mem.items()}}
            # Unique temp name + atomic rename: concurrent bench/CI runs
            # saving the same cache path race only on *which complete file
            # wins*, never on partial writes (a shared ".tmp" suffix would
            # let two writers interleave into one temp file).
            fd, tmp = tempfile.mkstemp(
                dir=d, prefix=os.path.basename(self.path) + ".", suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(blob, f, indent=2, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise

    # -- lookup / update ----------------------------------------------------

    def _ensure_loaded(self):
        if not self._loaded:
            self.load()

    def get(self, p: Problem) -> Optional[TunedConfig]:
        self._ensure_loaded()
        with self._lock:
            return self._mem.get(problem_key(p))

    def put(self, p: Problem, cfg: TunedConfig, *, persist: bool = False):
        self._ensure_loaded()
        with self._lock:
            self._mem[problem_key(p)] = cfg
        if persist:
            self.save()

    def invalidate(self, p: Problem):
        self._ensure_loaded()
        with self._lock:
            self._mem.pop(problem_key(p), None)

    def clear(self):
        with self._lock:
            self._mem.clear()

    def resolve(self, p: Problem) -> TunedConfig:
        """Cache hit or heuristic default — never measures, safe to call at
        jit-trace time (only static shape information is consulted)."""
        # register both families up front so a snapshot always shows the
        # hit/miss pair even when one side is still zero
        hits = _counter("tune_cache_hits_total",
                        "resolve() served from cache (incl. memoized "
                        "heuristics)", op=p.op)
        misses = _counter("tune_cache_misses_total",
                          "resolve() fell back to a fresh heuristic default",
                          op=p.op)
        from repro import obs

        hit = self.get(p)
        if hit is not None:
            hits.inc()
            # resolve() runs at jit-trace time, i.e. inside the dispatching
            # request's obs context — the event inherits its trace_id
            obs.metrics().trace.event("tune_cache_resolve", op=p.op,
                                      outcome="hit", backend=hit.backend)
            return hit
        misses.inc()
        cfg = heuristic_default(p)
        obs.metrics().trace.event("tune_cache_resolve", op=p.op,
                                  outcome="miss", backend=cfg.backend)
        # memoize the heuristic so repeated traces skip the registry walk,
        # but never persist it: a later autotune run should win.
        with self._lock:
            self._mem.setdefault(problem_key(p), cfg)
        return cfg

    def __len__(self):
        with self._lock:
            return len(self._mem)


_default_cache: Optional[TuneCache] = None
_default_lock = threading.Lock()


def default_cache() -> TuneCache:
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = TuneCache()
        return _default_cache


def set_default_cache(cache: Optional[TuneCache]):
    """Swap the process-wide cache (tests; custom cache paths)."""
    global _default_cache
    with _default_lock:
        _default_cache = cache
