"""``repro.tune`` — kernel registry, autotuner, and dispatch cache.

The software analogue of the paper's DeMM(N, M, C, k) reconfiguration: the
engine wins by matching its datapath shape to the sparsity pattern, and the
Pallas kernels win by matching their tile shapes (``block_r``/``block_c``/
``block_b``) and backend to the (shape, dtype, N:M pattern, platform)
instance.  This package owns that choice:

  * :mod:`repro.tune.registry`  — registered kernel variants + param spaces.
  * :mod:`repro.tune.autotune`  — enumerate → VMEM/perfmodel prune → measure.
  * :mod:`repro.tune.cache`     — JSON-persistent (op, shapes, dtype,
    pattern, platform) → (backend, tiles) cache with heuristic fallback.

``kernels/ops.py`` resolves ``backend="auto"`` through
:func:`resolve_xwT` / :func:`resolve_spmm`: a pure cache/heuristic lookup on
static shapes, safe at jit-trace time.  Measurement happens only in explicit
:func:`autotune_xwT` / :func:`autotune_spmm` calls (see
``benchmarks/kernel_bench.py --autotune`` and ``launch/serve.py
--autotune``), whose results persist for later processes.
"""

from __future__ import annotations

from repro.core.sparsity import SparsityConfig
from repro.tune.autotune import (
    DEFAULT_VMEM_BUDGET,
    TuneResult,
    autotune_spmm,
    autotune_xwT,
    autotune_xwT_block,
    autotune_xwT_q8,
    enumerate_candidates,
    estimate_cycles,
    measure,
    prune_candidates,
    vmem_bytes,
)
from repro.tune.cache import (
    TuneCache,
    TunedConfig,
    default_cache,
    heuristic_default,
    problem_key,
    set_default_cache,
)
from repro.tune.registry import (
    KernelVariant,
    Problem,
    backend_names,
    current_platform,
    get_variant,
    register_variant,
    variants_for,
)

__all__ = [
    "DEFAULT_VMEM_BUDGET", "KernelVariant", "Problem", "TuneCache",
    "TuneResult", "TunedConfig", "autotune_spmm", "autotune_xwT",
    "autotune_xwT_block", "autotune_xwT_q8", "backend_names",
    "current_platform", "default_cache", "enumerate_candidates",
    "estimate_cycles", "get_variant", "heuristic_default", "measure",
    "problem_key", "prune_candidates", "register_variant", "resolve_spmm",
    "resolve_xwT", "resolve_xwT_block", "resolve_xwT_q8",
    "set_default_cache", "variants_for", "vmem_bytes",
]


def resolve_xwT(x_shape, w_shape, cfg: SparsityConfig, dtype,
                shards: int = 1) -> TunedConfig:
    """Static (backend, params) choice for ``backend="auto"`` xwT dispatch.

    Never measures: tuning-cache hit or heuristic default.  Shapes may come
    from tracers — only static metadata is consulted.  ``shards`` > 1 marks
    the shard-local problem of a renumbered row-parallel weight (distinct
    cache key from the same-shape global problem).
    """
    p = Problem.for_xwT(x_shape, w_shape, cfg, dtype, shards=shards)
    return default_cache().resolve(p)


def resolve_xwT_q8(x_shape, w_shape, cfg: SparsityConfig,
                   dtype, shards: int = 1) -> TunedConfig:
    """Static (backend, params) choice for ``backend="auto"`` dispatch of an
    int8-quantized xwT weight — its own ``xwT_q8`` cache key, so float and
    quantized tunings coexist.  Never measures."""
    p = Problem.for_xwT(x_shape, w_shape, cfg, dtype, quantized=True,
                        shards=shards)
    return default_cache().resolve(p)


def resolve_spmm(a_shape, b_shape, cfg: SparsityConfig, dtype) -> TunedConfig:
    """Static (backend, params) choice for ``backend="auto"`` spmm dispatch."""
    p = Problem.for_spmm(a_shape, b_shape, cfg, dtype)
    return default_cache().resolve(p)


def resolve_xwT_block(x_shape, pw, dtype) -> TunedConfig:
    """Static (backend, params) choice for ``backend="auto"`` dispatch of a
    block-layout :class:`~repro.core.sparsity.PackedWeight` — keyed by the
    full problem including the pack-time block geometry.  Never measures."""
    p = Problem.for_xwT_block(x_shape, pw, dtype)
    return default_cache().resolve(p)


def autotune_packed_tree(params, batch: int, dtype=None, *,
                         persist: bool = True, **tune_kw) -> dict:
    """Pre-tune every distinct packed-weight matmul shape in a param pytree.

    Walks ``params`` for :class:`~repro.core.sparsity.PackedWeight` nodes
    (as produced by ``launch.pack_tree``) and runs :func:`autotune_xwT` /
    :func:`autotune_xwT_q8` (or :func:`autotune_xwT_block`, which covers
    both float and quantized block nodes) once per distinct
    (O, K, pattern[, block geometry], qdtype) — all read from the type's
    static aux data, k-reconfiguration included — with a dummy activation
    batch of ``batch`` rows, so a subsequent jit trace with
    ``backend="auto"`` resolves every layer from measured entries instead
    of heuristics.  Returns {problem_key: TuneResult}.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core.sparsity import LAYOUT_BLOCK, PackedWeight, shard_slice

    dtype = dtype or jnp.float32
    seen = {}

    def tune_one(pw: PackedWeight):
        if pw.shard_axis is not None:
            # Shard-stacked row-parallel weight: what dispatches inside the
            # shard_map island is the shard-local problem (every slice has
            # identical static geometry), so tune slice 0 — its key carries
            # the shard-local k/a_max plus the |sN shard marker.
            pw = shard_slice(pw, 0)
        o, k = pw.dense_shape
        if pw.layout == LAYOUT_BLOCK:
            stack = pw.stack_dims
            if stack:   # layer-stacked: tune one slice (scan applies 2-D)
                first = (0,) * len(stack)
                pw = pw.replace(
                    values=pw.values[first], indices=pw.indices[first],
                    active_groups=pw.active_groups[first],
                    scales=(pw.scales[first] if pw.scales is not None
                            else None))
            p = Problem.for_xwT_block((batch, k), pw, dtype)
            key = problem_key(p)
            if key in seen:
                return
            x = jnp.asarray(
                np.random.default_rng(0).standard_normal((batch, k)), dtype)
            seen[key] = autotune_xwT_block(x, pw, persist=persist, **tune_kw)
            return
        quant = pw.qdtype is not None
        vals, idxs, scls = pw.values, pw.indices, pw.scales
        if vals.ndim > 3:   # layer-stacked: tune one slice
            vals = vals.reshape(-1, *vals.shape[-2:])[:o]
            idxs = idxs.reshape(-1, *idxs.shape[-2:])[:o]
            if quant:
                scls = scls.reshape(-1)[:o]
        p = Problem.for_xwT((batch, k), (o, k), pw.cfg, dtype,
                            quantized=quant, shards=pw.shards)
        key = problem_key(p)
        if key in seen:
            return
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal((batch, k)), dtype)
        if quant:
            seen[key] = autotune_xwT_q8(x, vals, idxs, scls, pw.cfg, (o, k),
                                        persist=persist, **tune_kw)
        else:
            seen[key] = autotune_xwT(x, vals, idxs, pw.cfg, (o, k),
                                     persist=persist, **tune_kw)

    def visit(node):
        if isinstance(node, PackedWeight):
            tune_one(node)
        elif isinstance(node, dict):
            if "values" in node and "shape" in node:
                raise ValueError(
                    "legacy packed {values, indices, shape} dicts are no "
                    "longer supported; pack with launch.pack_tree to get "
                    "PackedWeight nodes")
            for v in node.values():
                visit(v)

    visit(params)
    return seen
