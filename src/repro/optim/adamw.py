"""AdamW with ZeRO-1-ready state layout, grad clipping, and schedules.

Functional (optax-style) but self-contained.  Optimizer moments are stored
in fp32 regardless of param dtype.  Under distribution, the moment pytrees
get the ZeRO-1 shardings from ``partitioning.opt_state_specs`` — the update
then computes on (data-axis) shards and SPMD all-gathers fresh params.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict
    compression: Optional[dict] = None  # error-feedback residuals


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # gradient compression (see optim/compression.py)
    compression: Optional[str] = None     # None | "topk" | "int8"
    topk_fraction: float = 0.05


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _is_float(x):
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


def init(cfg: AdamWConfig, params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32) if _is_float(p) else None,
        params)
    comp = None
    if cfg.compression == "topk":
        comp = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32) if _is_float(p) else None,
            params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros,
                      compression=comp)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree) if _is_float(g)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    from repro.optim import compression as comp_mod

    step = state.step + 1
    comp_state = state.compression
    if cfg.compression == "topk":
        grads, comp_state = comp_mod.topk_with_error_feedback(
            grads, comp_state, cfg.topk_fraction)
    elif cfg.compression == "int8":
        grads = comp_mod.int8_roundtrip(grads)

    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(
            lambda g: g * scale if _is_float(g) else g, grads)

    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        if not _is_float(p) or g is None:
            return p, m, v
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v,
                             compression=comp_state), \
        {"grad_norm": gnorm, "lr": lr}
