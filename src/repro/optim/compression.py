"""Gradient compression for data-parallel reduction.

Two schemes:

* ``topk_with_error_feedback`` — keep the top-|g| fraction per tensor, add
  the dropped mass to a residual that is re-injected next step (error
  feedback keeps the scheme convergent).  Applied before the DP reduction,
  it cuts all-reduce volume by ~1/fraction.

* ``int8_roundtrip`` / ``compressed_psum_int8`` — symmetric per-tensor int8
  quantization.  ``compressed_psum_int8`` is the shard_map building block:
  quantize locally, all-reduce the int8 payload (as int32 accumulators),
  dequantize — 4x volume reduction vs fp32 with one scale exchange.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_float(x):
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


def topk_with_error_feedback(grads, residuals, fraction: float):
    """Per-tensor magnitude top-k with error feedback."""

    def one(g, r):
        if not _is_float(g):
            return g, r
        g32 = g.astype(jnp.float32) + (r if r is not None else 0.0)
        flat = g32.reshape(-1)
        k = max(1, int(fraction * flat.shape[0]))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(g32) >= thresh
        sent = jnp.where(mask, g32, 0.0)
        return sent.astype(g.dtype), g32 - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals) if residuals is not None else \
        [None] * len(flat_g)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def int8_quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_roundtrip(grads):
    """Quantize-dequantize every tensor (models the numerics of a compressed
    all-reduce on a single host)."""

    def one(g):
        if not _is_float(g):
            return g
        q, scale = int8_quantize(g.astype(jnp.float32))
        return (q.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree.map(one, grads)


def compressed_psum_int8(x, axis_name: str):
    """int8-compressed psum for use inside shard_map: each participant
    quantizes locally; the int8 payloads are summed in int32 (exact), and
    the shared scale is the max over participants."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale
