"""``repro.quant`` — int8 symmetric quantization of packed sparse weights.

DeMM targets pruned models on mobile-class accelerators, where structured
sparsity is almost always deployed together with low-precision arithmetic
(S2TA shows the two wins are multiplicative).  This package makes
quantization a first-class property of the packed format: a quantized
:class:`~repro.core.sparsity.PackedWeight` carries int8 ``values``, a traced
``scales`` child, and a static ``qdtype`` aux tag, and every consumer of the
float path — kernels (``xwT_q8`` / ``xwT_block_q8`` registry ops), the
autotuner, structural sharding, checkpointing, and the serving CLI — knows
the quantized form.

Entry points:

* :func:`quantize_packed` / :func:`quantize_tree` — quantize one packed
  weight / every packed node of a params pytree (data-free amax calibration
  by default).
* :func:`activation_calibration` — an optional observer built from sample
  activations that picks per-row clip ratios minimizing a diagonal
  approximation of the output error.
* :func:`dequantize_packed` — back to the float packed form (testing,
  fine-tuning export).
"""

from __future__ import annotations

from repro.quant.quantize import (
    CLIP_GRID,
    QMAX,
    activation_calibration,
    amax_scales,
    dequantize_packed,
    quantize_packed,
    quantize_tree,
)
from repro.core.sparsity import QDTYPE_INT8, QDTYPES

__all__ = [
    "CLIP_GRID", "QDTYPES", "QDTYPE_INT8", "QMAX", "activation_calibration",
    "amax_scales", "dequantize_packed", "quantize_packed", "quantize_tree",
]
