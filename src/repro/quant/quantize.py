"""Symmetric int8 quantization of packed relaxed-N:M sparse weights.

Granularity follows the packed layout (DESIGN.md §10):

* ``xwT``    — default one scale per output row: ``scales (*stack, O)``.
  The row is the reduction unit of the serving matmul ``y = x @ Wᵀ``, so a
  per-row scale folds into the kernel as a single multiply on the (rows, M)
  scatter matrix.  ``granularity="per_group"`` refines this to one scale
  per (row, M-group): ``scales (*stack, O, G)`` — each group's Ne values
  share one exponent, which matters exactly when a row mixes large and
  small groups (the kernel cost is unchanged: the scatter tile of grid step
  ``g`` scales by column ``g`` of the scales operand instead of column 0).
* ``block``  — one scale per (row-block, active-group slot, row):
  ``scales (*stack, RB, A_max, block_r)``.  Per-group scales are finer than
  per-row (each group's Ne values share one exponent) and line up with the
  block kernel's (block_r, Ne) value tiles.

Quantization is symmetric round-to-nearest: ``q = clip(round(v / s), ±127)``
with ``s = amax / 127`` (data-free) or an observer-provided scale.  Padded
slots (value 0) quantize to 0 and keep contributing nothing; a genuine
weight that rounds to 0 merely drops below the quantization floor.

The optional activation-calibration hook searches a small clip grid per
scale unit, weighting each packed slot's quantization error by the RMS of
the calibration activations at the slot's *global* column (the diagonal /
OBS approximation of the output MSE).  It never needs labels or a backward
pass — a handful of activation rows from the serving distribution is
enough.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.sparsity import (
    LAYOUT_BLOCK,
    QDTYPE_INT8,
    QDTYPES,
    PackedWeight,
    expand_scales,
)

QMAX = 127.0
# Clip ratios searched by the activation observer (1.0 = plain amax).
CLIP_GRID = (1.0, 0.95, 0.9, 0.85, 0.8)

_EPS = 1e-12

GRANULARITIES = ("per_row", "per_group")


def _check_granularity(pw: PackedWeight, granularity: str):
    if granularity not in GRANULARITIES:
        raise ValueError(f"unknown granularity {granularity!r}; expected "
                         f"one of {GRANULARITIES}")
    if granularity == "per_group" and pw.layout == LAYOUT_BLOCK:
        raise ValueError(
            "granularity only applies to the xwT layout; block scales are "
            "already per (row-block, group, row)")


def _reduce_axes(pw: PackedWeight, granularity: str = "per_row"):
    """Packed axes reduced away by one scale unit."""
    if pw.layout == LAYOUT_BLOCK or granularity == "per_group":
        return (-1,)
    return (-2, -1)


def amax_scales(pw: PackedWeight,
                granularity: str = "per_row") -> jax.Array:
    """Data-free calibration: ``amax / 127`` per scale unit (float32).

    Zero rows (fully padded slots) get a scale of ``1/127`` so the divide
    stays finite; their values are all 0 and quantize to 0 regardless.
    """
    _check_granularity(pw, granularity)
    amax = jnp.max(jnp.abs(pw.values.astype(jnp.float32)),
                   axis=_reduce_axes(pw, granularity))
    return jnp.where(amax > _EPS, amax, 1.0) / QMAX


def _quantize_values(pw: PackedWeight, scales: jax.Array) -> jax.Array:
    q = jnp.round(pw.values.astype(jnp.float32)
                  / expand_scales(scales, pw.values))
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)


def quantize_packed(pw: PackedWeight, qdtype: str = QDTYPE_INT8, *,
                    observer: Optional[Callable] = None,
                    granularity: str = "per_row") -> PackedWeight:
    """Quantize a float packed weight to ``qdtype`` (int8 today).

    ``observer`` maps the float ``PackedWeight`` to per-unit scales (see
    :func:`activation_calibration`); by default the cheap data-free
    :func:`amax_scales` pass is used.  ``granularity`` picks the scale unit
    for the xwT layout — ``per_row`` (``scales (*stack, O)``, the default)
    or ``per_group`` (``(*stack, O, G)``); an observer's output shape wins
    over ``granularity``.  Returns a new ``PackedWeight`` with int8
    ``values``, a float32 ``scales`` child, and the ``qdtype`` aux tag;
    ``indices``/``active_groups`` and all static aux are shared unchanged.
    """
    if qdtype not in QDTYPES:
        raise ValueError(f"unknown qdtype {qdtype!r}; expected {QDTYPES}")
    if pw.qdtype is not None:
        raise ValueError(f"weight is already quantized ({pw.qdtype!r}); "
                         "dequantize_packed first to re-calibrate")
    _check_granularity(pw, granularity)
    scales = (observer(pw) if observer is not None
              else amax_scales(pw, granularity)).astype(jnp.float32)
    return pw.replace(values=_quantize_values(pw, scales), scales=scales,
                      qdtype=qdtype)


def dequantize_packed(pw: PackedWeight) -> PackedWeight:
    """Back to the float packed form (float32 values, no scales child)."""
    if pw.qdtype is None:
        return pw
    return pw.replace(values=pw.dequantized_values(), scales=None,
                      qdtype=None)


def quantize_tree(params, qdtype: str = QDTYPE_INT8, *,
                  observer: Optional[Callable] = None,
                  granularity: str = "per_row"):
    """Quantize every :class:`PackedWeight` node of a params pytree
    (as produced by ``launch.pack_tree``); everything else passes through.
    Already-quantized nodes are left untouched.  ``granularity`` applies to
    xwT-layout nodes (block nodes are inherently per-group)."""
    if isinstance(params, PackedWeight):
        if params.qdtype is not None:
            return params
        gran = ("per_row" if params.layout == LAYOUT_BLOCK else granularity)
        return quantize_packed(params, qdtype, observer=observer,
                               granularity=gran)
    if isinstance(params, dict):
        return {k: quantize_tree(v, qdtype, observer=observer,
                                 granularity=granularity)
                for k, v in params.items()}
    return params


# ---------------------------------------------------------------------------
# Activation calibration
# ---------------------------------------------------------------------------

def _slot_columns(pw: PackedWeight) -> jax.Array:
    """Global contraction-dim column of every packed slot (same shape as
    ``indices``): ``group_id * M + local_index``."""
    m = pw.cfg.m
    if pw.layout == LAYOUT_BLOCK:
        # active_groups (*stack, RB, A_max) carries the group ids.
        return (pw.active_groups[..., None, None] * m
                + pw.indices).astype(jnp.int32)
    g = pw.groups
    gids = jnp.arange(g, dtype=jnp.int32)[:, None]        # (G, 1)
    return (gids * m + pw.indices).astype(jnp.int32)


def activation_calibration(x: jax.Array,
                           grid: Sequence[float] = CLIP_GRID,
                           granularity: str = "per_row") -> Callable:
    """Observer factory: pick per-unit clip ratios from sample activations.

    ``x`` is a small ``(B, K)`` batch drawn from the serving distribution.
    For every scale unit the observer evaluates each clip ratio ``c`` in
    ``grid`` on the weighted quantization error

        err(c) = Σ_slots ( (deq_c(v) - v) · act_rms[column(slot)] )²

    — the diagonal approximation of the output MSE ``‖x (W - Ŵ)ᵀ‖²`` — and
    keeps the best ``c * amax_scale``.  Clipping below amax trades a few
    saturated outliers for a finer grid on the bulk, which wins exactly when
    the activation mass says the bulk matters more.
    """
    act_sq = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=0)   # (K,)

    def observer(pw: PackedWeight) -> jax.Array:
        base = amax_scales(pw, granularity)
        axes = _reduce_axes(pw, granularity)
        v = pw.values.astype(jnp.float32)
        w = act_sq[_slot_columns(pw)]                  # per-slot weight
        errs = []
        for c in grid:
            s = expand_scales(base * c, pw.values)
            deq = jnp.clip(jnp.round(v / s), -QMAX, QMAX) * s
            errs.append(jnp.sum(jnp.square(deq - v) * w, axis=axes))
        errs = jnp.stack(errs)                         # (|grid|, *units)
        best = jnp.argmin(errs, axis=0)
        ratios = jnp.asarray(grid, jnp.float32)[best]
        return base * ratios

    return observer
