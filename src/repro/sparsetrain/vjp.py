"""custom_vjp coverage for the packed ops beyond ``xwT``.

``kernels/ops.py`` has always carried a custom_vjp for the row-packed
``xwT`` op (dL/dvalues = gather of dyᵀx at the packed coordinates); the
``block`` and quantized ops were serving-only and raised inside ``jax.grad``.
This module closes that gap so ``ExecPolicy(mode="packed")`` is legal under
differentiation for every layout:

* ``xwT_block_grad``    — the two-level block layout.  Forward dispatches
  through the ``repro.tune`` registry (reference or Pallas ``block_spmm``);
  backward scatters through the :func:`~repro.core.sparsity.unpack_block`
  reference: dx = dy @ W_dense, and dvalues is the gather of dyᵀx at each
  slot's (row-block, active-group, local-index) coordinate.  Duplicate
  active-group ids accumulate in the forward scatter, so the per-slot
  gather *is* the exact vjp of that linear map.  ``indices`` and
  ``active_groups`` (the address streams) are non-differentiable.

* ``xwT_q8_grad`` / ``xwT_block_q8_grad`` — the int8 quantized twins
  (dequant-and-scatter backward).  The int8 ``values`` are not a
  differentiable parameterization (cotangent None, like the indices), but
  the op is no longer a wall: dx flows through the *dequantized* dense
  weight — so activations behind a quantized layer get exact gradients —
  and ``scales`` (a float leaf) receives its true gradient
  dL/ds = Σ_slots gather(dyᵀx) · int_value, which is what a
  learned-scale QAT variant would train.  Padded slots (value 0)
  contribute nothing to either.

All backward passes run through the ``kernels/ref.py`` / ``core.sparsity``
scatter references (pure jnp, fp32 accumulation); forwards reuse whatever
backend the policy picked, Pallas included.  The padding rule matches the
``xwT`` vjp: slots with value 0 receive zero gradient, so the packed
pattern can never densify during fine-tuning.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sparsity import (SparsityConfig, expand_scales, unpack,
                                 unpack_block)


def _variant_call(op: str, backend: str, params: tuple, *args):
    from repro import tune

    return tune.get_variant(op, backend).call(*args, **dict(params))


def _dw(dy: jax.Array, x: jax.Array) -> jax.Array:
    """dW = dyᵀ @ x in fp32 — the dense-weight cotangent every packed
    backward gathers from."""
    return jnp.dot(dy.T.astype(jnp.float32), x.astype(jnp.float32))


def _gather_block_slots(dw: jax.Array, indices: jax.Array,
                        active_groups: jax.Array, m: int) -> jax.Array:
    """Gather the (O, K) dense cotangent at every block-layout slot:
    result (RB, A_max, block_r, Ne) aligned with the packed values."""
    rb, a_max, block_r, _ne = indices.shape
    o = rb * block_r
    g = dw.shape[1] // m
    assert dw.shape[0] == o, (dw.shape, indices.shape)
    dw_g = jnp.swapaxes(dw.reshape(rb, block_r, g, m), 1, 2)   # (RB,G,br,M)
    sel = jnp.take_along_axis(
        dw_g, active_groups[:, :, None, None].astype(jnp.int32), axis=1
    )                                                          # (RB,A,br,M)
    return jnp.take_along_axis(sel, indices, axis=-1)          # (RB,A,br,Ne)


# ---------------------------------------------------------------------------
# float block layout
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def xwT_block_grad(x, values, indices, active_groups, cfg: SparsityConfig,
                   w_shape, backend: str = "reference", params: tuple = ()):
    """y = x @ W_blockᵀ, differentiable in x and values."""
    return _variant_call("xwT_block", backend, params, x, values, indices,
                         active_groups, cfg, tuple(w_shape))


def _block_fwd(x, values, indices, active_groups, cfg, w_shape, backend,
               params):
    y = xwT_block_grad(x, values, indices, active_groups, cfg, w_shape,
                       backend, params)
    return y, (x, values, indices, active_groups)


def _block_bwd(cfg, w_shape, backend, params, res, dy):
    x, values, indices, active_groups = res
    o, k = w_shape
    w = unpack_block(active_groups, values.astype(jnp.float32), indices,
                     cfg, (o, k))
    dx = jnp.dot(dy.astype(jnp.float32), w)
    dvalues = _gather_block_slots(_dw(dy, x), indices, active_groups,
                                  cfg.m).astype(values.dtype)
    # Padded / inactive slots (value 0, aliased at group 0 index 0) must not
    # accumulate gradient, or they would densify the pattern.
    dvalues = jnp.where(values != 0, dvalues, jnp.zeros((), values.dtype))
    return dx.astype(x.dtype), dvalues, None, None


xwT_block_grad.defvjp(_block_fwd, _block_bwd)


# ---------------------------------------------------------------------------
# int8 quantized xwT (w8a16)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def xwT_q8_grad(x, values, indices, scales, cfg: SparsityConfig, w_shape,
                backend: str = "reference", params: tuple = ()):
    """y = x @ W_q8ᵀ, differentiable in x and scales (values are int8)."""
    return _variant_call("xwT_q8", backend, params, x, values, indices,
                         scales, cfg, tuple(w_shape))


def _q8_fwd(x, values, indices, scales, cfg, w_shape, backend, params):
    y = xwT_q8_grad(x, values, indices, scales, cfg, w_shape, backend,
                    params)
    return y, (x, values, indices, scales)


def _q8_bwd(cfg, w_shape, backend, params, res, dy):
    x, values, indices, scales = res
    o, k = w_shape
    g = k // cfg.m
    vals_f = values.astype(jnp.float32)
    deq = vals_f * expand_scales(scales, values)
    w = unpack(deq, indices, cfg, (o, k))
    dx = jnp.dot(dy.astype(jnp.float32), w)
    dslot = jnp.take_along_axis(_dw(dy, x).reshape(o, g, cfg.m), indices,
                                axis=-1)                       # (O, G, Ne)
    # dL/ds = Σ over the slots sharing the scale of dW[slot] · int_value
    # (padded slots have int_value 0 and drop out automatically).
    axes = (-1,) if scales.ndim == values.ndim - 1 else (-2, -1)
    dscales = jnp.sum(dslot * vals_f, axis=axes).astype(scales.dtype)
    return dx.astype(x.dtype), None, None, dscales


xwT_q8_grad.defvjp(_q8_fwd, _q8_bwd)


# ---------------------------------------------------------------------------
# int8 quantized block layout
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def xwT_block_q8_grad(x, values, indices, active_groups, scales,
                      cfg: SparsityConfig, w_shape,
                      backend: str = "reference", params: tuple = ()):
    """y = x @ W_block_q8ᵀ, differentiable in x and scales."""
    return _variant_call("xwT_block_q8", backend, params, x, values, indices,
                         active_groups, scales, cfg, tuple(w_shape))


def _block_q8_fwd(x, values, indices, active_groups, scales, cfg, w_shape,
                  backend, params):
    y = xwT_block_q8_grad(x, values, indices, active_groups, scales, cfg,
                          w_shape, backend, params)
    return y, (x, values, indices, active_groups, scales)


def _block_q8_bwd(cfg, w_shape, backend, params, res, dy):
    x, values, indices, active_groups, scales = res
    o, k = w_shape
    vals_f = values.astype(jnp.float32)
    deq = vals_f * scales[..., None]
    w = unpack_block(active_groups, deq, indices, cfg, (o, k))
    dx = jnp.dot(dy.astype(jnp.float32), w)
    dslot = _gather_block_slots(_dw(dy, x), indices, active_groups, cfg.m)
    dscales = jnp.sum(dslot * vals_f, axis=-1).astype(scales.dtype)
    return dx.astype(x.dtype), None, None, None, dscales


xwT_block_q8_grad.defvjp(_block_q8_fwd, _block_q8_bwd)
