"""Straight-through fake quantization matching ``repro.quant`` numerics.

QAT works by running the *serving* arithmetic in the forward pass while
keeping full-precision weights and straight-through gradients in the
backward pass.  For the numerics to be worth anything, the fake-quant here
must be bit-identical to what ``quant.quantize_packed`` serves — same scale
formula (``amax / 127`` with the same zero-row guard), same rounding
(``jnp.round``, round-half-to-even), same clip (±127).  Because packing
keeps exactly the non-zero (masked) entries of each row/group, the amax of
a *masked dense* row equals the amax of its packed values — so fake-quant
on the masked training weight and real quantization of the packed serving
weight produce the same grid (DESIGN.md §11, the QAT↔serve contract;
asserted in tests/test_sparsetrain.py).

Granularities mirror ``repro.quant`` for the xwT layout:

* ``per_row``   — one scale per output row (the serving default).
* ``per_group`` — one scale per (row, M-group), matching
  ``quantize_packed(..., granularity="per_group")``.

Gradients: the round is straight-through (identity); the clip masks
gradients of saturated weights (standard QAT behaviour — a weight pinned at
±127 stops receiving gradient pressure to grow); the scale is treated as a
constant (``stop_gradient`` on the amax), matching the data-free
calibration that recomputes it from the weights at pack time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

QMAX = 127.0
_EPS = 1e-12  # identical zero-row guard to repro.quant.amax_scales

GRANULARITIES = ("per_row", "per_group")


@jax.custom_vjp
def ste_round(x: jax.Array) -> jax.Array:
    """round-to-nearest-even with a straight-through (identity) gradient."""
    return jnp.round(x)


def _round_fwd(x):
    return jnp.round(x), None


def _round_bwd(_, g):
    return (g,)


ste_round.defvjp(_round_fwd, _round_bwd)


def amax_scale(w: jax.Array, axis, keepdims: bool = True) -> jax.Array:
    """``amax / 127`` over ``axis`` with the quantizer's zero-row guard
    (all-zero units get scale 1/127 so the divide stays finite)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis,
                   keepdims=keepdims)
    return jnp.where(amax > _EPS, amax, 1.0) / QMAX


def fake_quant(w: jax.Array, scales: jax.Array) -> jax.Array:
    """Quantize-dequantize ``w`` on the int8 grid defined by ``scales``
    (broadcastable to ``w``), straight-through backward.

    The clip is *inclusive* straight-through: a weight landing exactly on
    ±127 (every row/group max does, under amax scales) keeps its full
    gradient — ``jnp.clip`` would halve it at the tie — while weights
    strictly beyond the grid (possible under clip-search observers) get
    zero, the standard QAT saturation behaviour."""
    s = jax.lax.stop_gradient(scales.astype(jnp.float32))
    r = ste_round(w.astype(jnp.float32) / s)
    q = jnp.where(jnp.abs(r) <= QMAX, r,
                  jax.lax.stop_gradient(jnp.clip(r, -QMAX, QMAX)))
    return (q * s).astype(w.dtype)


def fake_quant_weight(w: jax.Array, *, m: int = 0,
                      granularity: str = "per_row") -> jax.Array:
    """Fake-quantize a (…, O, K) dense weight on the grid its packed form
    will serve at.

    ``per_row`` scales over the full contraction dim K; ``per_group`` needs
    the sparsity group size ``m`` and scales per (row, M-group) — exactly
    the units :func:`repro.quant.amax_scales` uses on the packed layout.
    """
    if granularity not in GRANULARITIES:
        raise ValueError(f"unknown granularity {granularity!r}; expected "
                         f"one of {GRANULARITIES}")
    if granularity == "per_row":
        return fake_quant(w, amax_scale(w, axis=-1))
    if m <= 0:
        raise ValueError("per_group fake quantization needs the sparsity "
                         "group size m")
    if w.shape[-1] % m:
        raise ValueError(f"contraction dim {w.shape[-1]} not divisible by "
                         f"group size m={m}")
    wg = w.reshape(*w.shape[:-1], w.shape[-1] // m, m)
    out = fake_quant(wg, amax_scale(wg, axis=-1))
    return out.reshape(w.shape)
