"""Sparsity-aware training recipes: schedule + QAT + fault tolerance.

:class:`SparseTrainer` is the host-side driver that turns the pieces of
this package into one supervisor-compatible step function:

* it owns the **mask state** (``masks.init_mask_state``) and refreshes it
  deterministically from the integer step before every train step;
* it builds the jitted step via ``train_loop.make_train_step`` with
  scheduled masks and optional fake-quant QAT;
* it implements the :class:`~repro.train.fault_tolerance.TrainingSupervisor`
  extra-state protocol (``extra_state()`` / ``load_extra_state()``), so the
  mask tree, phase index, refresh step, and the schedule's canonical spec
  ride every checkpoint through ``train/checkpoint.py`` — a resume
  mid-schedule continues with the exact masks it left with, and a resume
  against a *different* schedule fails loudly instead of silently training
  a different model.

After training, :meth:`finalize` bakes the final masks into the weights
(hard zeros) so the checkpointed model satisfies its N:M patterns exactly
and packs losslessly for serving (``launch.pack_tree`` → ``launch.serve``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.core.sparsity import Static
from repro.sparsetrain import masks as masks_mod
from repro.sparsetrain.masks import SparsifySchedule
from repro.sparsetrain.qat import validate_qat
from repro.train.train_loop import make_train_step


@dataclasses.dataclass(frozen=True)
class SparseTrainRecipe:
    """What to train: the sparsification schedule and the QAT choice."""

    schedule: SparsifySchedule
    qat: Optional[str] = None           # None | "int8"
    qat_granularity: str = "per_row"    # per_row | per_group

    def __post_init__(self):
        validate_qat(self.qat, self.qat_granularity)


class SparseTrainer:
    """Drives a sparsify schedule (and optional QAT) through the supervisor.

    Usage::

        trainer = SparseTrainer(model, opt_cfg, recipe)
        trainer.init_state(params)
        sup = TrainingSupervisor(cfg, trainer.train_step, data_cfg,
                                 extra_state=trainer)
        params, opt, metrics, _ = sup.run(params, opt, steps)
        params = trainer.finalize(params)       # bake the final masks
    """

    def __init__(self, model, opt_cfg, recipe: SparseTrainRecipe, *,
                 num_microbatches: int = 1, backend: str = "reference",
                 jit: bool = True):
        from repro.core.sparse_linear import ExecPolicy

        self.recipe = recipe
        self._state = None
        step_fn = make_train_step(
            model, opt_cfg, num_microbatches=num_microbatches,
            policy=ExecPolicy(mode="masked", backend=backend),
            premask=True, fake_quant=recipe.qat,
            qat_granularity=recipe.qat_granularity)
        self._step_fn = jax.jit(step_fn) if jit else step_fn

    # ---- mask-state lifecycle -------------------------------------------
    @property
    def state(self):
        if self._state is None:
            raise RuntimeError("call init_state(params) (or restore a "
                               "checkpoint) before training")
        return self._state

    def init_state(self, params, step: int = 0):
        self._state = masks_mod.init_mask_state(params, self.recipe.schedule,
                                                step)
        return self._state

    def train_step(self, params, opt_state, batch, step):
        """Supervisor-compatible step: refresh masks if due, then step."""
        self._state, _ = masks_mod.update_mask_state(
            params, self.state, self.recipe.schedule, int(step))
        return self._step_fn(params, opt_state, batch, step,
                             self._state["masks"])

    def finalize(self, params):
        """Bake the final masks into the weights (hard zeros): the result
        satisfies each node's N:M pattern exactly and packs losslessly."""
        return masks_mod.bake_masks(params, self.state["masks"])

    # ---- TrainingSupervisor extra-state protocol ------------------------
    def extra_state(self):
        return {"sparsetrain": dict(self.state,
                                    spec=Static(self.recipe.schedule.spec()))}

    def load_extra_state(self, tree):
        st = dict(tree["sparsetrain"])
        spec = st.pop("spec", None)
        want = self.recipe.schedule.spec()
        if spec is not None:
            got = spec.value if isinstance(spec, Static) else spec
            if got != want:
                raise ValueError(
                    f"checkpoint carries sparsify schedule {got!r} but this "
                    f"run was configured with {want!r}; resuming across "
                    "schedules would silently train a different model")
        self._state = st
