"""Quantization-aware training over the masked-sparse training form.

``fake_quant_params`` walks a params tree that has **already been masked**
(``masks.apply_mask_tree`` / ``train_loop.premask_params``) and replaces
every sparse linear's weight by its straight-through fake-quantized image
(``ste.fake_quant_weight``).  The forward pass then computes exactly what
``pack_tree(..., quantize="int8")`` will serve — same amax scales, same
round-to-nearest-even, same ±127 clip — while gradients pass straight
through to the dense weight (see ``ste.py`` for the contract argument and
DESIGN.md §11 for the table).

Only *sparse* linears are fake-quantized: they are the nodes ``pack_tree``
packs and ``quant.quantize_tree`` quantizes, so QAT mirrors the serving
conversion exactly — dense projections (norms, embeddings, routers) serve
in full precision and train in full precision.

``granularity`` picks the scale unit for the (xwT-layout) serving form:
``per_row`` (default) or ``per_group`` — matching
``quant.quantize_packed(granularity=...)``.
"""

from __future__ import annotations

from repro.sparsetrain.ste import GRANULARITIES, fake_quant_weight

QAT_DTYPES = ("int8",)


def validate_qat(qdtype, granularity: str = "per_row"):
    if qdtype is not None and qdtype not in QAT_DTYPES:
        raise ValueError(f"unknown QAT dtype {qdtype!r}; expected one of "
                         f"{QAT_DTYPES}")
    if granularity not in GRANULARITIES:
        raise ValueError(f"unknown QAT granularity {granularity!r}; "
                         f"expected one of {GRANULARITIES}")


def fake_quant_params(params, granularity: str = "per_row"):
    """Fake-quantize every sparse linear weight of a (masked) params tree."""
    from repro.core.sparse_linear import node_sparsity

    if isinstance(params, dict):
        if "w" in params:
            cfg = node_sparsity(params)
            if cfg is not None:
                w = params["w"]
                return dict(params, w=fake_quant_weight(
                    w, m=cfg.m, granularity=granularity))
        return {k: fake_quant_params(v, granularity) for k, v in
                params.items()}
    return params
