"""``repro.sparsetrain`` — sparsity-aware training for the DeMM formats.

The train-side pillar of the dense → prune → train/QAT → pack → serve
pipeline (DESIGN.md §11):

  * :mod:`repro.sparsetrain.vjp`     — custom_vjp coverage for the
    ``xwT_block`` / ``xwT_q8`` / ``xwT_block_q8`` registry ops, making
    ``ExecPolicy(mode="packed")`` legal inside ``jax.grad`` for every
    packed layout (``kernels/ops.py`` dispatches through it).
  * :mod:`repro.sparsetrain.masks`   — gradual magnitude-pruning schedules
    (dense → coarse-group N:2M → N:M, k-reconfiguration phases) with
    deterministic, checkpointable mask state.
  * :mod:`repro.sparsetrain.ste`     — straight-through fake quantization
    matching ``repro.quant``'s serving numerics bit-for-bit.
  * :mod:`repro.sparsetrain.qat`     — QAT application over the masked
    training form (per-row / per-group int8 scales).
  * :mod:`repro.sparsetrain.recipes` — :class:`SparseTrainer`, the
    supervisor-compatible driver (``launch/train.py --sparsify ... --qat
    int8``).
"""

from repro.sparsetrain.masks import (
    SparsifyPhase,
    SparsifySchedule,
    anneal_schedule,
    apply_mask_tree,
    bake_masks,
    build_masks,
    init_mask_state,
    map_sparse_nodes,
    parse_pattern,
    parse_schedule,
    update_mask_state,
)
from repro.sparsetrain.qat import fake_quant_params
from repro.sparsetrain.ste import fake_quant, fake_quant_weight


def __getattr__(name):
    # Lazy (PEP 562): recipes pulls in the training stack (train_loop →
    # optim), which serving-side importers of this package — kernels/ops.py
    # reaches sparsetrain.vjp on the first packed block/q8 matmul — must
    # not pay for.
    if name in ("SparseTrainRecipe", "SparseTrainer"):
        from repro.sparsetrain import recipes

        return getattr(recipes, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "SparsifyPhase",
    "SparsifySchedule",
    "SparseTrainRecipe",
    "SparseTrainer",
    "anneal_schedule",
    "apply_mask_tree",
    "bake_masks",
    "build_masks",
    "fake_quant",
    "fake_quant_params",
    "fake_quant_weight",
    "init_mask_state",
    "parse_pattern",
    "parse_schedule",
    "update_mask_state",
]
