"""Gradual N:M sparsification schedules over ``core/pruning.py``.

FlexSA / S2TA-style pruned-model training derives the accelerator's density
target from a *schedule*, not a single projection: the model trains dense
for a warmup, is pruned to a coarse relaxed pattern (larger groups — the
paper's N:256), then annealed to the serving pattern (N:128), with the mask
refreshed from weight magnitude every ``update_every`` steps (straight-
through gradients keep pruned weights alive, so the pattern tracks the
weights) and frozen late in training so the final weights settle on a fixed
support.  Phase configs may also carry the paper's k-reconfiguration
(``"8:128:2"`` = 16:128 served as 2 passes of 8:128) — the "simple
reconfiguration" knob toward the denser 2:4 / 1:4 fine-grained patterns.

Everything here is **host-driven and deterministic**: phase and refresh
decisions are pure functions of the integer step, and the masks are pure
functions of (weights, phase config) — so the supervisor's
restore-and-replay fault tolerance reproduces the uninterrupted mask
trajectory bitwise.  The mask state rides the checkpoint through
``train/checkpoint.py`` (see ``recipes.SparseTrainer``).

Per-node resolution: model layers adapt their group size to the contraction
dim (``configs.base.choose_group``), so a schedule phase is resolved
against each node's own :class:`SparsityConfig`:

* the **final** phase always resolves to the node's stored config — the
  pattern the model will be packed and served at;
* an intermediate phase applies verbatim where its group size divides the
  node's contraction dim, and falls back to a density-matched pattern at
  the node's native group size otherwise.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.pruning import straight_through_mask
from repro.core.sparsity import SparsityConfig, prune_mask


@dataclasses.dataclass(frozen=True)
class SparsifyPhase:
    """One schedule phase: from ``start`` (inclusive) the masks follow
    ``cfg`` (``None`` = dense warmup, no masking)."""

    start: int
    cfg: Optional[SparsityConfig] = None

    def name(self) -> str:
        if self.cfg is None:
            return f"dense@{self.start}"
        n, m, k = self.cfg.n, self.cfg.m, self.cfg.k
        pat = f"{n}:{m}" if k == 1 else f"{n}:{m}:{k}"
        return f"{pat}@{self.start}"


@dataclasses.dataclass(frozen=True)
class SparsifySchedule:
    phases: Tuple[SparsifyPhase, ...]
    update_every: int = 25            # within-phase magnitude-mask refresh
    freeze_after: Optional[int] = None  # stop refreshing late in training

    def __post_init__(self):
        if not self.phases:
            raise ValueError("schedule needs at least one phase")
        starts = [p.start for p in self.phases]
        if starts != sorted(starts) or len(set(starts)) != len(starts):
            raise ValueError(f"phase starts must be strictly increasing, "
                             f"got {starts}")
        if self.phases[0].start != 0:
            raise ValueError("the first phase must start at step 0")
        if self.phases[-1].cfg is None:
            raise ValueError("the final phase must carry a SparsityConfig "
                             "(the pattern the model is packed at)")
        if self.update_every < 1:
            raise ValueError(f"update_every must be >= 1, "
                             f"got {self.update_every}")

    def phase_index(self, step: int) -> int:
        idx = 0
        for i, p in enumerate(self.phases):
            if step >= p.start:
                idx = i
        return idx

    def cfg_at(self, step: int) -> Optional[SparsityConfig]:
        return self.phases[self.phase_index(step)].cfg

    def spec(self) -> str:
        """Canonical string form — checkpointed so a resume can verify it
        is continuing the same schedule."""
        phases = ",".join(p.name() for p in self.phases)
        freeze = "-" if self.freeze_after is None else str(self.freeze_after)
        return f"{phases}|every{self.update_every}|freeze{freeze}"


def parse_pattern(s: str) -> SparsityConfig:
    """``"8:128"`` or ``"8:128:2"`` (k-reconfiguration) → SparsityConfig."""
    parts = s.split(":")
    if len(parts) not in (2, 3):
        raise ValueError(f"cannot parse sparsity pattern {s!r}; expected "
                         "'n:m' or 'n:m:k'")
    n, m = int(parts[0]), int(parts[1])
    k = int(parts[2]) if len(parts) == 3 else 1
    return SparsityConfig(n, m, k)


def anneal_schedule(final_cfg: SparsityConfig, total_steps: int, *,
                    warmup_frac: float = 0.15, target_frac: float = 0.5,
                    freeze_frac: float = 0.9,
                    update_every: int = 25) -> SparsifySchedule:
    """The default 3-phase anneal: dense → N:2M (coarse groups) → N:M.

    Doubling the group size first prunes to the *relaxed* coarse pattern
    (any N positions per 2M columns) before tightening to the serving
    group size — the dense → N:256 → N:128 trajectory of the paper's
    relaxed range.  The mask freezes at ``freeze_frac`` of training so the
    surviving weights fine-tune on a fixed support.
    """
    t1 = max(1, int(total_steps * warmup_frac))
    t2 = max(t1 + 1, int(total_steps * target_frac))
    coarse = SparsityConfig(final_cfg.n, final_cfg.m * 2, final_cfg.k)
    return SparsifySchedule(
        phases=(SparsifyPhase(0, None), SparsifyPhase(t1, coarse),
                SparsifyPhase(t2, final_cfg)),
        update_every=update_every,
        freeze_after=max(t2 + 1, int(total_steps * freeze_frac)))


def parse_schedule(spec: str, total_steps: int, *, update_every: int = 25,
                   freeze_after: Optional[int] = None) -> SparsifySchedule:
    """Build a schedule from a CLI spec.

    ``"8:128"``                       → :func:`anneal_schedule` to 8:128.
    ``"dense@0,8:256@50,8:128@150"``  → explicit phases (the final phase's
    pattern is the serving target).

    ``freeze_after`` stops within-phase mask refreshes from that step on.
    For explicit phases it defaults to 90% of ``total_steps`` (past the
    last phase start) so the final support settles before baking — pass a
    value to override, or one beyond ``total_steps`` to disable.
    """
    if "@" not in spec:
        sched = anneal_schedule(parse_pattern(spec), total_steps,
                                update_every=update_every)
        if freeze_after is not None:
            sched = dataclasses.replace(sched, freeze_after=freeze_after)
        return sched
    phases = []
    for part in spec.split(","):
        pat, _, start = part.partition("@")
        if not start:
            raise ValueError(f"phase {part!r} needs an '@step' suffix")
        cfg = None if pat.strip() == "dense" else parse_pattern(pat.strip())
        phases.append(SparsifyPhase(int(start), cfg))
    if phases and phases[-1].start >= total_steps:
        raise ValueError(
            f"final phase starts at step {phases[-1].start} but the run is "
            f"only {total_steps} steps — the serving pattern would never "
            "apply (and the final bake would fail); extend --steps or move "
            "the phase earlier")
    if freeze_after is None:
        freeze_after = max(phases[-1].start + 1, int(total_steps * 0.9))
    return SparsifySchedule(phases=tuple(phases), update_every=update_every,
                            freeze_after=freeze_after)


# ---------------------------------------------------------------------------
# Per-node phase resolution
# ---------------------------------------------------------------------------

def node_phase_cfg(phase_cfg: Optional[SparsityConfig],
                   node_cfg: SparsityConfig, kdim: int,
                   is_final: bool) -> Optional[SparsityConfig]:
    """Resolve a schedule phase against one layer's stored config."""
    if phase_cfg is None:
        return None
    if is_final:
        return node_cfg
    if kdim % phase_cfg.m == 0:
        return phase_cfg
    ne = min(node_cfg.m, max(1, round(phase_cfg.density * node_cfg.m)))
    return SparsityConfig(ne, node_cfg.m, 1)


# ---------------------------------------------------------------------------
# Mask-state tree (mirrors the params pytree)
# ---------------------------------------------------------------------------

def _is_sparse_node(node) -> bool:
    from repro.core.sparse_linear import node_sparsity

    return (isinstance(node, dict) and "w" in node
            and node_sparsity(node) is not None)


def map_sparse_nodes(params, fn):
    """Mirror ``params``: ``fn(node, cfg)`` at sparse linears, None at
    everything else (so the result checkpoints as a plain pytree).  The
    single home for the sparse-node traversal convention — fold over it
    instead of re-walking the tree."""
    from repro.core.sparse_linear import node_sparsity

    if _is_sparse_node(params):
        return fn(params, node_sparsity(params))
    if isinstance(params, dict):
        return {k: map_sparse_nodes(v, fn) for k, v in params.items()}
    return None


def build_masks(params, schedule: SparsifySchedule, phase: int):
    """Magnitude top-N:M masks for every sparse linear at ``phase``.

    Dense-phase masks are all-ones (straight-through identity), so one
    jitted train step serves the whole schedule — only mask *contents*
    change across phases, never the pytree structure.
    """
    phase_cfg = schedule.phases[phase].cfg
    is_final = phase == len(schedule.phases) - 1

    def one(node, cfg):
        w = node["w"]
        pcfg = node_phase_cfg(phase_cfg, cfg, int(w.shape[-1]), is_final)
        if pcfg is None:
            return jnp.ones(w.shape, bool)
        flat = w.reshape(-1, w.shape[-1])
        return prune_mask(flat, pcfg).reshape(w.shape)

    return map_sparse_nodes(params, one)


def apply_mask_tree(params, masks):
    """Straight-through masking of every sparse linear with its entry of a
    :func:`build_masks` tree (the gradient reaches the dense weight
    unmasked, so pruned weights can re-enter on the next refresh)."""
    if _is_sparse_node(params):
        return dict(params, w=straight_through_mask(params["w"], masks))
    if isinstance(params, dict):
        return {k: apply_mask_tree(v, masks[k]) for k, v in params.items()}
    return params


def bake_masks(params, masks):
    """Permanently zero the pruned weights (the pre-packing projection:
    after baking, every sparse linear satisfies its mask's pattern
    exactly and packs losslessly)."""
    if _is_sparse_node(params):
        w = params["w"]
        return dict(params, w=w * masks.astype(w.dtype))
    if isinstance(params, dict):
        return {k: bake_masks(v, masks[k]) for k, v in params.items()}
    return params


# ---------------------------------------------------------------------------
# Mask state: the checkpointable schedule position
# ---------------------------------------------------------------------------

def init_mask_state(params, schedule: SparsifySchedule, step: int = 0):
    phase = schedule.phase_index(step)
    return {"masks": build_masks(params, schedule, phase),
            "phase": jnp.asarray(phase, jnp.int32),
            "last_update": jnp.asarray(step, jnp.int32)}


def update_mask_state(params, state, schedule: SparsifySchedule, step: int):
    """Deterministic host-side mask refresh.  Returns ``(state, changed)``.

    A refresh happens on phase transitions (always — the schedule must
    advance even after ``freeze_after``) and every ``update_every`` steps
    within a sparse phase until ``freeze_after``.
    """
    phase = schedule.phase_index(step)
    cur = int(state["phase"])
    frozen = (schedule.freeze_after is not None
              and step >= schedule.freeze_after)
    due = phase != cur or (
        not frozen and schedule.phases[phase].cfg is not None
        and step - int(state["last_update"]) >= schedule.update_every)
    if not due:
        return state, False
    return {"masks": build_masks(params, schedule, phase),
            "phase": jnp.asarray(phase, jnp.int32),
            "last_update": jnp.asarray(step, jnp.int32)}, True
