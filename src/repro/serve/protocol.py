"""Shared serving-engine protocol + distribution plumbing.

Every engine — the dense-cache :class:`~repro.serve.serve_loop.ServeEngine`,
the paged :class:`~repro.paged.engine.PagedServeEngine`, and the
data-parallel :class:`~repro.serve.router.ReplicaRouter` — speaks the same
surface:

    submit(req)            enqueue a Request
    step() -> int          one engine tick; returns occupied slots
    run_until_drained()    tick until queue + slots are empty
    tick() / drain()       aliases for the above (the protocol names)
    completed              finished Requests, in completion order
    metrics                a MetricsRegistry (or a merged facade with the
                           same snapshot()/write() surface)

so drivers (``launch/serve.py``, benchmarks, the examples) hold any of them
behind one variable.  :class:`EngineBase` provides the aliases plus the
:class:`~repro.sharding.plan.ShardingPlan` plumbing both concrete engines
share: resolving ``policy.plan`` into a mesh + sharding context, renumbering
and placing params, placing decode state, and wrapping the compiled step
functions so trace *and* execution happen under the plan's mesh.
"""

from __future__ import annotations

import functools
from typing import Optional, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class Engine(Protocol):
    """Structural type of a serving engine (isinstance-checkable)."""

    def submit(self, req) -> None: ...
    def step(self) -> int: ...
    def run_until_drained(self, max_ticks: int = 10000) -> int: ...


class EngineBase:
    """Protocol aliases + ShardingPlan plumbing shared by the engines.

    Subclasses must set ``self.model`` before calling :meth:`_setup_plan`
    (the plan reads the arch's head counts off ``model.cfg``) and implement
    ``submit`` / ``step`` / ``run_until_drained``.

    Observability v2 plumbing lives here too: per-engine trace attribution
    labels (``replica`` — set by the DP router via :meth:`set_replica` —
    plus ``tp_shard``/``pp_stage`` extents from the plan), per-request
    :class:`~repro.obs.context.TraceContext` roots, and optional
    flight-recorder attachment (:meth:`_setup_recorder`).
    """

    plan = None          # ShardingPlan from policy.plan (or None)
    mesh = None          # the plan's Mesh (None on a single device)
    _shctx = None        # ShardingContext installed around compiled steps
    replica_id = None    # set by ReplicaRouter on DP replicas
    _recorder = None     # FlightRecorder (launch --flight-dir)
    _watchdog = None     # stall watchdog beaten once per step()

    # -- protocol aliases ---------------------------------------------------

    def tick(self) -> int:
        """Protocol alias for :meth:`step`."""
        return self.step()

    def drain(self, max_ticks: int = 10000) -> int:
        """Protocol alias for :meth:`run_until_drained`."""
        return self.run_until_drained(max_ticks)

    # -- trace attribution (DESIGN.md §16) ----------------------------------

    def set_replica(self, i: int):
        """Stamp this engine as DP replica ``i`` — every subsequent trace
        context (and so every event) carries ``replica=i``."""
        self.replica_id = int(i)
        if self._watchdog is not None:
            # recorder attached before the router stamped us: rename so
            # flight dumps distinguish the per-replica tick watchdogs
            self._watchdog.name = f"serve_tick_r{self.replica_id}"

    def _trace_labels(self) -> dict:
        """Topology labels attached to this engine's trace contexts.  The
        engine runs the whole tp×pp extent of its plan (shards live inside
        one process), so labels record extents, not per-device ranks."""
        out = {}
        if self.replica_id is not None:
            out["replica"] = str(self.replica_id)
        if self.plan is not None:
            if self.plan.tp > 1:
                out["tp_shard"] = f"0:{self.plan.tp}"
            if self.plan.pp > 1:
                out["pp_stage"] = f"0:{self.plan.pp}"
        return out

    def _request_context(self, req):
        """The request's root TraceContext (creating ``req.trace_id`` on
        first use); entered around every dispatch done on its behalf."""
        from repro.obs.context import TraceContext, new_trace_id
        if getattr(req, "trace_id", None) is None:
            req.trace_id = new_trace_id()
        return TraceContext(req.trace_id, span_id=req.trace_id,
                            labels=tuple(sorted(
                                self._trace_labels().items())))

    def _setup_recorder(self, recorder):
        """Attach a FlightRecorder: tap this engine's trace into its rings
        and register a per-engine tick watchdog (beaten by ``step()``)."""
        self._recorder = recorder
        if recorder is None:
            return
        recorder.attach_trace(self.trace)
        name = "serve_tick" if self.replica_id is None \
            else f"serve_tick_r{self.replica_id}"
        self._watchdog = recorder.watchdog(name)

    def _beat(self):
        if self._watchdog is not None:
            self._watchdog.beat()

    # -- plan plumbing ------------------------------------------------------

    def _head_counts(self):
        cfg = getattr(self.model, "cfg", None)
        return (int(getattr(cfg, "num_kv_heads", 16) or 16),
                int(getattr(cfg, "num_heads", 0) or 0))

    def _setup_plan(self, policy, params):
        """Resolve ``policy.plan``: build the mesh + sharding context and
        return the renumbered, device-placed params.  Identity (and
        ``self.mesh`` stays None) for plan-less / single-device policies.

        ``dp`` is not consumed here — data parallelism is replica-level
        (:class:`~repro.serve.router.ReplicaRouter`), so an engine only
        realizes the plan's tp×pp slice of the mesh.
        """
        plan = getattr(policy, "plan", None)
        self.plan = plan
        if plan is None or plan.tp * plan.pp == 1:
            return params
        # engines realize tp (and pp) only; never demand dp devices here
        import dataclasses
        engine_plan = (plan if plan.dp == 1
                       else dataclasses.replace(plan, dp=1))
        self.mesh = engine_plan.make_mesh()
        nkv, nh = self._head_counts()
        self._shctx = engine_plan.context(
            self.mesh, num_kv_heads=nkv, num_heads=nh)
        return engine_plan.shard_params(params, self.mesh)

    def _place_state(self, state):
        """device_put a freshly built decode state per the plan (KV head
        axis over TP when divisible); identity without a mesh."""
        if self.plan is None or self.mesh is None:
            return state
        nkv, _ = self._head_counts()
        return self.plan.shard_decode_state(state, self.mesh,
                                            num_kv_heads=nkv)

    def _wrap_step(self, fn):
        """Run ``fn`` (typically a jitted step) under the plan's mesh and
        sharding context — covering both the trace and every execution —
        so ``shard_map`` islands and ``constrain`` calls see the mesh."""
        if self._shctx is None:
            return fn
        from repro.sharding import context as shctx
        ctx = self._shctx

        @functools.wraps(fn)
        def wrapped(*a, **k):
            with shctx.use_mesh(ctx):
                return fn(*a, **k)

        return wrapped


def greedy_token(logits_row: np.ndarray) -> int:
    """The shared greedy sampler (argmax over the vocab axis)."""
    return int(np.argmax(logits_row))
