"""``repro.serve`` — serving engines behind one protocol + factory.

Two concrete engines share the protocol surface (``submit`` / ``step`` /
``run_until_drained``, aliases ``tick``/``drain`` — see
:mod:`repro.serve.protocol`):

* :class:`ServeEngine` — dense per-slot KV caches, continuous batching.
* :class:`~repro.paged.PagedServeEngine` — shared paged KV arena, chunked
  prefill, scheduled admission/preemption (selected by passing a
  :class:`~repro.paged.PagedServeConfig`).

:func:`make_engine` dispatches on the config type and folds an optional
:class:`~repro.sharding.plan.ShardingPlan` into the policy, so callers
write one construction path for single-device, TP, PP, and (with
:class:`~repro.serve.router.ReplicaRouter` / ``replicas=``) DP serving.
"""

from __future__ import annotations

from typing import Optional

from repro.serve.protocol import Engine, EngineBase
from repro.serve.router import ReplicaRouter, make_replicas
from repro.serve.serve_loop import Request, ServeConfig, ServeEngine

__all__ = [
    "Engine", "EngineBase", "ReplicaRouter", "Request", "ServeConfig",
    "ServeEngine", "make_engine", "make_replicas",
]


def make_engine(model, params, config, *, plan=None, policy=None,
                autotune: bool = False, metrics=None, replicas: int = 1,
                spec=None, recorder=None):
    """Build a serving engine for ``config``.

    * ``config`` — :class:`ServeConfig` selects the dense-cache
      :class:`ServeEngine`; :class:`~repro.paged.PagedServeConfig` selects
      the paged :class:`~repro.paged.PagedServeEngine`.
    * ``plan`` — optional :class:`~repro.sharding.plan.ShardingPlan`,
      folded onto the policy (``policy.plan``); the engine then renumbers
      row-parallel packed weights, builds the mesh, and shards params +
      decode state.  Passing both ``plan`` and a policy that already
      carries a *different* plan is an error.
    * ``replicas`` — N > 1 wraps N engines (each with its own metrics
      registry and decode state, sharing ``params``) in a round-robin
      :class:`~repro.serve.router.ReplicaRouter`; ``metrics`` must then be
      None (each replica owns a registry; the router merges snapshots).
    * ``spec`` — optional :class:`~repro.spec.SpecConfig`: the engine
      drafts with the sparser-tier view of the same packed buffers and
      verifies in batched full-tier dispatches (DESIGN.md §15).  Requires
      a packed params tree whose pattern the draft tier can narrow.
    * ``recorder`` — optional :class:`~repro.obs.FlightRecorder`: each
      engine taps its trace into the recorder's rings and beats a stall
      watchdog once per tick (DESIGN.md §16).
    """
    from repro.core.sparse_linear import resolve_policy

    policy = resolve_policy(policy, None, None)
    if plan is not None:
        if policy.plan is not None and policy.plan != plan:
            raise ValueError(
                "make_engine(plan=...) conflicts with policy.plan; pass the "
                "plan in one place")
        policy = policy.replace(plan=plan)

    def build(m):
        # dispatch on config type, paged imported lazily (repro.paged
        # imports repro.serve for the Request type)
        type_name = type(config).__name__
        if type_name == "PagedServeConfig":
            from repro.paged import PagedServeEngine
            return PagedServeEngine(model, params, config, policy=policy,
                                    autotune=autotune, metrics=m, spec=spec,
                                    recorder=recorder)
        if isinstance(config, ServeConfig):
            return ServeEngine(model, params, config, policy=policy,
                               autotune=autotune, metrics=m, spec=spec,
                               recorder=recorder)
        raise TypeError(
            f"make_engine: unknown config type {type(config).__name__!r} "
            "(expected ServeConfig or PagedServeConfig)")

    if replicas > 1:
        if metrics is not None:
            raise ValueError(
                "make_engine(replicas=N, metrics=...) is unsupported: each "
                "replica owns a registry and the router merges snapshots")
        return make_replicas(replicas, build)
    return build(metrics)
