"""Batched serving with continuous slot-based batching.

The engine owns a fixed decode batch of ``num_slots`` sequences.  Requests
(prompts) are queued; a free slot is claimed, its cache region reset, the
prompt prefilled token-by-token (the jitted decode step doubles as a
prefill-by-steps path so the engine needs exactly one compiled program),
then generation proceeds until EOS/max_tokens and the slot frees.

The packed-DeMM serving path is selected by handing the engine a params tree
of ``PackedWeight`` nodes (``launch.pack_tree``) plus an
``ExecPolicy(mode="packed", backend=...)``: every matmul in the decode step
then reads only packed bytes (see DESIGN.md §6).  ``backend='auto'``
resolves each packed matmul through the ``repro.tune`` registry/cache; pass
``autotune=True`` to pre-measure tile configs for every packed weight shape
before the decode step is compiled (DESIGN.md §8).

Sampling: ``ServeConfig(temperature=, top_k=, seed=)`` selects the
replay-safe coupled sampler (``repro.spec.sampling``) — greedy argmax at
``temperature == 0``.  Speculative decoding: pass
``spec=SpecConfig(draft="N:M", gamma=G)`` and the engine drafts γ tokens per
tick with the *draft-tier* view of the same packed buffers, then verifies
the whole window in one batched full-tier dispatch (DESIGN.md §15).  The
committed stream is token-identical to the non-speculative engine at any
temperature.

Observability (``repro.obs``, DESIGN.md §12): the engine instruments the
full request lifecycle on its :class:`~repro.obs.MetricsRegistry` (the
process default unless ``metrics=`` is given) — queue wait
submit→first-claim, per-token decode latency, time-to-first-token, tick
duration histograms; slot-occupancy and tokens/sec gauges; request/token
counters — and emits ``request_submit`` / ``request_claim`` /
``request_first_token`` / ``request_complete`` events plus one ``request``
span per request on the registry's event trace.  Speculative runs add the
``spec_*`` families (acceptance histogram, drafted/accepted/rejected
counters, tokens-per-dispatch gauge).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.serve.protocol import EngineBase


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    priority: int = 1           # 0 = highest; scheduler policy (repro.paged)
    # filled by the engine:
    output: Optional[list] = None
    # lifecycle timestamps (time.monotonic seconds), filled by the engine:
    submit_ts: Optional[float] = None
    claim_ts: Optional[float] = None
    first_token_ts: Optional[float] = None
    complete_ts: Optional[float] = None
    # -- observability v2 (DESIGN.md §16) -----------------------------------
    # correlates every trace event emitted on this request's behalf
    trace_id: Optional[str] = None
    # waste / phase attribution (repro.obs.slo):
    preempts: int = 0                 # times evicted by the paged scheduler
    wasted_prefill_tokens: int = 0    # tokens re-ingested after preemption
    rejected_draft_tokens: int = 0    # draft proposals the verifier threw away
    preempt_overhead_s: float = 0.0   # evict -> resumed-re-prefill round trips
    preempt_ts: Optional[float] = None   # open preemption episode start


@dataclasses.dataclass
class ServeConfig:
    num_slots: int = 4
    max_len: int = 256
    greedy: bool = True         # legacy alias; temperature == 0 means greedy
    temperature: float = 0.0
    top_k: int = 0              # 0 = full vocab
    seed: int = 0               # sampling seed (keys the per-position RNG)


class ServeEngine(EngineBase):
    def __init__(self, model, params, cfg: ServeConfig, *, policy=None,
                 mode=None, backend=None, autotune=False, metrics=None,
                 spec=None, recorder=None):
        from repro.core.sparse_linear import resolve_policy
        from repro.spec.sampling import ReplaySafeSampler

        if mode is not None or backend is not None:
            raise ValueError(
                "ServeEngine(mode=..., backend=...) was removed (PR 8 "
                "deprecation); pass policy=ExecPolicy(mode=..., "
                "backend=...) — and sharding via "
                "ExecPolicy(plan=ShardingPlan(...))")
        policy = resolve_policy(policy)
        self.model = model
        if spec is not None:
            # establish the tier-sort invariant (per-group pairs ordered
            # magnitude-descending) BEFORE renumbering/sharding so the
            # draft tier's prefix-read is exact magnitude pruning even on
            # shard-stacked nodes (sharding preserves Ne-axis order).
            from repro.spec.tiers import tier_sort_tree
            params = tier_sort_tree(params)
        # policy.plan (ShardingPlan): renumber row-parallel packed weights
        # and place everything on the plan's mesh before any compile
        params = self._setup_plan(policy, params)
        self.params = params
        self.cfg = cfg
        self.policy = policy
        if autotune and policy.mode == "packed":
            # Measure tile configs for every packed weight at the decode
            # batch shape so backend="auto" resolves from the cache when the
            # step below is traced (shard-stacked nodes tune their
            # shard-local slice — the problem the shard_map island runs).
            from repro import tune
            tune.autotune_packed_tree(params, cfg.num_slots)
        self.state = self._place_state(
            model.init_decode_state(cfg.num_slots, cfg.max_len,
                                    dtype=jnp.float32))
        self._init_state = jax.tree.map(lambda x: x, self.state)
        # locate each leaf's slot (batch) axis robustly: init a state with
        # one extra slot and diff the shapes.
        probe = model.init_decode_state(cfg.num_slots + 1, cfg.max_len,
                                        dtype=jnp.float32)
        self._slot_axis = jax.tree.map(
            lambda a, b: next((i for i, (x, y) in
                               enumerate(zip(a.shape, b.shape)) if x != y),
                              None) if hasattr(a, "shape") else None,
            self.state, probe)
        if self.plan is not None and self.plan.pp > 1:
            if self.plan.tp > 1:
                raise NotImplementedError(
                    "combined tp>1 + pp>1 serving would nest the packed TP "
                    "shard_map island inside the pipeline shard_map; pick "
                    "one (DESIGN.md §14)")
            if spec is not None:
                raise NotImplementedError(
                    "speculative decoding with pp>1 would need a pipelined "
                    "multistep verify program; serve spec on tp/dp plans")
            pp, pp_axis = self.plan.pp, self.plan.pp_axis
            self._step = self._wrap_step(jax.jit(
                lambda p, s, t: model.decode_step_pipelined(
                    p, s, t, policy=policy, pp=pp, pp_axis=pp_axis)))
        else:
            self._step = self._wrap_step(jax.jit(
                lambda p, s, t: model.decode_step(p, s, t, policy=policy)))
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * cfg.num_slots
        self._fed: List[int] = [0] * cfg.num_slots    # prompt tokens fed
        self._next_tok = np.zeros((cfg.num_slots, 1), np.int32)
        self.completed: List[Request] = []
        self.sampler = ReplaySafeSampler(temperature=cfg.temperature,
                                         top_k=cfg.top_k, seed=cfg.seed)
        # -- observability (instruments fetched once; per-tick cost is a few
        #    histogram observes, noise next to the jitted decode step) ------
        self.metrics = metrics if metrics is not None else obs.metrics()
        m = self.metrics
        self.trace = m.trace
        self._spans = {}                              # uid -> open Span
        self._m_submitted = m.counter(
            "serve_requests_submitted_total", help="requests accepted")
        self._m_completed = m.counter(
            "serve_requests_completed_total", help="requests fully decoded")
        self._m_tokens = m.counter(
            "serve_tokens_total", help="generated (decode) tokens")
        self._m_prefill = m.counter(
            "serve_prefill_tokens_total", help="prompt tokens prefilled")
        self._m_queue_wait = m.histogram(
            "serve_queue_wait_seconds", help="submit -> first slot claim")
        self._m_ttft = m.histogram(
            "serve_time_to_first_token_seconds",
            help="submit -> first generated token")
        self._m_tok_lat = m.histogram(
            "serve_decode_token_seconds",
            help="decode-step latency per generated token")
        self._m_tick = m.histogram(
            "serve_tick_seconds", help="full engine tick duration")
        self._m_slots = m.gauge(
            "serve_slots_active", help="occupied decode slots")
        self._m_tps = m.gauge(
            "serve_tokens_per_second",
            help="decode throughput of the last run_until_drained window")
        # sketch-backed latency percentiles (mergeable across DP replicas;
        # the fixed-bucket histograms above stay for rate/dashboard queries)
        self._sk_ttft = m.sketch(
            "serve_ttft_seconds_sketch",
            help="submit -> first token (quantile sketch)")
        self._sk_tok = m.sketch(
            "serve_decode_token_seconds_sketch",
            help="per-generated-token decode latency (quantile sketch)")
        self._sk_e2e = m.sketch(
            "serve_e2e_seconds_sketch",
            help="submit -> completion (quantile sketch)")
        self._setup_recorder(recorder)
        # -- speculative decoding (DESIGN.md §15) ---------------------------
        self._spec = spec
        if spec is not None:
            from repro.spec.decode import (SpecMetrics, guard_cache_kinds,
                                           make_multistep)
            from repro.spec.tiers import derive_draft_tier
            guard_cache_kinds(self.state)
            # derive AFTER _setup_plan so the draft view aliases the
            # placed/renumbered buffers (draft.values IS full.values)
            self._draft_params, self.tier_report = derive_draft_tier(
                self.params, spec.draft)
            self._verify = self._wrap_step(make_multistep(model, policy))
            self._spec_metrics = SpecMetrics(self.metrics)

    def submit(self, req: Request):
        req.output = []
        req.submit_ts = time.monotonic()
        ctx = self._request_context(req)   # mints req.trace_id
        self.queue.append(req)
        self._m_submitted.inc()
        with obs.use_context(ctx):
            self._spans[req.uid] = self.trace.span("request", uid=req.uid)
            self.trace.event("request_submit", uid=req.uid,
                             prompt_len=len(req.prompt))

    def _claim_slots(self):
        for i in range(self.cfg.num_slots):
            if self.active[i] is None and self.queue:
                req = self.queue.popleft()
                self.active[i] = req
                self._fed[i] = 0
                self._reset_slot(i)
                self._next_tok[i, 0] = req.prompt[0]
                req.claim_ts = time.monotonic()
                self._m_queue_wait.observe(req.claim_ts - req.submit_ts)
                self.trace.event("request_claim", uid=req.uid, slot=i,
                                 trace_id=req.trace_id)

    def _reset_slot(self, i):
        """Restore slot ``i``'s state region from the initial template.

        KV caches self-mask stale entries (cache_len / slot_pos), but the
        O(1) SSM/mLSTM states must be re-initialized per request.  The slot
        axis is the first axis whose size equals num_slots."""
        def reset(cur, init, ax):
            if ax is None or not hasattr(cur, "shape"):
                return cur
            idx = [slice(None)] * cur.ndim
            idx[ax] = i
            return cur.at[tuple(idx)].set(init[tuple(idx)])

        self.state = jax.tree.map(reset, self.state, self._init_state,
                                  self._slot_axis)

    def _complete(self, i, req, now):
        req.complete_ts = now
        self.completed.append(req)
        self.active[i] = None
        self._m_completed.inc()
        self._sk_e2e.observe(now - req.submit_ts)
        self.trace.event("request_complete", uid=req.uid,
                         tokens=len(req.output), trace_id=req.trace_id)
        span = self._spans.pop(req.uid, None)
        if span is not None:
            span.end(tokens=len(req.output))

    def step(self) -> int:
        """One engine tick.  Returns the number of active slots.

        Non-speculative: one decode step for the whole batch.  Speculative:
        one draft→verify window (γ draft-tier steps + ONE batched full-tier
        verify dispatch), clamped so no lane's window crosses ``max_len``."""
        t_tick = time.perf_counter()
        self._beat()
        self._claim_slots()
        lanes = [i for i, r in enumerate(self.active) if r is not None]
        self._m_slots.set(len(lanes))
        if not lanes:
            return 0
        if self._spec is not None:
            pos0 = np.asarray(self.state["pos"], np.int64)
            g_eff = min(self._spec.gamma,
                        self.cfg.max_len - 1
                        - max(int(pos0[i]) for i in lanes))
            if g_eff >= 1:
                return self._spec_window(t_tick, lanes, pos0, g_eff)
            # a lane is one token from max_len: fall back to a plain step
        return self._plain_step(t_tick, lanes)

    def _plain_step(self, t_tick, lanes) -> int:
        t0 = time.perf_counter()
        # batched dispatch: attributed to the first active lane's request
        # (any compile-time kernel_dispatch events inherit its trace_id)
        with obs.use_context(self._request_context(self.active[lanes[0]])):
            logits, self.state = self._step(self.params, self.state,
                                            jnp.asarray(self._next_tok))
        logits = np.asarray(logits[:, 0], np.float32)   # device sync
        step_dt = time.perf_counter() - t0
        now = time.monotonic()
        for i in lanes:
            req = self.active[i]
            self._fed[i] += 1
            if self._fed[i] < len(req.prompt):
                # still prefilling: feed the next prompt token
                self._next_tok[i, 0] = req.prompt[self._fed[i]]
                self._m_prefill.inc()
                continue
            # the emitted token occupies sequence index _fed[i] (== pos)
            tok = self.sampler.sample(logits[i], req.uid, self._fed[i])
            req.output.append(tok)
            self._next_tok[i, 0] = tok
            self._m_tokens.inc()
            self._m_tok_lat.observe(step_dt)
            self._sk_tok.observe(step_dt)
            if len(req.output) == 1:
                req.first_token_ts = now
                self._m_ttft.observe(now - req.submit_ts)
                self._sk_ttft.observe(now - req.submit_ts)
                self.trace.event("request_first_token", uid=req.uid,
                                 trace_id=req.trace_id)
            done = (len(req.output) >= req.max_new_tokens or
                    (req.eos_id is not None and tok == req.eos_id) or
                    int(self.state["pos"][i]) >= self.cfg.max_len - 1)
            if done:
                self._complete(i, req, now)
        self._m_slots.set(sum(r is not None for r in self.active))
        self._m_tick.observe(time.perf_counter() - t_tick)
        return sum(r is not None for r in self.active)

    def _spec_window(self, t_tick, lanes, pos0, g_eff) -> int:
        """One speculation window: γ_eff draft-tier steps propose tokens,
        one batched full-tier multistep dispatch verifies every window
        position, then each lane commits its accepted prefix + the
        correcting/bonus token and rolls ``pos`` back to its last valid
        input (stale draft KV beyond it is masked by attention and
        overwritten by the next window)."""
        t0 = time.perf_counter()
        W = g_eff + 1
        window = np.zeros((self.cfg.num_slots, W), np.int32)
        window[:, 0] = self._next_tok[:, 0]
        is_draft = np.zeros((self.cfg.num_slots, g_eff), bool)
        d_state = self.state                    # self.state stays pre-draft
        window_ctx = self._request_context(self.active[lanes[0]])
        for j in range(g_eff):
            with obs.use_context(window_ctx):
                d_logits, d_state = self._step(
                    self._draft_params, d_state,
                    jnp.asarray(window[:, j:j + 1]))
            d_logits = np.asarray(d_logits[:, 0], np.float32)
            for i in lanes:
                req = self.active[i]
                fed = self._fed[i] + j + 1      # inputs fed through col j
                if fed < len(req.prompt):
                    window[i, j + 1] = req.prompt[fed]
                else:
                    # draft proposes with the SAME (rid, pos) key the
                    # verifier will sample with — acceptance iff equal
                    window[i, j + 1] = self.sampler.sample(
                        d_logits[i], req.uid, int(pos0[i]) + j + 1)
                    is_draft[i, j] = True
        # ONE batched full-tier dispatch verifies the whole window from the
        # pre-draft state (jax arrays are immutable — the draft steps above
        # never touched self.state), rewriting every window position's KV
        # with full-tier values.
        with obs.use_context(window_ctx):
            f_logits, new_state = self._verify(self.params, self.state,
                                               jnp.asarray(window))
        f_logits = np.asarray(f_logits, np.float32)
        window_dt = time.perf_counter() - t0
        now = time.monotonic()
        new_pos = pos0.copy()
        drafted = accepted = committed = 0
        for i in lanes:
            req = self.active[i]
            p, fed0 = int(pos0[i]), self._fed[i]
            valid = W                   # window inputs this lane keeps
            lane_accepted = lane_committed = 0
            for j in range(W):
                if fed0 + j + 1 < len(req.prompt):
                    self._m_prefill.inc()
                    if j == g_eff:      # window ends mid-prompt
                        self._next_tok[i, 0] = req.prompt[fed0 + W]
                    continue
                tok = self.sampler.sample(f_logits[i, j], req.uid, p + j + 1)
                if j < g_eff and is_draft[i, j]:
                    drafted += 1
                    ok = int(window[i, j + 1]) == tok
                    accepted += ok
                    lane_accepted += ok
                req.output.append(tok)
                committed += 1
                lane_committed += 1
                self._m_tokens.inc()
                if len(req.output) == 1:
                    req.first_token_ts = now
                    self._m_ttft.observe(now - req.submit_ts)
                    self._sk_ttft.observe(now - req.submit_ts)
                    self.trace.event("request_first_token", uid=req.uid,
                                     trace_id=req.trace_id)
                done = (len(req.output) >= req.max_new_tokens or
                        (req.eos_id is not None and tok == req.eos_id) or
                        p + j + 1 >= self.cfg.max_len - 1)
                if done:
                    valid = j + 1
                    self._complete(i, req, now)
                    break
                if j < g_eff and int(window[i, j + 1]) != tok:
                    # first mismatch truncates the window; the committed
                    # full-tier token opens the next one
                    valid = j + 1
                    self._next_tok[i, 0] = tok
                    break
                if j == g_eff:
                    # every draft accepted: the bonus token rides along
                    self._next_tok[i, 0] = tok
            self._fed[i] += valid
            new_pos[i] = p + valid
            # lane-level waste: every drafted-but-uncommitted proposal
            # (including drafts past a truncation point, whose draft-step
            # work is discarded unexamined)
            lane_rejected = int(is_draft[i].sum()) - lane_accepted
            if lane_rejected > 0:
                req.rejected_draft_tokens += lane_rejected
                self._spec_metrics.observe_wasted(lane_rejected)
            if lane_committed:
                self.trace.event("spec_commit", uid=req.uid,
                                 trace_id=req.trace_id,
                                 committed=lane_committed,
                                 accepted=lane_accepted,
                                 rejected=lane_rejected)
        self.state = dict(new_state)
        self.state["pos"] = jnp.asarray(new_pos, jnp.int32)
        if committed:
            per_tok = window_dt / committed
            for _ in range(committed):
                self._m_tok_lat.observe(per_tok)
                self._sk_tok.observe(per_tok)
        self._spec_metrics.observe_window(drafted, accepted, committed)
        self._m_slots.set(sum(r is not None for r in self.active))
        self._m_tick.observe(time.perf_counter() - t_tick)
        return sum(r is not None for r in self.active)

    def run_until_drained(self, max_ticks: int = 10000):
        ticks = 0
        t0 = time.perf_counter()
        tok0 = self._m_tokens.value
        while (self.queue or any(r is not None for r in self.active)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        dt = time.perf_counter() - t0
        if dt > 0:
            self._m_tps.set((self._m_tokens.value - tok0) / dt)
        return ticks
