"""ReplicaRouter: data-parallel serving over N engine replicas.

The router is the ``dp`` leg of a :class:`~repro.sharding.plan.ShardingPlan`
realized at the *engine* level: each replica is a full engine (its own
decode state, KV arena, scheduler, and compiled programs) over a **shared**
params tree — one checkpoint in memory, N decode batches draining it —
and the router round-robins submissions across them.

It speaks the same engine protocol (``submit`` / ``step`` /
``run_until_drained`` + the ``tick``/``drain`` aliases), so
``launch/serve.py --replicas N`` holds a router exactly where it held an
engine.  Observability: each replica gets its **own**
:class:`~repro.obs.MetricsRegistry`, and :attr:`ReplicaRouter.metrics`
merges them into one snapshot with a ``replica="<i>"`` label on every
per-replica family, plus router-level gauges:

    serve_replica_slots_active{replica=i}    occupied slots per replica
    serve_replica_tokens_per_second{replica=i}
    serve_router_requests_total              requests routed
    serve_router_replicas                    replica count
"""

from __future__ import annotations

from typing import Callable, List

from repro.obs.metrics import MetricsRegistry
from repro.serve.protocol import EngineBase


class _MergedMetrics:
    """Snapshot/write facade over the replicas' registries + the router's
    own.  Merging happens at snapshot time — instruments stay owned by the
    engine that increments them, so the hot path is untouched."""

    def __init__(self, router: "ReplicaRouter"):
        self._router = router

    def _merged(self) -> MetricsRegistry:
        out = MetricsRegistry()

        def copy_from(reg: MetricsRegistry, extra_labels: dict):
            snap = reg.snapshot(meta=False)
            for e in snap["counters"]:
                c = out.counter(e["name"], **{**e["labels"], **extra_labels})
                c.value = e["value"]
            for e in snap["gauges"]:
                out.gauge(e["name"],
                          **{**e["labels"], **extra_labels}).set(e["value"])
            for e in snap["histograms"]:
                h = out.histogram(e["name"], buckets=e["buckets"],
                                  **{**e["labels"], **extra_labels})
                h.counts = list(e["counts"])
                h.sum = e["sum"]
                h.count = e["count"]
            for e in snap.get("sketches", ()):
                from repro.obs.sketch import QuantileSketch
                part = QuantileSketch.from_entry(e)
                # per-replica labeled copy ...
                sk = out.sketch(e["name"], alpha=part.alpha,
                                **{**e["labels"], **extra_labels})
                sk.merge(part)
                # ... plus the exact bucket-wise merge into the combined
                # (replica-less) instrument: its percentiles equal a single
                # sketch that saw every replica's observations
                if "replica" in extra_labels:
                    out.sketch(e["name"], alpha=part.alpha,
                               **e["labels"]).merge(part)

        for i, eng in enumerate(self._router.replicas):
            copy_from(eng.metrics, {"replica": str(i)})
        copy_from(self._router._registry, {})
        return out

    def snapshot(self, *, meta: bool = True) -> dict:
        return self._merged().snapshot(meta=meta)

    def to_prometheus(self) -> str:
        return self._merged().to_prometheus()

    def write(self, path: str):
        self._merged().write(path)

    @property
    def trace(self):
        # router-level trace (replica traces stay on their registries)
        return self._router._registry.trace


class ReplicaRouter(EngineBase):
    """Round-robin data-parallel front over N serving engines.

    Build with :func:`make_replicas` (or any list of protocol-speaking
    engines).  ``step`` ticks every replica; ``run_until_drained`` drains
    them all.  ``completed`` concatenates in replica order (stable for
    tests: uid ``k`` lands on replica ``k % N`` under pure round-robin).
    """

    def __init__(self, replicas: List):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        self.replicas = list(replicas)
        # stamp each engine with its replica id so every trace context it
        # mints (and so every event) carries replica=<i>
        for i, eng in enumerate(self.replicas):
            if hasattr(eng, "set_replica"):
                eng.set_replica(i)
        self._rr = 0
        self._registry = MetricsRegistry()
        self.metrics = _MergedMetrics(self)
        n = len(self.replicas)
        self._m_routed = self._registry.counter(
            "serve_router_requests_total", help="requests routed to replicas")
        self._registry.gauge(
            "serve_router_replicas", help="engine replicas behind the router"
        ).set(n)
        self._m_slots = [self._registry.gauge(
            "serve_replica_slots_active",
            help="occupied decode slots per replica", replica=str(i))
            for i in range(n)]
        self._m_tps = [self._registry.gauge(
            "serve_replica_tokens_per_second",
            help="decode throughput per replica over the last drain window",
            replica=str(i)) for i in range(n)]

    # -- engine protocol ----------------------------------------------------

    def submit(self, req):
        eng = self.replicas[self._rr]
        self._rr = (self._rr + 1) % len(self.replicas)
        self._m_routed.inc()
        eng.submit(req)

    def step(self) -> int:
        n_active = 0
        for i, eng in enumerate(self.replicas):
            n = eng.step()
            self._m_slots[i].set(n)
            n_active += n
        return n_active

    def run_until_drained(self, max_ticks: int = 10000):
        ticks = 0
        for i, eng in enumerate(self.replicas):
            ticks = max(ticks, eng.run_until_drained(max_ticks))
            self._m_slots[i].set(0)
            tps = getattr(eng, "_m_tps", None)
            if tps is not None:
                self._m_tps[i].set(tps.value)
        return ticks

    @property
    def completed(self) -> list:
        return [r for eng in self.replicas for r in eng.completed]

    @property
    def queue_depth(self) -> int:
        def depth(eng):
            q = getattr(eng, "queue", None)
            if q is not None:
                return len(q)
            sched = getattr(eng, "sched", None)
            return len(sched) if sched is not None else 0
        return sum(depth(e) for e in self.replicas)


def make_replicas(n: int, factory: Callable[[MetricsRegistry], object]
                  ) -> ReplicaRouter:
    """Build N replicas through ``factory(metrics_registry)`` — the factory
    must pass the registry to the engine it builds (each replica gets its
    own, so the merged snapshot can label families per replica) — and wrap
    them in a :class:`ReplicaRouter`."""
    return ReplicaRouter([factory(MetricsRegistry()) for _ in range(n)])
