"""Convert repro.obs JSONL traces into Perfetto/Chrome trace JSON.

The JSONL event trace (``--trace-out``, :mod:`repro.obs.trace`) is
trace-id-correlated: every event emitted on a request's behalf carries the
owning request's ``trace_id`` (spliced by :mod:`repro.obs.context`).  This
module renders those events in the Chrome trace event format — one virtual
*thread* per request, span events as ``"ph": "X"`` complete events, point
events as instants — which ``https://ui.perfetto.dev`` (or
``chrome://tracing``) opens directly::

    python -m repro.launch.serve ... --trace-out serve_trace.jsonl
    python -m repro.obs.export serve_trace.jsonl -o serve_perfetto.json

``--check`` additionally validates trace-context propagation (the CI
``metrics-smoke`` gate): every kernel-dispatch, scheduler, prefill-chunk,
and spec event must carry a ``trace_id`` introduced by some
``request_submit`` — a regression here means a dispatch path lost its
request attribution.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

__all__ = [
    "check_propagation", "load_events", "span_trees", "to_chrome_trace",
]

# Events that MUST be attributable to a submitted request (--check).
# kernel_dispatch fires at jit-trace time under the dispatching request's
# context; the request_*/prefill_/spec_ families are emitted by the engines
# with explicit trace_id attrs.
CHECKED_PREFIXES = ("kernel_dispatch", "request", "prefill_", "spec_")


def load_events(path: str) -> Tuple[Optional[dict], List[dict]]:
    """Read a JSONL trace; returns ``(header, events)`` where ``header`` is
    the ``_trace_header`` drop marker if present (else None)."""
    header, events = None, []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("name") == "_trace_header":
                header = rec
            else:
                events.append(rec)
    return header, events


def _subsystem(name: str) -> str:
    from repro.obs.recorder import subsystem_of
    return subsystem_of(name)


def to_chrome_trace(events: List[dict]) -> dict:
    """Chrome trace event format: ``pid`` = replica (0 when unlabeled),
    one ``tid`` per ``trace_id`` (tid 0 collects unattributed events),
    spans as complete ("X") events, points as thread-scoped instants."""
    if events:
        t0 = min(float(e["ts"]) for e in events)
    else:
        t0 = 0.0
    tids: Dict[str, int] = {}
    tid_meta: Dict[Tuple[int, int], str] = {}
    out: List[dict] = []

    def tid_of(e) -> int:
        trace_id = e.get("trace_id")
        if trace_id is None:
            return 0
        if trace_id not in tids:
            tids[trace_id] = len(tids) + 1
        return tids[trace_id]

    for e in events:
        name = str(e.get("name", "?"))
        pid = int(e.get("replica", 0) or 0)
        tid = tid_of(e)
        if tid != 0 and (pid, tid) not in tid_meta:
            uid = e.get("uid")
            label = f"req uid={uid} " if uid is not None else "req "
            tid_meta[(pid, tid)] = label + str(e.get("trace_id"))
        args = {k: v for k, v in e.items()
                if k not in ("name", "ts", "wall", "ph", "dur")}
        base = {"name": name, "cat": _subsystem(name), "pid": pid,
                "tid": tid, "ts": (float(e["ts"]) - t0) * 1e6, "args": args}
        if e.get("ph") == "span":
            out.append({**base, "ph": "X",
                        "dur": float(e.get("dur", 0.0)) * 1e6})
        else:
            out.append({**base, "ph": "i", "s": "t"})
    for (pid, tid), label in sorted(tid_meta.items()):
        out.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": tid, "args": {"name": label}})
    for pid in sorted({ev["pid"] for ev in out}):
        out.append({"ph": "M", "name": "process_name", "pid": pid,
                    "args": {"name": f"replica {pid}"}})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def span_trees(events: List[dict]) -> Dict[str, List[dict]]:
    """Events grouped per ``trace_id`` in timestamp order — the per-request
    span tree (submit → admit → prefill chunks → draft/verify → complete,
    including preempt/resume)."""
    trees: Dict[str, List[dict]] = {}
    for e in events:
        trace_id = e.get("trace_id")
        if trace_id is not None:
            trees.setdefault(trace_id, []).append(e)
    for tree in trees.values():
        tree.sort(key=lambda e: float(e["ts"]))
    return trees


def check_propagation(events: List[dict]) -> List[str]:
    """Validate that every checked event carries a trace_id introduced by a
    ``request_submit``; returns human-readable violations (empty = pass)."""
    known = {e["trace_id"] for e in events
             if e.get("name") == "request_submit" and "trace_id" in e}
    problems: List[str] = []
    checked = 0
    for i, e in enumerate(events):
        name = str(e.get("name", ""))
        if not name.startswith(CHECKED_PREFIXES):
            continue
        checked += 1
        trace_id = e.get("trace_id")
        if trace_id is None:
            problems.append(f"event #{i} {name!r}: missing trace_id")
        elif trace_id not in known:
            problems.append(
                f"event #{i} {name!r}: trace_id {trace_id!r} not "
                f"introduced by any request_submit")
    if checked == 0:
        problems.append(
            "no checked events found (expected at least request_submit "
            "lifecycle events in a serve trace)")
    if not known:
        problems.append("no request_submit events with trace_id found")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Convert a repro.obs JSONL trace to Perfetto/Chrome "
                    "trace JSON; --check gates trace-context propagation.")
    ap.add_argument("trace", help="input JSONL trace (--trace-out file)")
    ap.add_argument("-o", "--out", default=None,
                    help="output Chrome-trace JSON path "
                         "(default: <trace>.perfetto.json)")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) unless every kernel_dispatch/"
                         "scheduler/spec event carries a known request "
                         "trace_id")
    args = ap.parse_args(argv)

    header, events = load_events(args.trace)
    if header is not None:
        print(f"note: trace ring dropped {header.get('dropped')} events "
              f"before this dump", file=sys.stderr)

    out_path = args.out or (args.trace + ".perfetto.json")
    chrome = to_chrome_trace(events)
    with open(out_path, "w") as f:
        json.dump(chrome, f)
    trees = span_trees(events)
    print(f"wrote {out_path}: {len(chrome['traceEvents'])} trace events, "
          f"{len(trees)} request span trees")

    if args.check:
        problems = check_propagation(events)
        if problems:
            for p in problems[:20]:
                print(f"check: {p}", file=sys.stderr)
            extra = len(problems) - 20
            if extra > 0:
                print(f"check: ... and {extra} more", file=sys.stderr)
            return 1
        print(f"check: OK — {len(trees)} traces, all checked events "
              f"carry a known request trace_id")
    return 0


if __name__ == "__main__":
    sys.exit(main())
