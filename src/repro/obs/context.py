"""Request-scoped trace context, propagated via ``contextvars``.

A :class:`TraceContext` names the request a piece of work belongs to
(``trace_id``), the span doing the work (``span_id`` / ``parent_id``), and
topology attribution labels (``replica`` / ``tp_shard`` / ``pp_stage``).
The serving engines create a root context at ``submit()`` and re-enter it
(:func:`use`) around every dispatch done on the request's behalf — chunked
prefill steps, batched decode / draft / verify programs — so events emitted
*anywhere below* (``kernel_dispatch`` at jit-trace time, autotune and
tune-cache events, scheduler events) inherit the owning request's
``trace_id`` without any of those layers knowing about requests.

:class:`~repro.obs.trace.EventTrace` splices :func:`current` into every
event whose explicit attrs don't already carry a ``trace_id``, which is the
only coupling point; everything else is plain ``contextvars`` so the
context survives threads started with ``contextvars.copy_context`` and
nested ``with use(...)`` blocks restore the outer context on exit.

Batched dispatches serve several requests at once; the engines attribute
the *dispatch* to the first active lane's context and additionally emit
per-lane events with explicit ``trace_id`` attrs, so per-request span trees
stay complete while the kernel-level events remain single-parented.
"""

from __future__ import annotations

import contextlib
import contextvars
import itertools
import os
import threading
from typing import Dict, Iterator, Optional, Tuple

__all__ = [
    "TraceContext", "current", "current_context", "new_span_id",
    "new_trace_id", "use",
]

_counter = itertools.count(1)
_counter_lock = threading.Lock()


def _next() -> int:
    with _counter_lock:
        return next(_counter)


def new_trace_id() -> str:
    """Process-unique trace id (pid-salted so DP replica processes and
    multi-host runs don't collide when traces are merged offline)."""
    return f"t{os.getpid():x}-{_next():x}"


def new_span_id() -> str:
    return f"s{_next():x}"


class TraceContext:
    """Immutable (trace_id, span_id, parent_id, labels) tuple-alike."""

    __slots__ = ("trace_id", "span_id", "parent_id", "labels")

    def __init__(self, trace_id: str, span_id: Optional[str] = None,
                 parent_id: Optional[str] = None,
                 labels: Tuple[Tuple[str, str], ...] = ()):
        self.trace_id = trace_id
        self.span_id = span_id or new_span_id()
        self.parent_id = parent_id
        self.labels = tuple(labels)

    @classmethod
    def root(cls, trace_id: Optional[str] = None,
             **labels) -> "TraceContext":
        """A new root span; fresh ``trace_id`` unless one is supplied."""
        lk = tuple(sorted((k, str(v)) for k, v in labels.items()
                          if v is not None))
        return cls(trace_id or new_trace_id(), labels=lk)

    def child(self, **labels) -> "TraceContext":
        """A child span under this one (same trace, new span id)."""
        lk = dict(self.labels)
        lk.update((k, str(v)) for k, v in labels.items() if v is not None)
        return TraceContext(self.trace_id, new_span_id(), self.span_id,
                            tuple(sorted(lk.items())))

    def with_labels(self, **labels) -> "TraceContext":
        """Same span, extra attribution labels (replica / tp / pp)."""
        lk = dict(self.labels)
        lk.update((k, str(v)) for k, v in labels.items() if v is not None)
        return TraceContext(self.trace_id, self.span_id, self.parent_id,
                            tuple(sorted(lk.items())))

    def attrs(self) -> Dict[str, str]:
        """The event attrs this context contributes (spliced by
        ``EventTrace.event`` when not explicitly present)."""
        out = {"trace_id": self.trace_id, "span_id": self.span_id}
        if self.parent_id is not None:
            out["parent_id"] = self.parent_id
        out.update(self.labels)
        return out

    def __repr__(self):
        return (f"TraceContext(trace_id={self.trace_id!r}, "
                f"span_id={self.span_id!r}, parent_id={self.parent_id!r}, "
                f"labels={dict(self.labels)!r})")

    def __eq__(self, other):
        return (isinstance(other, TraceContext)
                and self.trace_id == other.trace_id
                and self.span_id == other.span_id
                and self.parent_id == other.parent_id
                and self.labels == other.labels)

    def __hash__(self):
        return hash((self.trace_id, self.span_id, self.parent_id,
                     self.labels))


_current: contextvars.ContextVar[Optional[TraceContext]] = \
    contextvars.ContextVar("repro_obs_trace_context", default=None)


def current() -> Optional[TraceContext]:
    """The active :class:`TraceContext`, or None outside any request."""
    return _current.get()


@contextlib.contextmanager
def use(ctx: Optional[TraceContext]) -> Iterator[Optional[TraceContext]]:
    """Enter ``ctx`` for the dynamic extent of the block (None = no-op,
    so call sites don't need to branch on 'is tracing attributed here')."""
    if ctx is None:
        yield None
        return
    token = _current.set(ctx)
    try:
        yield ctx
    finally:
        _current.reset(token)


current_context = current   # re-exported as ``repro.obs.current_context``
