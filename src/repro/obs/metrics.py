"""Dependency-free metrics primitives: counters, gauges, histograms.

A :class:`MetricsRegistry` owns named metric *families*; a family plus one
label set is one instrument.  Instruments are cheap handles (plain Python
objects sharing the registry lock), so hot paths fetch them once and call
``inc()``/``set()``/``observe()`` per event — the serve tick observes a few
histograms per step, which is noise next to a jitted decode step.

Two exporters render the same registry state:

* :meth:`MetricsRegistry.snapshot` — a JSON-able dict (``meta`` +
  ``counters``/``gauges``/``histograms`` entry lists), the format written by
  ``launch/serve.py --metrics-out`` and validated by
  ``benchmarks/validate_metrics.py`` against
  ``benchmarks/metrics_schema.json``.
* :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition
  (``# TYPE`` lines, ``{label="value"}`` pairs, cumulative ``_bucket{le=}``
  histogram series).

Histograms use *fixed* buckets declared at first registration (default:
:data:`DEFAULT_TIME_BUCKETS`, exponential 100µs…60s — decode ticks, queue
waits, and train steps all land mid-range).  Fixed buckets keep ``observe``
O(log buckets) with no allocation and make snapshots mergeable across
processes.

The fourth family kind is the quantile **sketch**
(:class:`~repro.obs.sketch.QuantileSketch`, DDSketch-style): registered via
``registry.sketch(name, alpha=..., **labels)``, exported in the snapshot
under ``"sketches"`` and as Prometheus summary-style quantile series, and
*exactly* mergeable — the DP replica router merges per-replica sketches
into combined percentiles identical to a single sketch over all
observations.  Serving latency percentiles (TTFT / per-token decode / e2e)
report through sketches; the fixed-bucket histogram instruments stay for
dashboard compatibility and cheap rate queries.
"""

from __future__ import annotations

import json
import os
import threading
import time
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.sketch import DEFAULT_ALPHA, QuantileSketch

# Exponential-ish time buckets in seconds: 100µs .. 60s.  Decode ticks on
# CPU land around 1-100ms, train steps 10ms-10s, queue waits anywhere.
DEFAULT_TIME_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)

_KINDS = ("counter", "gauge", "histogram", "sketch")

# Quantiles rendered in the Prometheus exposition for sketch families.
SKETCH_QUANTILES = (0.5, 0.9, 0.99)


def run_metadata() -> dict:
    """Host/platform/version stamp shared by metrics snapshots and the
    benchmark JSONs, so artifacts from different machines are comparable."""
    import platform as _platform
    import socket

    import jax

    return {
        "host": socket.gethostname(),
        "platform": jax.default_backend(),
        "jax": jax.__version__,
        "python": _platform.python_version(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }


class Counter:
    """Monotonically increasing count (use a Gauge for values that go down)."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self.value = 0

    def inc(self, n: int = 1):
        if n < 0:
            raise ValueError(f"counters only go up; inc({n})")
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins instantaneous value (slot occupancy, tokens/sec)."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self.value = 0.0

    def set(self, v: float):
        with self._lock:
            self.value = float(v)

    def inc(self, n: float = 1.0):
        with self._lock:
            self.value += n

    def dec(self, n: float = 1.0):
        self.inc(-n)


class Histogram:
    """Fixed-bucket histogram; ``counts[i]`` counts observations with
    ``value <= buckets[i]`` (exclusive of earlier buckets); ``counts[-1]``
    is the +Inf overflow bucket."""

    __slots__ = ("_lock", "buckets", "counts", "sum", "count")

    def __init__(self, lock: threading.RLock, buckets: Sequence[float]):
        self._lock = lock
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"histogram buckets must be strictly "
                             f"increasing, got {buckets}")
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        v = float(v)
        i = bisect_left(self.buckets, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def cumulative(self) -> List[int]:
        """Cumulative ``le`` counts (Prometheus semantics), +Inf last."""
        out, acc = [], 0
        with self._lock:
            for c in self.counts:
                acc += c
                out.append(acc)
        return out


class _Family:
    __slots__ = ("kind", "help", "buckets", "alpha", "children")

    def __init__(self, kind: str, help_text: str,
                 buckets: Optional[Tuple[float, ...]],
                 alpha: Optional[float] = None):
        self.kind = kind
        self.help = help_text
        self.buckets = buckets
        self.alpha = alpha
        self.children: Dict[Tuple[Tuple[str, str], ...], object] = {}


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n",
                                                                    "\\n")


def _label_str(labels: Dict[str, str], extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in sorted(items.items()))
    return "{" + body + "}"


class MetricsRegistry:
    """Thread-safe registry of counter/gauge/histogram families.

    ``registry.counter(name, **labels)`` registers on first use and returns
    the same instrument for the same (name, labels) afterwards; a name can
    hold only one kind.  The registry also owns an
    :class:`~repro.obs.trace.EventTrace` (``registry.trace``) so one object
    threads both numeric metrics and the JSONL event stream through a
    subsystem.
    """

    def __init__(self, trace=None):
        self._lock = threading.RLock()
        self._families: Dict[str, _Family] = {}
        if trace is None:
            from repro.obs.trace import EventTrace
            trace = EventTrace()
        self.trace = trace
        # Surface ring overflow as a counter — registered lazily on the
        # first actual drop so registries that never overflow stay clean.
        if getattr(trace, "on_drop", None) is None:
            trace.on_drop = lambda n: self.counter(
                "trace_events_dropped_total",
                help="trace events evicted from the bounded ring").inc(n)

    # -- registration / lookup ----------------------------------------------

    def _get(self, kind: str, name: str, help_text: str,
             labels: Dict[str, str],
             buckets: Optional[Sequence[float]] = None,
             alpha: Optional[float] = None):
        lk = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(kind, help_text,
                              tuple(buckets) if buckets else None, alpha)
                self._families[name] = fam
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"cannot re-register as {kind}")
            child = fam.children.get(lk)
            if child is None:
                if kind == "counter":
                    child = Counter(self._lock)
                elif kind == "gauge":
                    child = Gauge(self._lock)
                elif kind == "sketch":
                    child = QuantileSketch(self._lock,
                                           alpha=fam.alpha or DEFAULT_ALPHA)
                else:
                    child = Histogram(self._lock,
                                      fam.buckets or DEFAULT_TIME_BUCKETS)
                fam.children[lk] = child
            return child

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        """``buckets`` is honored on first registration of ``name``; later
        calls reuse the family's fixed buckets (snapshots stay mergeable)."""
        return self._get("histogram", name, help, labels, buckets)

    def sketch(self, name: str, help: str = "",
               alpha: Optional[float] = None, **labels) -> QuantileSketch:
        """A mergeable quantile sketch (DDSketch-style; see
        :mod:`repro.obs.sketch`).  ``alpha`` (relative-error bound) is
        honored on first registration of ``name``; later calls reuse the
        family's alpha so per-replica sketches stay exactly mergeable."""
        return self._get("sketch", name, help, labels, alpha=alpha)

    def reset(self, *, clear_trace: bool = True):
        """Drop every family (tests / fresh measurement windows)."""
        with self._lock:
            self._families.clear()
        if clear_trace:
            self.trace.clear()

    # -- exporters ----------------------------------------------------------

    def snapshot(self, *, meta: bool = True) -> dict:
        counters, gauges, hists, sketches = [], [], [], []
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                for lk in sorted(fam.children):
                    child = fam.children[lk]
                    entry = {"name": name, "labels": dict(lk)}
                    if fam.kind == "counter":
                        counters.append({**entry, "value": child.value})
                    elif fam.kind == "gauge":
                        gauges.append({**entry, "value": child.value})
                    elif fam.kind == "sketch":
                        sketches.append({**entry, **child.to_entry()})
                    else:
                        hists.append({**entry,
                                      "buckets": list(child.buckets),
                                      "counts": list(child.counts),
                                      "sum": child.sum,
                                      "count": child.count})
        out = {"counters": counters, "gauges": gauges, "histograms": hists,
               "sketches": sketches}
        if meta:
            out["meta"] = run_metadata()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                if fam.help:
                    lines.append(f"# HELP {name} {fam.help}")
                # sketches render as Prometheus summaries (quantile series)
                kind = "summary" if fam.kind == "sketch" else fam.kind
                lines.append(f"# TYPE {name} {kind}")
                for lk in sorted(fam.children):
                    child = fam.children[lk]
                    labels = dict(lk)
                    ls = _label_str(labels)
                    if fam.kind in ("counter", "gauge"):
                        lines.append(f"{name}{ls} {child.value:g}")
                        continue
                    if fam.kind == "sketch":
                        for q in SKETCH_QUANTILES:
                            v = child.quantile(q)
                            if v is not None:
                                lines.append(
                                    f"{name}"
                                    f"{_label_str(labels, {'quantile': f'{q:g}'})}"
                                    f" {v:g}")
                        lines.append(f"{name}_sum{ls} {child.sum:g}")
                        lines.append(f"{name}_count{ls} {child.count}")
                        continue
                    cum = child.cumulative()
                    for b, c in zip(child.buckets, cum):
                        lines.append(
                            f"{name}_bucket"
                            f"{_label_str(labels, {'le': f'{b:g}'})} {c}")
                    lines.append(
                        f"{name}_bucket"
                        f"{_label_str(labels, {'le': '+Inf'})} {cum[-1]}")
                    lines.append(f"{name}_sum{ls} {child.sum:g}")
                    lines.append(f"{name}_count{ls} {child.count}")
        return "\n".join(lines) + "\n"

    def write(self, path: str):
        """Write a snapshot; ``.prom``/``.txt`` suffixes select Prometheus
        text exposition, anything else the JSON snapshot."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        if path.endswith((".prom", ".txt")):
            blob = self.to_prometheus()
        else:
            blob = json.dumps(self.snapshot(), indent=2)
        with open(path, "w") as f:
            f.write(blob)

    def __len__(self):
        with self._lock:
            return sum(len(f.children) for f in self._families.values())


_default: Optional[MetricsRegistry] = None
_default_lock = threading.Lock()


def default_registry() -> MetricsRegistry:
    """Process-wide registry — the one the kernel dispatch path, the tuning
    cache, and the launch drivers share (mirrors ``tune.default_cache``)."""
    global _default
    with _default_lock:
        if _default is None:
            _default = MetricsRegistry()
        return _default


def set_default_registry(reg: Optional[MetricsRegistry]):
    """Swap the process-wide registry (tests; isolated measurement runs)."""
    global _default
    with _default_lock:
        _default = reg
