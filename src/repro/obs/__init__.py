"""``repro.obs`` — metrics, event tracing, structured logging, profiling.

The observability layer every perf claim in this repo is judged against
(DESIGN.md §12).  Dependency-free (stdlib + jax only):

* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` with counters, gauges,
  and fixed-bucket histograms; JSON-snapshot and Prometheus-text exporters.
* :mod:`repro.obs.trace`   — JSONL event trace (:class:`Span` / ``event()``
  with monotonic timestamps), attached to each registry as ``.trace``.
* :mod:`repro.obs.log`     — level-filtered structured logger (text or JSON
  lines) used by the ``launch/`` drivers.
* :mod:`repro.obs.profile` — opt-in kernel profiling: ``annotate(name)``
  names DeMM kernels in profiler traces, ``profile(trace_dir)`` dumps a
  jax profiler trace directory for TensorBoard/perfetto.

The process-wide default registry (:func:`metrics`) is what the kernel
dispatch counters, the tuning-cache hit/miss counters, the serve engine, and
the training supervisor share by default, so ``launch/serve.py
--metrics-out metrics.json`` captures one coherent snapshot across all four
subsystems.  Tests (and anything wanting isolation) construct their own
:class:`MetricsRegistry` or swap the default with
:func:`set_default_registry`.
"""

from __future__ import annotations

from repro.obs.log import LEVELS, StructuredLogger, get_logger
from repro.obs.metrics import (
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    run_metadata,
    set_default_registry,
)
from repro.obs.profile import annotate, profile, profiling_active
from repro.obs.trace import EventTrace, Span

__all__ = [
    "DEFAULT_TIME_BUCKETS", "Counter", "EventTrace", "Gauge", "Histogram",
    "LEVELS", "MetricsRegistry", "Span", "StructuredLogger", "annotate",
    "default_registry", "event", "get_logger", "metrics", "profile",
    "profiling_active", "run_metadata", "set_default_registry",
]


def metrics() -> MetricsRegistry:
    """The process-wide default :class:`MetricsRegistry` (see module doc)."""
    return default_registry()


def event(name: str, **attrs) -> dict:
    """Record a point event on the default registry's trace."""
    return default_registry().trace.event(name, **attrs)
