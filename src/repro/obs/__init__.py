"""``repro.obs`` — metrics, event tracing, structured logging, profiling.

The observability layer every perf claim in this repo is judged against
(DESIGN.md §12).  Dependency-free (stdlib + jax only):

* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` with counters, gauges,
  and fixed-bucket histograms; JSON-snapshot and Prometheus-text exporters.
* :mod:`repro.obs.trace`   — JSONL event trace (:class:`Span` / ``event()``
  with monotonic timestamps), attached to each registry as ``.trace``.
* :mod:`repro.obs.log`     — level-filtered structured logger (text or JSON
  lines) used by the ``launch/`` drivers.
* :mod:`repro.obs.profile` — opt-in kernel profiling: ``annotate(name)``
  names DeMM kernels in profiler traces, ``profile(trace_dir)`` dumps a
  jax profiler trace directory for TensorBoard/perfetto.

Observability v2 (DESIGN.md §16) adds:

* :mod:`repro.obs.context`  — contextvar trace context (``trace_id`` /
  span ids / attribution labels) created per request at ``submit()`` and
  spliced into every trace event emitted on the request's behalf.
* :mod:`repro.obs.sketch`   — :class:`QuantileSketch`, a DDSketch-style
  mergeable relative-error quantile sketch; fourth registry family kind.
* :mod:`repro.obs.slo`      — per-request phase attribution, goodput /
  wasted-token accounting, SLO pass-fail reports.
* :mod:`repro.obs.recorder` — :class:`FlightRecorder` (bounded
  per-subsystem event rings + stall watchdogs + crash/signal dumps).
* :mod:`repro.obs.export`   — JSONL trace → Perfetto/Chrome trace JSON
  (``python -m repro.obs.export``).

The process-wide default registry (:func:`metrics`) is what the kernel
dispatch counters, the tuning-cache hit/miss counters, the serve engine, and
the training supervisor share by default, so ``launch/serve.py
--metrics-out metrics.json`` captures one coherent snapshot across all four
subsystems.  Tests (and anything wanting isolation) construct their own
:class:`MetricsRegistry` or swap the default with
:func:`set_default_registry`.
"""

from __future__ import annotations

from repro.obs.context import TraceContext, current_context, new_trace_id
from repro.obs.context import use as use_context
from repro.obs.log import LEVELS, StructuredLogger, get_logger
from repro.obs.metrics import (
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    run_metadata,
    set_default_registry,
)
from repro.obs.profile import annotate, profile, profiling_active
from repro.obs.recorder import FlightRecorder, Watchdog
from repro.obs.sketch import QuantileSketch
from repro.obs.slo import SLOConfig, phase_sketches, request_phases, slo_report
from repro.obs.trace import EventTrace, Span

__all__ = [
    "DEFAULT_TIME_BUCKETS", "Counter", "EventTrace", "FlightRecorder",
    "Gauge", "Histogram", "LEVELS", "MetricsRegistry", "QuantileSketch",
    "SLOConfig", "Span", "StructuredLogger", "TraceContext", "Watchdog",
    "annotate", "current_context", "default_registry", "event",
    "get_logger", "metrics", "new_trace_id", "phase_sketches", "profile",
    "profiling_active", "request_phases", "run_metadata",
    "set_default_registry", "slo_report", "use_context",
]


def metrics() -> MetricsRegistry:
    """The process-wide default :class:`MetricsRegistry` (see module doc)."""
    return default_registry()


def event(name: str, **attrs) -> dict:
    """Record a point event on the default registry's trace."""
    return default_registry().trace.event(name, **attrs)
