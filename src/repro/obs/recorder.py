"""Flight recorder: bounded per-subsystem event rings, stall watchdogs,
and crash/signal dumps.

When a serving engine wedges (a deadlocked collective, a runaway compile, a
scheduler live-lock) the interesting evidence is the last few hundred
events *before* the hang — exactly what a post-mortem restart loses.  The
:class:`FlightRecorder` taps the registry's
:class:`~repro.obs.trace.EventTrace` (``trace.tap``) and routes every event
into a small per-subsystem ring (``serve`` / ``kernels`` / ``tune`` /
``train`` / ``misc``), so a dump is cheap, bounded, and still contains each
subsystem's recent history even when one of them is noisy.

Stall detection (:class:`Watchdog`): the instrumented loop calls
``beat()`` once per engine tick / supervisor step; a background thread
compares the time since the last beat against ``threshold ×`` an EWMA of
recent beat intervals (the same EWMA idiom as
:class:`~repro.train.fault_tolerance.StragglerMonitor`), floored at
``min_stall_s`` so microsecond ticks don't make the threshold trigger on
scheduling jitter.  One dump is produced per stall episode (re-armed by
the next beat).

A dump (``dump(reason)``) is a directory under the recorder's
``flight_dir``::

    flight-0001-stall-serve_tick/
        rings.json      # {subsystem: [event, ...]} — most recent last
        metrics.json    # full MetricsRegistry snapshot at dump time
        meta.json       # run metadata + reason + watchdog states

Crash dumps: wrap the serving loop in ``with recorder.guard():`` —
any exception dumps ``reason="crash"`` before propagating.  Signal dumps:
``install_signal_handlers()`` chains SIGTERM/SIGINT to a dump.  Normal
shutdown calls ``close()``, which stops the watchdog threads so a clean
exit never produces a spurious stall dump.
"""

from __future__ import annotations

import contextlib
import itertools
import json
import os
import signal
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

__all__ = ["FlightRecorder", "Watchdog", "subsystem_of"]

DEFAULT_RING_SIZE = 512

# event-name prefix → subsystem ring (first match wins; order matters:
# spec/prefill/request are serve-side, kernel dispatch is its own ring so
# noisy compile bursts don't evict scheduler history)
_SUBSYSTEM_PREFIXES = (
    ("kernel_", "kernels"),
    ("autotune_", "tune"),
    ("tune_", "tune"),
    ("checkpoint_", "train"),
    ("train_", "train"),
    ("restart", "train"),
    ("straggler", "train"),
    ("request_", "serve"),
    ("request", "serve"),
    ("serve_", "serve"),
    ("spec_", "serve"),
    ("prefill_", "serve"),
)


def subsystem_of(name: str) -> str:
    for prefix, subsystem in _SUBSYSTEM_PREFIXES:
        if name.startswith(prefix):
            return subsystem
    return "misc"


class Watchdog:
    """Detects a stalled loop from missing ``beat()`` calls.

    Armed after the *second* beat (the first interval is dominated by
    unbounded jit-compile time, so one beat is not enough to call silence
    a stall); stalled when the time since the last beat exceeds
    ``max(threshold * ewma(beat interval), min_stall_s)``.  Fires
    ``on_stall(self)`` once per episode from a daemon poll thread.
    """

    EWMA_ALPHA = 0.3   # matches StragglerMonitor's smoothing

    def __init__(self, name: str, on_stall: Callable[["Watchdog"], None],
                 *, threshold: float = 8.0, min_stall_s: float = 1.0,
                 poll_s: float = 0.05):
        self.name = name
        self.threshold = float(threshold)
        self.min_stall_s = float(min_stall_s)
        self._on_stall = on_stall
        self._lock = threading.Lock()
        self._last: Optional[float] = None
        self._ewma: Optional[float] = None
        self.beats = 0
        self.stalls = 0
        self._fired = False          # one dump per stall episode
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._poll_loop, args=(poll_s,),
            name=f"watchdog-{name}", daemon=True)
        self._thread.start()

    def beat(self):
        now = time.monotonic()
        with self._lock:
            if self._last is not None:
                dt = now - self._last
                self._ewma = dt if self._ewma is None else (
                    self.EWMA_ALPHA * dt
                    + (1.0 - self.EWMA_ALPHA) * self._ewma)
            self._last = now
            self.beats += 1
            self._fired = False      # re-arm: the loop is alive again

    def stall_after(self) -> float:
        """Seconds of beat silence that count as a stall right now."""
        with self._lock:
            ewma = self._ewma or 0.0
        return max(self.threshold * ewma, self.min_stall_s)

    def check(self, now: Optional[float] = None) -> bool:
        """True iff currently stalled (armed + beat silence past the
        threshold).  Exposed for deterministic tests; the poll thread calls
        this too."""
        now = time.monotonic() if now is None else now
        with self._lock:
            # armed only once an interval estimate exists (>= 2 beats):
            # the first interval is unbounded jit-compile time, which a
            # single-beat arm would misread as a stall
            if self._last is None or self._ewma is None or self._fired:
                return False
            ewma = self._ewma
            stalled = (now - self._last) > max(self.threshold * ewma,
                                               self.min_stall_s)
            if stalled:
                self._fired = True
                self.stalls += 1
        return stalled

    def _poll_loop(self, poll_s: float):
        while not self._stop.wait(poll_s):
            if self.check():
                try:
                    self._on_stall(self)
                except Exception:    # noqa: BLE001 — a failing dump must
                    pass             # not kill the watchdog thread

    def state(self) -> dict:
        with self._lock:
            return {"name": self.name, "beats": self.beats,
                    "stalls": self.stalls, "ewma_s": self._ewma,
                    "threshold": self.threshold,
                    "min_stall_s": self.min_stall_s,
                    "last_beat_age_s": (
                        None if self._last is None
                        else time.monotonic() - self._last)}

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2.0)


class FlightRecorder:
    """Bounded per-subsystem rings + watchdogs + dump-on-{stall,crash,signal}.

    One recorder serves a whole process (all engines / the supervisor share
    it via the launch drivers' ``--flight-dir``); ``attach_trace`` taps a
    registry's event stream, ``watchdog(name)`` hands the instrumented loop
    a beat target.
    """

    def __init__(self, flight_dir: str, metrics=None,
                 ring_size: int = DEFAULT_RING_SIZE,
                 watchdog_threshold: float = 8.0):
        self.flight_dir = flight_dir
        self._metrics = metrics
        self.ring_size = int(ring_size)
        self.watchdog_threshold = float(watchdog_threshold)
        self._lock = threading.Lock()
        self.rings: Dict[str, deque] = {}
        self._watchdogs: List[Watchdog] = []
        self.dumps: List[str] = []
        self._dump_event = threading.Event()
        self._seq = itertools.count(1)
        self._closed = False

    # -- event capture ------------------------------------------------------

    def _metrics_registry(self):
        if self._metrics is None:
            from repro import obs
            self._metrics = obs.metrics()
        return self._metrics

    def record(self, subsystem: str, rec: dict):
        with self._lock:
            ring = self.rings.get(subsystem)
            if ring is None:
                ring = self.rings[subsystem] = deque(maxlen=self.ring_size)
            ring.append(rec)

    def _tap(self, rec: dict):
        self.record(subsystem_of(str(rec.get("name", ""))), rec)

    def attach_trace(self, trace):
        """Route every event of ``trace`` into the rings (chains any
        existing tap so multiple consumers compose)."""
        prev = getattr(trace, "tap", None)
        if prev is self._tap:
            return
        if prev is None:
            trace.tap = self._tap
        else:
            def chained(rec, _prev=prev):
                _prev(rec)
                self._tap(rec)
            trace.tap = chained

    # -- watchdogs ----------------------------------------------------------

    def watchdog(self, name: str, *, threshold: Optional[float] = None,
                 min_stall_s: float = 1.0, poll_s: float = 0.05) -> Watchdog:
        """A stall watchdog whose trip dumps a flight directory.
        ``threshold`` defaults to the recorder's ``watchdog_threshold``."""
        if threshold is None:
            threshold = self.watchdog_threshold
        def on_stall(wd: Watchdog):
            self._metrics_registry().counter(
                "obs_watchdog_stalls_total",
                help="stall episodes detected by flight-recorder watchdogs",
                watch=wd.name).inc()
            self.dump(f"stall-{wd.name}")

        wd = Watchdog(name, on_stall, threshold=threshold,
                      min_stall_s=min_stall_s, poll_s=poll_s)
        with self._lock:
            self._watchdogs.append(wd)
        return wd

    # -- dumping ------------------------------------------------------------

    def dump(self, reason: str) -> str:
        """Write rings + metrics snapshot + run metadata; returns the dump
        directory path."""
        from repro.obs.metrics import run_metadata

        safe = "".join(c if c.isalnum() or c in "-_" else "-"
                       for c in reason)
        out = os.path.join(self.flight_dir,
                           f"flight-{next(self._seq):04d}-{safe}")
        os.makedirs(out, exist_ok=True)
        with self._lock:
            rings = {name: list(ring) for name, ring in self.rings.items()}
            watchdogs = [wd.state() for wd in self._watchdogs]
        with open(os.path.join(out, "rings.json"), "w") as f:
            json.dump(rings, f, indent=2, default=str)
        try:
            metrics_snap = self._metrics_registry().snapshot()
        except Exception as e:  # noqa: BLE001 — metrics must not block a dump
            metrics_snap = {"error": f"{type(e).__name__}: {e}"}
        with open(os.path.join(out, "metrics.json"), "w") as f:
            json.dump(metrics_snap, f, indent=2, default=str)
        meta = {**run_metadata(), "reason": reason,
                "watchdogs": watchdogs,
                "ring_sizes": {k: len(v) for k, v in rings.items()}}
        with open(os.path.join(out, "meta.json"), "w") as f:
            json.dump(meta, f, indent=2, default=str)
        try:
            self._metrics_registry().counter(
                "flight_dumps_total", help="flight-recorder dumps written",
                reason=safe).inc()
        except Exception:  # noqa: BLE001
            pass
        with self._lock:
            self.dumps.append(out)
        self._dump_event.set()
        return out

    def wait_for_dump(self, timeout: float) -> bool:
        """Block until at least one dump has been written (forced-stall CI
        leg / tests)."""
        return self._dump_event.wait(timeout)

    @contextlib.contextmanager
    def guard(self):
        """Dump ``reason="crash"`` on any escaping exception."""
        try:
            yield self
        except BaseException as e:
            self.dump(f"crash-{type(e).__name__}")
            raise

    def install_signal_handlers(self, signals=(signal.SIGTERM,)):
        """Dump on delivery of ``signals``, then chain to the previous
        handler (or re-raise the default behavior).  Main thread only."""
        for signum in signals:
            prev = signal.getsignal(signum)

            def handler(num, frame, _prev=prev):
                self.dump(f"signal-{num}")
                if callable(_prev):
                    _prev(num, frame)
                else:
                    signal.signal(num, signal.SIG_DFL)
                    signal.raise_signal(num)

            signal.signal(signum, handler)

    def close(self):
        """Stop watchdog threads (normal shutdown — no stall dump races
        after the loops exit)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            watchdogs = list(self._watchdogs)
        for wd in watchdogs:
            wd.stop()
