"""Opt-in kernel profiling hooks.

``annotate(name)`` wraps a region in ``jax.named_scope`` — zero steady-state
cost: named scopes only exist at trace time, where they stamp the HLO ops
(and therefore the Pallas kernel launches lowered from them) with a
hierarchical name.  The kernel dispatch path wraps every DeMM matmul in
``demm/<op>/<backend>`` scopes, so a TensorBoard/perfetto trace shows which
registry variant each kernel launch came from.

Inside an active :func:`profile` window, ``annotate`` additionally opens a
``jax.profiler.TraceAnnotation`` so host-side work (dispatch, autotune
measurement) shows up on the profiler timeline too.  ``profile(trace_dir)``
brackets the region with ``jax.profiler.start_trace``/``stop_trace`` and
dumps the trace directory for TensorBoard (``tensorboard --logdir
<trace_dir>``) or perfetto::

    with obs.profile("/tmp/serve_trace"):
        engine.run_until_drained()

``launch/serve.py --profile-dir DIR`` is the CLI spelling.
"""

from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def profiling_active() -> bool:
    """True inside a :func:`profile` window (in this thread)."""
    return getattr(_state, "depth", 0) > 0


@contextlib.contextmanager
def profile(trace_dir=None, *, enabled: bool = True):
    """Activate the profiling hooks for the enclosed region.

    With ``trace_dir`` set, a jax profiler trace is captured and dumped
    there (Pallas kernels appear under their ``annotate`` names).  Without
    it, only the host-side ``TraceAnnotation`` behavior of :func:`annotate`
    is switched on — useful when an external profiler is already attached.
    """
    if not enabled:
        yield
        return
    import jax

    if trace_dir:
        jax.profiler.start_trace(str(trace_dir))
    _state.depth = getattr(_state, "depth", 0) + 1
    try:
        yield
    finally:
        _state.depth -= 1
        if trace_dir:
            jax.profiler.stop_trace()


@contextlib.contextmanager
def annotate(name: str):
    """Name the enclosed computation: always a ``jax.named_scope`` (HLO op
    names → named kernels in profiler traces), plus a host
    ``TraceAnnotation`` when a :func:`profile` window is active."""
    import jax

    with jax.named_scope(name):
        if profiling_active():
            with jax.profiler.TraceAnnotation(name):
                yield
        else:
            yield
