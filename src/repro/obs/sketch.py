"""Mergeable relative-error quantile sketch (DDSketch-style, stdlib-only).

Fixed-bucket histograms (PR 6) answer "how many decode ticks were under
25ms" but quantiles read off them are only as good as the bucket edges —
a p99 between 2.5s and 5s is reported as "somewhere in [2.5, 5]".  The
sketch replaces that with a *relative* accuracy guarantee: every quantile
estimate ``q̂`` satisfies ``|q̂ - q| <= alpha * q`` regardless of scale,
using geometrically-spaced buckets ``(γ^(i-1), γ^i]`` with
``γ = (1+α)/(1-α)`` and the index map ``i = ceil(log_γ(v))``.  Buckets are
a sparse dict, so a sketch over µs-to-minutes latencies stays a few hundred
ints.

Sketches are **exactly mergeable**: merging is bucket-wise integer
addition, so merging per-replica sketches in any grouping or order yields
bit-identical bucket state — the DP router's combined percentiles equal
those of one sketch that saw every observation (the property the
exact-merge test in ``tests/test_obs_v2.py`` pins).  Compare histograms,
whose merge is also exact, but whose *accuracy* is fixed by bucket edges;
and t-digests, whose merge is order-dependent.

Values must be >= 0 (these are latencies / sizes); values below
``MIN_VALUE`` (1e-9 s — sub-nanosecond) land in a dedicated zero bucket.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, Optional, Sequence

__all__ = ["DEFAULT_ALPHA", "MIN_VALUE", "QuantileSketch"]

DEFAULT_ALPHA = 0.01   # 1% relative error; ~900 buckets span 1µs..1h
MIN_VALUE = 1e-9


class QuantileSketch:
    """DDSketch-style quantile sketch; thread-safe under the given lock.

    Registered as the fourth :class:`~repro.obs.metrics.MetricsRegistry`
    family kind (``registry.sketch(name, **labels)``); also usable
    standalone (``QuantileSketch()`` makes its own lock).
    """

    __slots__ = ("_lock", "alpha", "gamma", "_log_gamma", "bins",
                 "zero_count", "count", "sum", "min", "max")

    def __init__(self, lock: Optional[threading.RLock] = None,
                 alpha: float = DEFAULT_ALPHA):
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"sketch alpha must be in (0, 1), got {alpha}")
        self._lock = lock if lock is not None else threading.RLock()
        self.alpha = float(alpha)
        self.gamma = (1.0 + alpha) / (1.0 - alpha)
        self._log_gamma = math.log(self.gamma)
        self.bins: Dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- recording ----------------------------------------------------------

    def _index(self, v: float) -> int:
        return math.ceil(math.log(v) / self._log_gamma)

    def observe(self, v: float):
        v = float(v)
        if v < 0.0:
            raise ValueError(f"sketch values must be >= 0, got {v}")
        with self._lock:
            if v <= MIN_VALUE:
                self.zero_count += 1
            else:
                i = self._index(v)
                self.bins[i] = self.bins.get(i, 0) + 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    # -- queries ------------------------------------------------------------

    def quantile(self, q: float) -> Optional[float]:
        """Estimate the ``q``-quantile (0 <= q <= 1); None when empty.
        Relative error <= alpha for values above ``MIN_VALUE``."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            if self.count == 0:
                return None
            rank = q * (self.count - 1)       # 0-indexed rank, nearest-rank
            if rank < self.zero_count:
                return 0.0
            acc = self.zero_count
            for i in sorted(self.bins):
                acc += self.bins[i]
                if acc > rank:
                    # midpoint of (γ^(i-1), γ^i]: relative error <= alpha
                    return 2.0 * self.gamma ** i / (self.gamma + 1.0)
            return self.max                   # numerically unreachable guard

    def quantiles(self, qs: Sequence[float]) -> Dict[float, Optional[float]]:
        return {q: self.quantile(q) for q in qs}

    # -- merge / serialization ----------------------------------------------

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold ``other`` into self (bucket-wise addition; exact).  Both
        sketches must share ``alpha`` — merging across resolutions would
        silently void the error bound."""
        if abs(other.alpha - self.alpha) > 1e-12:
            raise ValueError(
                f"cannot merge sketches with different alpha "
                f"({self.alpha} vs {other.alpha})")
        with self._lock:
            for i, c in other.bins.items():
                self.bins[i] = self.bins.get(i, 0) + c
            self.zero_count += other.zero_count
            self.count += other.count
            self.sum += other.sum
            if other.min < self.min:
                self.min = other.min
            if other.max > self.max:
                self.max = other.max
        return self

    def to_entry(self) -> dict:
        """JSON-able state (the snapshot ``sketches`` entry body)."""
        with self._lock:
            return {
                "alpha": self.alpha,
                "bins": {str(i): c for i, c in sorted(self.bins.items())},
                "zero_count": self.zero_count,
                "count": self.count,
                "sum": self.sum,
                "min": None if self.count == 0 else self.min,
                "max": None if self.count == 0 else self.max,
            }

    @classmethod
    def from_entry(cls, entry: dict,
                   lock: Optional[threading.RLock] = None) -> "QuantileSketch":
        """Rebuild from :meth:`to_entry` output (router merge, bench
        cross-run merge)."""
        sk = cls(lock, alpha=float(entry["alpha"]))
        sk.bins = {int(i): int(c) for i, c in entry.get("bins", {}).items()}
        sk.zero_count = int(entry.get("zero_count", 0))
        sk.count = int(entry.get("count", 0))
        sk.sum = float(entry.get("sum", 0.0))
        sk.min = math.inf if entry.get("min") is None else float(entry["min"])
        sk.max = (-math.inf if entry.get("max") is None
                  else float(entry["max"]))
        return sk

    def copy(self) -> "QuantileSketch":
        return QuantileSketch.from_entry(self.to_entry())

    def __len__(self):
        return self.count

    def __repr__(self):
        return (f"QuantileSketch(alpha={self.alpha}, count={self.count}, "
                f"bins={len(self.bins)})")
