"""JSONL event tracing: point events and duration spans.

An :class:`EventTrace` is an in-memory ring of JSON-able event dicts with
monotonic timestamps, optionally streamed to a JSONL sink as they happen.
Two record shapes:

* point events — ``trace.event("request_submit", uid=3)`` →
  ``{"name": ..., "ts": <monotonic s>, "wall": <epoch s>, ...attrs}``
* spans — ``with trace.span("request", uid=3): ...`` (or manual
  ``s = trace.span(...); ...; s.end()``) → one event with ``"ph": "span"``,
  ``ts`` at span *start*, and ``"dur"`` seconds.

Timestamps come from ``time.monotonic()`` so orderings and durations are
immune to wall-clock steps; ``wall`` is carried for cross-host correlation
only.  The ring is bounded (default 64k events) so a long-running server
cannot grow without limit — attach a file sink (``EventTrace(path=...)`` or
``set_sink``) to keep everything.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Iterator, List, Optional


class Span:
    """A duration measurement; emits one span event on :meth:`end`.

    Usable as a context manager or via explicit ``end()`` (the serve engine
    opens a request span at submit and ends it at completion, ticks apart).
    ``end()`` is idempotent — the first call wins.
    """

    __slots__ = ("_trace", "name", "attrs", "t0", "wall0", "ended")

    def __init__(self, trace: "EventTrace", name: str, attrs: dict):
        self._trace = trace
        self.name = name
        self.attrs = attrs
        self.t0 = time.monotonic()
        self.wall0 = time.time()
        self.ended = False

    def event(self, name: str, **attrs):
        """A point event tagged as belonging to this span."""
        return self._trace.event(name, span=self.name, **{**self.attrs,
                                                          **attrs})

    def end(self, **attrs) -> Optional[dict]:
        if self.ended:
            return None
        self.ended = True
        rec = {"name": self.name, "ph": "span", "ts": self.t0,
               "wall": self.wall0, "dur": time.monotonic() - self.t0,
               **self.attrs, **attrs}
        self._trace._emit(rec)
        return rec

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc):
        self.end()
        return False


class EventTrace:
    """Bounded in-memory event ring with an optional JSONL file sink."""

    def __init__(self, path: Optional[str] = None, max_events: int = 65536):
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=max_events)
        self._file = None
        if path:
            self.set_sink(path)

    # -- recording ----------------------------------------------------------

    def _emit(self, rec: dict):
        with self._lock:
            self._events.append(rec)
            if self._file is not None:
                self._file.write(json.dumps(rec, default=str) + "\n")
                self._file.flush()

    def event(self, name: str, **attrs) -> dict:
        rec = {"name": name, "ts": time.monotonic(), "wall": time.time(),
               **attrs}
        self._emit(rec)
        return rec

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    # -- access / persistence -----------------------------------------------

    @property
    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def named(self, name: str) -> List[dict]:
        return [e for e in self.events if e.get("name") == name]

    def clear(self):
        with self._lock:
            self._events.clear()

    def set_sink(self, path: Optional[str]):
        """Stream every subsequent event to ``path`` as JSON lines (append);
        ``None`` detaches the sink."""
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None
            if path:
                d = os.path.dirname(path)
                if d:
                    os.makedirs(d, exist_ok=True)
                self._file = open(path, "a")

    def write(self, path: str) -> int:
        """Dump the buffered events to ``path`` as JSONL; returns #events.
        (Events already streamed by a sink are not deduplicated — use one
        mechanism or the other per file.)"""
        events = self.events
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            for rec in events:
                f.write(json.dumps(rec, default=str) + "\n")
        return len(events)

    def __len__(self):
        with self._lock:
            return len(self._events)

    def __iter__(self) -> Iterator[dict]:
        return iter(self.events)
