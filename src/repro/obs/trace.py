"""JSONL event tracing: point events and duration spans.

An :class:`EventTrace` is an in-memory ring of JSON-able event dicts with
monotonic timestamps, optionally streamed to a JSONL sink as they happen.
Two record shapes:

* point events — ``trace.event("request_submit", uid=3)`` →
  ``{"name": ..., "ts": <monotonic s>, "wall": <epoch s>, ...attrs}``
* spans — ``with trace.span("request", uid=3): ...`` (or manual
  ``s = trace.span(...); ...; s.end()``) → one event with ``"ph": "span"``,
  ``ts`` at span *start*, and ``"dur"`` seconds.

Timestamps come from ``time.monotonic()`` so orderings and durations are
immune to wall-clock steps; ``wall`` is carried for cross-host correlation
only.  The ring is bounded (default 64k events) so a long-running server
cannot grow without limit — attach a file sink (``EventTrace(path=...)`` or
``set_sink``) to keep everything.  Overflow is *counted*, not silent:
``trace.dropped`` tracks evicted events, an ``on_drop`` callback lets the
owning registry surface it as ``trace_events_dropped_total``, and
:meth:`EventTrace.write` prepends a ``_trace_header`` line whenever events
were lost so offline consumers know the file is a suffix.

Every event additionally splices the active request's
:class:`~repro.obs.context.TraceContext` (``trace_id`` / ``span_id`` /
attribution labels) unless the caller passed an explicit ``trace_id`` —
that one hook is how kernel-dispatch, autotune, and tune-cache events get
correlated to the serving request that triggered them.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Callable, Iterator, List, Optional


def _context_attrs(attrs: dict) -> dict:
    """Attrs contributed by the ambient TraceContext (empty if none or if
    the caller already attributed the event explicitly)."""
    if "trace_id" in attrs:
        return {}
    from repro.obs import context as _context
    ctx = _context.current()
    return ctx.attrs() if ctx is not None else {}


class Span:
    """A duration measurement; emits one span event on :meth:`end`.

    Usable as a context manager or via explicit ``end()`` (the serve engine
    opens a request span at submit and ends it at completion, ticks apart).
    ``end()`` is idempotent — the first call wins.
    """

    __slots__ = ("_trace", "name", "attrs", "t0", "wall0", "ended")

    def __init__(self, trace: "EventTrace", name: str, attrs: dict):
        self._trace = trace
        self.name = name
        self.attrs = {**_context_attrs(attrs), **attrs}
        self.t0 = time.monotonic()
        self.wall0 = time.time()
        self.ended = False

    def event(self, name: str, **attrs):
        """A point event tagged as belonging to this span."""
        return self._trace.event(name, span=self.name, **{**self.attrs,
                                                          **attrs})

    def end(self, **attrs) -> Optional[dict]:
        if self.ended:
            return None
        self.ended = True
        rec = {"name": self.name, "ph": "span", "ts": self.t0,
               "wall": self.wall0, "dur": time.monotonic() - self.t0,
               **self.attrs, **attrs}
        self._trace._emit(rec)
        return rec

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc):
        self.end()
        return False


class EventTrace:
    """Bounded in-memory event ring with an optional JSONL file sink."""

    def __init__(self, path: Optional[str] = None, max_events: int = 65536):
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=max_events)
        self._file = None
        self.dropped = 0
        # called as on_drop(n) after ring eviction; the owning registry uses
        # it to bump trace_events_dropped_total (lazily — no counter family
        # exists until loss actually happens)
        self.on_drop: Optional[Callable[[int], None]] = None
        # called as tap(rec) on every emit; the flight recorder uses it to
        # route events into per-subsystem rings
        self.tap: Optional[Callable[[dict], None]] = None
        if path:
            self.set_sink(path)

    # -- recording ----------------------------------------------------------

    def _emit(self, rec: dict):
        with self._lock:
            evicting = (self._events.maxlen is not None
                        and len(self._events) == self._events.maxlen)
            if evicting:
                self.dropped += 1
            self._events.append(rec)
            if self._file is not None:
                self._file.write(json.dumps(rec, default=str) + "\n")
                self._file.flush()
            on_drop, tap = self.on_drop, self.tap
        if evicting and on_drop is not None:
            on_drop(1)
        if tap is not None:
            tap(rec)

    def event(self, name: str, **attrs) -> dict:
        rec = {"name": name, "ts": time.monotonic(), "wall": time.time(),
               **_context_attrs(attrs), **attrs}
        self._emit(rec)
        return rec

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    # -- access / persistence -----------------------------------------------

    @property
    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def named(self, name: str) -> List[dict]:
        return [e for e in self.events if e.get("name") == name]

    def clear(self):
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def set_sink(self, path: Optional[str]):
        """Stream every subsequent event to ``path`` as JSON lines (append);
        ``None`` detaches the sink."""
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None
            if path:
                d = os.path.dirname(path)
                if d:
                    os.makedirs(d, exist_ok=True)
                self._file = open(path, "a")

    def write(self, path: str) -> int:
        """Dump the buffered events to ``path`` as JSONL; returns #events.
        If the ring overflowed, a ``_trace_header`` line records how many
        events were dropped (oldest-first), so the dump is marked as a
        suffix rather than a complete history.  (Events already streamed by
        a sink are not deduplicated — use one mechanism or the other per
        file.)"""
        with self._lock:
            events = list(self._events)
            dropped = self.dropped
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            if dropped:
                f.write(json.dumps({"name": "_trace_header",
                                    "dropped": dropped,
                                    "events": len(events),
                                    "wall": time.time()}) + "\n")
            for rec in events:
                f.write(json.dumps(rec, default=str) + "\n")
        return len(events)

    def __len__(self):
        with self._lock:
            return len(self._events)

    def __iter__(self) -> Iterator[dict]:
        return iter(self.events)
