"""Per-request phase attribution, SLO pass/fail, and goodput accounting.

"Where did this request's time go" decomposes a completed
:class:`~repro.serve.Request`'s lifecycle timestamps into phases:

* ``queue_wait``        — submit → first slot claim
* ``prefill``           — first claim → first generated token
* ``decode``            — first token → completion
* ``preempt_reprefill`` — time lost to preemption round-trips (eviction →
  requeue → re-claim → re-ingesting already-processed tokens), accumulated
  by the paged engine in ``req.preempt_overhead_s``; also *counted inside*
  ``prefill``/``decode`` above, so it is reported as an overlay, not a
  fifth disjoint slice.

Token accounting separates *useful* work (prompt tokens ingested once +
committed output tokens) from *wasted* work the serving stack re-did or
threw away: ``req.wasted_prefill_tokens`` (tokens re-fed after a
preemption evicted their KV pages) and ``req.rejected_draft_tokens``
(draft-tier proposals the verifier rejected).  The engines mirror the same
quantities live as ``serve_wasted_tokens_total{cause=preempt|spec_reject}``
counters; :func:`slo_report` rolls them into ``serve_goodput_ratio`` =
useful / (useful + wasted) and judges each request against
:class:`SLOConfig` (TTFT / e2e deadlines in milliseconds, matching the
``--slo-ttft-ms`` / ``--slo-e2e-ms`` driver flags).

Phase latencies aggregate through
:class:`~repro.obs.sketch.QuantileSketch` (:func:`phase_sketches`), so
serve_bench percentile breakdowns merge exactly across runs and replicas.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence

from repro.obs.sketch import DEFAULT_ALPHA, QuantileSketch

__all__ = [
    "PHASES", "SLOConfig", "phase_sketches", "request_phases",
    "request_tokens", "slo_report",
]

PHASES = ("queue_wait", "prefill", "decode", "preempt_reprefill")

REPORT_QUANTILES = (0.5, 0.9, 0.99)


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Per-request latency objectives (milliseconds); None = not enforced."""
    ttft_ms: Optional[float] = None
    e2e_ms: Optional[float] = None

    def enabled(self) -> bool:
        return self.ttft_ms is not None or self.e2e_ms is not None


def request_phases(req) -> Dict[str, float]:
    """Phase durations (seconds) for one request; phases whose boundary
    timestamps are missing (incomplete request) are omitted."""
    out: Dict[str, float] = {}
    sub, claim = req.submit_ts, req.claim_ts
    first, done = req.first_token_ts, req.complete_ts
    if sub is not None and claim is not None:
        out["queue_wait"] = max(0.0, claim - sub)
    if claim is not None and first is not None:
        out["prefill"] = max(0.0, first - claim)
    if first is not None and done is not None:
        out["decode"] = max(0.0, done - first)
    overhead = getattr(req, "preempt_overhead_s", 0.0) or 0.0
    if overhead > 0.0:
        out["preempt_reprefill"] = overhead
    if sub is not None and done is not None:
        out["e2e"] = max(0.0, done - sub)
    if sub is not None and first is not None:
        out["ttft"] = max(0.0, first - sub)
    return out


def request_tokens(req) -> Dict[str, int]:
    """Useful vs wasted token counts for one request."""
    useful = len(req.prompt) + len(req.output or ())
    return {
        "useful": useful,
        "wasted_preempt": int(getattr(req, "wasted_prefill_tokens", 0) or 0),
        "wasted_spec_reject": int(
            getattr(req, "rejected_draft_tokens", 0) or 0),
    }


def phase_sketches(requests: Iterable,
                   alpha: float = DEFAULT_ALPHA
                   ) -> Dict[str, QuantileSketch]:
    """One mergeable sketch per phase (plus ``ttft``/``e2e``) over
    ``requests`` — the aggregation serve_bench reports and merges."""
    sketches: Dict[str, QuantileSketch] = {}
    for req in requests:
        for phase, dt in request_phases(req).items():
            sk = sketches.get(phase)
            if sk is None:
                sk = sketches[phase] = QuantileSketch(alpha=alpha)
            sk.observe(dt)
    return sketches


def _percentile_entry(sk: QuantileSketch,
                      qs: Sequence[float] = REPORT_QUANTILES) -> dict:
    out = {f"p{int(q * 100)}": sk.quantile(q) for q in qs}
    out["mean"] = sk.sum / sk.count if sk.count else None
    out["count"] = sk.count
    return out


def slo_report(requests: Sequence, slo: Optional[SLOConfig] = None,
               metrics=None, alpha: float = DEFAULT_ALPHA) -> dict:
    """The SLO / goodput / phase-breakdown report serve_bench embeds in its
    JSON and ``launch/serve.py --slo-report`` prints.

    Judges *completed* requests against ``slo`` (a request passes iff it
    meets every enabled deadline), aggregates phase latencies into
    sketch-backed percentiles, and computes the goodput ratio.  When a
    :class:`~repro.obs.MetricsRegistry` is given, the verdicts are also
    published on it: ``serve_goodput_ratio`` gauge,
    ``serve_slo_pass_total`` / ``serve_slo_fail_total{slo=ttft|e2e}``
    counters.
    """
    slo = slo or SLOConfig()
    done = [r for r in requests if r.complete_ts is not None]
    useful = wasted_preempt = wasted_spec = 0
    for r in requests:
        toks = request_tokens(r)
        useful += toks["useful"]
        wasted_preempt += toks["wasted_preempt"]
        wasted_spec += toks["wasted_spec_reject"]
    wasted = wasted_preempt + wasted_spec
    ratio = useful / (useful + wasted) if (useful + wasted) else None

    n_pass = fail_ttft = fail_e2e = 0
    for r in done:
        ph = request_phases(r)
        ok = True
        if slo.ttft_ms is not None and ph.get("ttft") is not None \
                and ph["ttft"] * 1e3 > slo.ttft_ms:
            fail_ttft += 1
            ok = False
        if slo.e2e_ms is not None and ph.get("e2e") is not None \
                and ph["e2e"] * 1e3 > slo.e2e_ms:
            fail_e2e += 1
            ok = False
        n_pass += ok

    report = {
        "requests": len(requests),
        "completed": len(done),
        "preempted_requests": sum(
            1 for r in requests if getattr(r, "preempts", 0)),
        "goodput": {
            "useful_tokens": useful,
            "wasted_tokens": {"preempt": wasted_preempt,
                              "spec_reject": wasted_spec},
            "ratio": ratio,
        },
        "phases": {phase: _percentile_entry(sk)
                   for phase, sk in sorted(
                       phase_sketches(requests, alpha=alpha).items())},
    }
    if slo.enabled():
        report["slo"] = {
            "ttft_ms": slo.ttft_ms,
            "e2e_ms": slo.e2e_ms,
            "pass": n_pass,
            "fail": len(done) - n_pass,
            "fail_ttft": fail_ttft,
            "fail_e2e": fail_e2e,
            "attainment": (n_pass / len(done)) if done else None,
        }
    if metrics is not None:
        if ratio is not None:
            metrics.gauge(
                "serve_goodput_ratio",
                help="useful / (useful + wasted) tokens").set(ratio)
        if slo.enabled():
            metrics.counter("serve_slo_pass_total",
                            help="completed requests meeting every enabled "
                                 "SLO").inc(n_pass)
            if fail_ttft:
                metrics.counter("serve_slo_fail_total",
                                help="SLO deadline misses by objective",
                                slo="ttft").inc(fail_ttft)
            if fail_e2e:
                metrics.counter("serve_slo_fail_total",
                                help="SLO deadline misses by objective",
                                slo="e2e").inc(fail_e2e)
    return report
