"""Small structured logger for the launch drivers.

Human-readable lines on stdout by default (the ``launch/serve.py`` summary
stays copy-pasteable), with level filtering and an optional JSON-lines mode
for machine consumers:

* ``REPRO_LOG_LEVEL=debug|info|warning|error`` — filter (default ``info``).
* ``REPRO_LOG_JSON=1`` — emit one JSON object per line instead of text.

``log.info("served 8 requests", tokens=128, tok_s=41.2)`` renders as

    served 8 requests tokens=128 tok_s=41.2            # text mode
    {"ts": ..., "level": "info", "logger": "launch.serve",
     "msg": "served 8 requests", "tokens": 128, "tok_s": 41.2}   # JSON mode

No dependency on :mod:`logging` — the drivers need exactly level filtering
and key=value structure, and stdlib logging's global config would fight the
test harness.
"""

from __future__ import annotations

import json
import os
import sys
import threading
import time
from typing import Dict, Optional

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}


def _env_level() -> str:
    lvl = os.environ.get("REPRO_LOG_LEVEL", "info").lower()
    return lvl if lvl in LEVELS else "info"


def _env_json() -> bool:
    return os.environ.get("REPRO_LOG_JSON", "") not in ("", "0", "false")


def _fmt_value(v) -> str:
    if isinstance(v, float):
        return f"{v:g}"
    s = str(v)
    return json.dumps(s) if any(c in s for c in ' "=') else s


class StructuredLogger:
    """Level-filtered key=value / JSON-lines logger."""

    def __init__(self, name: str, level: Optional[str] = None,
                 json_lines: Optional[bool] = None, stream=None):
        self.name = name
        self.level = LEVELS[(level or _env_level()).lower()]
        self.json_lines = _env_json() if json_lines is None else json_lines
        self.stream = stream          # None → current sys.stdout at log time

    def log(self, level: str, msg: str, **fields):
        if LEVELS[level] < self.level:
            return
        stream = self.stream or sys.stdout
        if self.json_lines:
            rec = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"), "level": level,
                   "logger": self.name, "msg": msg, **fields}
            print(json.dumps(rec, default=str), file=stream, flush=True)
            return
        prefix = "" if level == "info" else f"[{level}] "
        kv = " ".join(f"{k}={_fmt_value(v)}" for k, v in fields.items())
        print(prefix + msg + (" " + kv if kv else ""), file=stream,
              flush=True)

    def debug(self, msg: str, **fields):
        self.log("debug", msg, **fields)

    def info(self, msg: str, **fields):
        self.log("info", msg, **fields)

    def warning(self, msg: str, **fields):
        self.log("warning", msg, **fields)

    def error(self, msg: str, **fields):
        self.log("error", msg, **fields)


_loggers: Dict[str, StructuredLogger] = {}
_lock = threading.Lock()


def get_logger(name: str) -> StructuredLogger:
    """Cached per-name logger (env-configured level/format)."""
    with _lock:
        lg = _loggers.get(name)
        if lg is None:
            lg = StructuredLogger(name)
            _loggers[name] = lg
        return lg
