"""repro.paged — paged KV cache, chunked prefill, and scheduled serving.

The paper's decoupling idea applied to serving state (DESIGN.md §13): KV
storage is decoupled from decode slots the way DeMM decouples its memory
block from the compute units — a shared physical arena of fixed-size pages
addressed through per-sequence block tables (the ``col_idx`` indirection
idiom one level up).  On top of it: chunked prefill as a second compiled
program (O(prompt_len / K) ingest dispatches) and an admission/preemption
scheduler driving the :class:`PagedServeEngine` tick.

Layering: this package never imports ``repro.models`` — the model is
injected (engine / launch drivers), and the device-side gather/scatter
indexing lives in ``repro.models.attention``.
"""

from repro.paged.kv_cache import (  # noqa: F401
    NULL_PAGE,
    PageAllocator,
    PagedKVCache,
    PagedLayout,
)
from repro.paged.prefill import ChunkedPrefill  # noqa: F401
from repro.paged.scheduler import (  # noqa: F401
    SchedConfig,
    Scheduler,
    Stage,
)
from repro.paged.engine import (  # noqa: F401
    PagedServeConfig,
    PagedServeEngine,
)

__all__ = [
    "NULL_PAGE",
    "PageAllocator",
    "PagedKVCache",
    "PagedLayout",
    "ChunkedPrefill",
    "SchedConfig",
    "Scheduler",
    "Stage",
    "PagedServeConfig",
    "PagedServeEngine",
]
