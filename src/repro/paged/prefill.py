"""Chunked prefill: the engine's second compiled program.

The legacy serve loop prefills token-by-token through the decode step —
O(prompt_len) compiled-step dispatches per request.  :class:`ChunkedPrefill`
wraps the model's ``prefill_chunk`` in ONE jit with a fixed chunk width K:
every chunk of every request of every length reuses the same compiled
program (``slot``, ``n_valid``, and the block-table contents are traced
values), so ingest costs O(prompt_len / K) dispatches and the engine runs
exactly two compiled programs total — prefill-chunk and decode-step.

The model is injected by the caller (the engine / launch driver):
``repro.paged`` never imports ``repro.models``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class ChunkedPrefill:
    """Feeds a prompt into a paged decode state K tokens per dispatch.

    ``model`` needs a ``prefill_chunk(params, state, tokens, slot, n_valid,
    policy=...)`` method (DecoderLM / EncDecLM).  ``step`` runs one chunk —
    the unit the scheduler interleaves with decode ticks; ``ingest`` loops a
    whole prompt (benchmarks, tests).
    """

    def __init__(self, model, *, chunk: int = 32, policy=None):
        if chunk < 1:
            raise ValueError(f"prefill chunk must be >= 1, got {chunk}")
        if not hasattr(model, "prefill_chunk"):
            raise NotImplementedError(
                f"{type(model).__name__} has no prefill_chunk (chunked "
                "paged prefill needs an attention-cache family)")
        self.chunk = int(chunk)
        self._fn = jax.jit(
            lambda p, s, t, slot, n: model.prefill_chunk(
                p, s, t, slot, n, policy=policy))
        self.dispatches = 0           # compiled-program invocations issued

    def num_chunks(self, prompt_len: int) -> int:
        return -(-int(prompt_len) // self.chunk)

    def step(self, params, state, prompt, fed: int, slot: int):
        """Feed ONE chunk of ``prompt`` starting at token ``fed`` into
        ``slot``.  Returns ``(logits, state, fed')`` where ``logits`` is the
        last *valid* position's (1, 1, V) logits — meaningful when
        ``fed' == len(prompt)`` (the first sampled token for free)."""
        part = np.asarray(prompt[fed:fed + self.chunk], np.int32)
        buf = np.zeros((self.chunk,), np.int32)
        buf[:len(part)] = part
        logits, state = self._fn(params, state, jnp.asarray(buf),
                                 jnp.int32(slot), jnp.int32(len(part)))
        self.dispatches += 1
        return logits, state, fed + len(part)

    def ingest(self, params, state, prompt, slot: int):
        """Feed a whole prompt; returns ``(last_logits, state)``."""
        fed, logits = 0, None
        while fed < len(prompt):
            logits, state, fed = self.step(params, state, prompt, fed, slot)
        return logits, state
