"""PagedServeEngine: scheduled serving over a paged KV arena.

The rewritten engine tick is admit → prefill → decode:

1. **admit** — the scheduler hands over queued requests in policy order; a
   free slot is claimed and pages for the prompt are allocated (admission
   may preempt a strictly lower-priority running request under the
   ``priority`` policy).
2. **prefill** — up to ``prefill_chunks_per_tick`` chunk dispatches are
   spent round-robin over prefilling slots (``repro.paged.prefill``); the
   final chunk's logits yield the request's first generated token for free.
3. **decode** — one batched decode step over every decode-ready slot; lanes
   still prefilling (or empty) are masked out via the ``active`` mask and
   null-page write redirection, so the two compiled programs interleave
   freely within a tick.

Page exhaustion preempts: the victim's pages are freed, the request is
requeued with its prompt + generated-so-far output, and a later admission
re-prefills it — the preempt/resume cycle is token-identical to an
uninterrupted run at any temperature, because sampling randomness is keyed
on (request, position), not on a sequential stream (DESIGN.md §13/§15;
``repro.spec.sampling``).

Speculative decoding (``spec=SpecConfig(...)``): the decode phase drafts γ
tokens per tick with the draft-tier view of the same packed buffers, grows
each lane's pages to cover the window, verifies in ONE batched full-tier
multistep dispatch, then trims pages beyond the committed tokens in the
same tick — drafted-but-rejected tokens never hold arena capacity across
ticks.

Control state (positions, block tables, the decode mask) is mirrored on the
host and pushed to the device pytree before each program call — value-only
updates, never a retrace.  Layering: this module never imports
``repro.models``; the model (and its two compiled entry points) is injected
by the caller.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.paged.kv_cache import PagedKVCache, PagedLayout
from repro.paged.prefill import ChunkedPrefill
from repro.paged.scheduler import SchedConfig, Scheduler, Stage
from repro.serve.protocol import EngineBase
from repro.serve.serve_loop import Request


@dataclasses.dataclass
class PagedServeConfig:
    num_slots: int = 4
    max_len: int = 256
    page_size: int = 16
    num_pages: Optional[int] = None   # None: fully provisioned (no sharing)
    prefill_chunk: int = 32
    greedy: bool = True         # legacy alias; temperature == 0 means greedy
    temperature: float = 0.0
    top_k: int = 0              # 0 = full vocab
    seed: int = 0               # sampling seed (keys the per-position RNG)
    sched: SchedConfig = dataclasses.field(default_factory=SchedConfig)


class PagedServeEngine(EngineBase):
    """Slot-batched serving with a shared paged KV arena.

    Same surface as the legacy :class:`~repro.serve.serve_loop.ServeEngine`
    (``submit`` / ``step`` / ``run_until_drained`` / ``completed``) plus the
    paged internals: ``kv`` (arena bookkeeping), ``sched`` (admission /
    preemption policy), and ``prefill`` (the chunked-ingest program).
    """

    def __init__(self, model, params, cfg: PagedServeConfig, *, policy=None,
                 autotune=False, metrics=None, spec=None, recorder=None):
        from repro.core.sparse_linear import resolve_policy
        from repro.spec.sampling import ReplaySafeSampler

        policy = resolve_policy(policy, None, None)
        self.model = model
        if spec is not None:
            # magnitude-descending per-group order BEFORE sharding so the
            # draft tier's prefix-read is exact magnitude pruning
            from repro.spec.tiers import tier_sort_tree
            params = tier_sort_tree(params)
        # policy.plan (ShardingPlan): renumber row-parallel packed weights
        # and place everything — the shared KV arena included — on the
        # plan's mesh before either program compiles
        params = self._setup_plan(policy, params)
        self.params = params
        self.cfg = cfg
        self.policy = policy
        if autotune and policy.mode == "packed":
            from repro import tune
            tune.autotune_packed_tree(params, cfg.num_slots)
        self.layout = PagedLayout.for_serve(
            cfg.max_len, page_size=cfg.page_size, num_pages=cfg.num_pages,
            num_slots=cfg.num_slots)
        self.kv = PagedKVCache(self.layout, cfg.num_slots)
        self.state = self._place_state(model.init_decode_state(
            cfg.num_slots, cfg.max_len, dtype=jnp.float32,
            paged=self.layout))
        self._decode = self._wrap_step(jax.jit(
            lambda p, s, t: model.decode_step(p, s, t, policy=policy)))
        self.prefill = ChunkedPrefill(model, chunk=cfg.prefill_chunk,
                                      policy=policy)
        self._prefill_step = self._wrap_step(self.prefill.step)
        self.sched = Scheduler(cfg.sched)
        # host mirrors of the control leaves (pushed before each program)
        self._pos = np.zeros((cfg.num_slots,), np.int32)
        self._decode_mask = np.zeros((cfg.num_slots,), bool)
        self._next_tok = np.zeros((cfg.num_slots, 1), np.int32)
        self.active: List[Optional[Request]] = [None] * cfg.num_slots
        self._work: List[Optional[np.ndarray]] = [None] * cfg.num_slots
        self._fed = [0] * cfg.num_slots       # work tokens ingested
        self.completed: List[Request] = []
        self.tick_count = 0
        self.sampler = ReplaySafeSampler(temperature=cfg.temperature,
                                         top_k=cfg.top_k, seed=cfg.seed)
        # -- observability (legacy names + paged families) ------------------
        self.metrics = metrics if metrics is not None else obs.metrics()
        m = self.metrics
        self.trace = m.trace
        self._spans = {}
        self._m_submitted = m.counter(
            "serve_requests_submitted_total", help="requests accepted")
        self._m_completed = m.counter(
            "serve_requests_completed_total", help="requests fully decoded")
        self._m_tokens = m.counter(
            "serve_tokens_total", help="generated (decode) tokens")
        self._m_prefill_tok = m.counter(
            "serve_prefill_tokens_total", help="prompt tokens prefilled")
        self._m_preempt = m.counter(
            "serve_preempt_total",
            help="requests preempted by page eviction")
        self._m_disp_prefill = m.counter(
            "serve_step_dispatch_total",
            help="compiled-program invocations per program",
            program="prefill")
        self._m_disp_decode = m.counter(
            "serve_step_dispatch_total",
            help="compiled-program invocations per program",
            program="decode")
        self._m_queue_wait = m.histogram(
            "serve_queue_wait_seconds", help="submit -> first slot claim")
        self._m_ttft = m.histogram(
            "serve_time_to_first_token_seconds",
            help="submit -> first generated token")
        self._m_tok_lat = m.histogram(
            "serve_decode_token_seconds",
            help="decode-step latency per generated token")
        self._m_tick = m.histogram(
            "serve_tick_seconds", help="full engine tick duration")
        self._m_slots = m.gauge(
            "serve_slots_active", help="occupied decode slots")
        self._m_queue_depth = m.gauge(
            "serve_queue_depth", help="requests waiting for a slot/pages")
        self._m_pages_free = m.gauge(
            "kv_pages_free", help="unallocated KV arena pages")
        self._m_occupancy = m.gauge(
            "kv_arena_occupancy",
            help="fraction of usable arena pages allocated")
        self._m_frag = m.gauge(
            "kv_page_fragmentation",
            help="allocated-but-empty token-slot fraction (last-page slack)")
        self._m_tps = m.gauge(
            "serve_tokens_per_second",
            help="decode throughput of the last run_until_drained window")
        # goodput accounting: tokens whose KV a preemption evicted — the
        # resume re-ingests them, so they are work done twice
        self._m_wasted_preempt = m.counter(
            "serve_wasted_tokens_total",
            help="tokens of work the engine re-did or discarded, by cause",
            cause="preempt")
        # sketch-backed latency percentiles (mergeable across DP replicas)
        self._sk_ttft = m.sketch(
            "serve_ttft_seconds_sketch",
            help="submit -> first token (quantile sketch)")
        self._sk_tok = m.sketch(
            "serve_decode_token_seconds_sketch",
            help="per-generated-token decode latency (quantile sketch)")
        self._sk_e2e = m.sketch(
            "serve_e2e_seconds_sketch",
            help="submit -> completion (quantile sketch)")
        self._m_pages_free.set(self.kv.pages_free)
        self._setup_recorder(recorder)
        # -- speculative decoding (DESIGN.md §15) ---------------------------
        self._spec = spec
        if spec is not None:
            from repro.spec.decode import (SpecMetrics, guard_cache_kinds,
                                           make_multistep)
            from repro.spec.tiers import derive_draft_tier
            guard_cache_kinds(self.state)
            # derive AFTER _setup_plan so the draft view aliases the
            # placed/renumbered buffers (draft.values IS full.values)
            self._draft_params, self.tier_report = derive_draft_tier(
                self.params, spec.draft)
            self._verify = self._wrap_step(make_multistep(model, policy))
            self._spec_metrics = SpecMetrics(self.metrics)
            self._m_disp_draft = m.counter(
                "serve_step_dispatch_total",
                help="compiled-program invocations per program",
                program="draft")
            self._m_disp_verify = m.counter(
                "serve_step_dispatch_total",
                help="compiled-program invocations per program",
                program="verify")

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request):
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if len(req.prompt) > self.cfg.max_len - 1:
            raise ValueError(
                f"request {req.uid}: prompt of {len(req.prompt)} tokens "
                f"exceeds max_len-1 = {self.cfg.max_len - 1}")
        peak = min(len(req.prompt) + req.max_new_tokens, self.cfg.max_len)
        need = self.layout.pages_for(peak)
        if need > min(self.layout.usable_pages, self.layout.max_blocks):
            raise RuntimeError(
                f"request {req.uid} needs {need} pages at peak ({peak} "
                f"tokens) but the arena has only "
                f"{self.layout.usable_pages} usable pages "
                f"(max_blocks={self.layout.max_blocks}) — it could never "
                f"complete even with every other sequence evicted; raise "
                f"--max-pages or --page-size")
        req.output = []
        req.submit_ts = time.monotonic()
        ctx = self._request_context(req)   # mints req.trace_id
        self.sched.submit(req)
        self._m_submitted.inc()
        self._m_queue_depth.set(len(self.sched))
        with obs.use_context(ctx):
            self._spans[req.uid] = self.trace.span("request", uid=req.uid)
            self.trace.event("request_submit", uid=req.uid,
                             prompt_len=len(req.prompt),
                             priority=req.priority)

    # -- device-control sync ------------------------------------------------

    def _sync_control(self):
        """Push the host-side control mirrors (positions, block tables,
        decode mask) into the device pytree.  Value-only: shapes and the
        Static kind/layout leaves never change, so no retrace.  The mirrors
        are COPIED before upload — jax's CPU client may zero-copy-alias an
        aligned numpy buffer, and these arrays keep mutating in place."""
        c = self.state["caches"]
        self.state = {
            **self.state,
            "pos": jnp.asarray(np.array(self._pos)),
            "caches": {**c,
                       "block_table": jnp.asarray(np.array(self.kv.table)),
                       "active": jnp.asarray(np.array(self._decode_mask))},
        }

    def _page_gauges(self):
        self._m_pages_free.set(self.kv.pages_free)
        self._m_occupancy.set(self.kv.occupancy())
        self._m_frag.set(self.kv.fragmentation())

    # -- lifecycle transitions ----------------------------------------------

    def _claim(self, slot: int, req: Request):
        work = (np.concatenate([np.asarray(req.prompt, np.int32),
                                np.asarray(req.output, np.int32)])
                if req.output else np.asarray(req.prompt, np.int32))
        self.active[slot] = req
        self._work[slot] = work
        self._fed[slot] = 0
        self._pos[slot] = 0
        self._decode_mask[slot] = False
        self.kv.note_tokens(slot, 0)
        now = time.monotonic()
        if req.claim_ts is None:
            self._m_queue_wait.observe(now - req.submit_ts)
        req.claim_ts = now
        self.sched.stage[req.uid] = Stage.SCHEDULED
        self.trace.event("request_schedule", uid=req.uid, slot=slot,
                         resume_tokens=len(req.output),
                         trace_id=req.trace_id)
        if req.preempts > 0:
            # a preempt-resume: the whole work buffer is a re-ingest
            self.trace.event("request_resume", uid=req.uid, slot=slot,
                             resume_tokens=len(work),
                             trace_id=req.trace_id)

    def _preempt(self, slot: int):
        req = self.active[slot]
        freed = self.kv.release(slot)
        # every token already ingested into the evicted pages is work the
        # resume must redo — charge it to the preempt waste cause now,
        # while the ingest depth is still known
        evicted_tokens = int(self._pos[slot])
        req.preempts += 1
        req.preempt_ts = time.monotonic()
        if evicted_tokens > 0:
            req.wasted_prefill_tokens += evicted_tokens
            self._m_wasted_preempt.inc(evicted_tokens)
        self.active[slot] = None
        self._work[slot] = None
        self._decode_mask[slot] = False
        self._pos[slot] = 0
        self.sched.stage[req.uid] = Stage.PREEMPTED
        self.sched.requeue(req)
        self._m_preempt.inc()
        self._m_queue_depth.set(len(self.sched))
        self._page_gauges()
        self.trace.event("request_preempt", uid=req.uid, slot=slot,
                         pages_freed=freed, tokens_done=len(req.output),
                         tokens_evicted=evicted_tokens,
                         trace_id=req.trace_id)

    def _complete(self, slot: int, req: Request, now: float):
        req.complete_ts = now
        self.completed.append(req)
        self.kv.release(slot)
        self.active[slot] = None
        self._work[slot] = None
        self._decode_mask[slot] = False
        self._pos[slot] = 0
        self._m_completed.inc()
        self._sk_e2e.observe(now - req.submit_ts)
        self._page_gauges()
        self.sched.stage[req.uid] = Stage.COMPLETE
        self.trace.event("request_complete", uid=req.uid,
                         tokens=len(req.output),
                         preempts=self.sched.preempts_of[req.uid],
                         trace_id=req.trace_id)
        span = self._spans.pop(req.uid, None)
        if span is not None:
            span.end(tokens=len(req.output))

    # -- tick phases --------------------------------------------------------

    def _admit(self):
        while len(self.sched):
            free = next((i for i in range(self.cfg.num_slots)
                         if self.active[i] is None), None)
            if free is None:
                # priority admission: preempt a strictly worse running req
                if not self.cfg.sched.preempt:
                    break
                incoming = self.sched.peek()
                victim = self.sched.victim(
                    [(s, r) for s, r in enumerate(self.active)
                     if r is not None], incoming=incoming)
                if victim is None:
                    break
                self._preempt(victim)
                continue
            req = self.sched.peek()
            work_len = len(req.prompt) + len(req.output or ())
            if not self.kv.ensure_capacity(free, work_len):
                if not self.cfg.sched.preempt:
                    break
                victim = self.sched.victim(
                    [(s, r) for s, r in enumerate(self.active)
                     if r is not None], incoming=req)
                if victim is None:
                    break
                self._preempt(victim)
                continue
            self._claim(free, self.sched.pop())
            self._m_queue_depth.set(len(self.sched))
            self._page_gauges()

    def _finish_prefill(self, slot: int, req: Request, logits, now: float):
        """Final chunk done: sample the next token from its logits (first
        generated token for a fresh request; the continuation token for a
        preempt-resume).  The sampler key is the token's absolute sequence
        index (= the work length), so a resume re-draws the identical
        token the uninterrupted run committed there."""
        tok = self.sampler.sample(np.asarray(logits[0, 0], np.float32),
                                  req.uid, int(self._pos[slot]))
        req.output.append(tok)
        self._next_tok[slot, 0] = tok
        self._m_tokens.inc()
        if req.preempt_ts is not None:
            # the eviction round trip (requeue -> re-claim -> re-prefill)
            # ends here; attribute it for the slo phase breakdown
            req.preempt_overhead_s += now - req.preempt_ts
            req.preempt_ts = None
        if len(req.output) == 1:
            req.first_token_ts = now
            self._m_ttft.observe(now - req.submit_ts)
            self._sk_ttft.observe(now - req.submit_ts)
            self.trace.event("request_first_token", uid=req.uid,
                             trace_id=req.trace_id)
        if (len(req.output) >= req.max_new_tokens or
                (req.eos_id is not None and tok == req.eos_id)):
            self._complete(slot, req, now)
            return
        self._decode_mask[slot] = True
        self.sched.stage[req.uid] = Stage.DECODE

    def _run_prefill(self):
        budget = self.cfg.sched.prefill_chunks_per_tick
        while budget > 0:
            slots = [i for i in range(self.cfg.num_slots)
                     if self.active[i] is not None
                     and not self._decode_mask[i]]
            if not slots:
                return
            for i in slots:
                if budget <= 0:
                    return
                req = self.active[i]
                if self._fed[i] == 0:
                    self.sched.stage[req.uid] = Stage.PREFILL
                    self.trace.event("request_prefill", uid=req.uid, slot=i,
                                     trace_id=req.trace_id,
                                     tokens=len(self._work[i]),
                                     chunks=self.prefill.num_chunks(
                                         len(self._work[i])))
                self._sync_control()
                was = self._fed[i]
                # chunk dispatch under the owning request's context: the
                # prefill_chunk event (and any compile-time kernel_dispatch
                # events) carry its trace_id
                with obs.use_context(self._request_context(req)):
                    logits, self.state, fed = self._prefill_step(
                        self.params, self.state, self._work[i], was, i)
                    self.trace.event("prefill_chunk", uid=req.uid, slot=i,
                                     fed_from=was, fed_to=fed)
                self._fed[i] = fed
                self._pos[i] = fed
                self.kv.note_tokens(i, fed)
                self._m_disp_prefill.inc()
                self._m_prefill_tok.inc(fed - was)
                budget -= 1
                if fed == len(self._work[i]):
                    self._finish_prefill(i, req, logits, time.monotonic())
            self._page_gauges()

    def _grow_or_preempt(self, tokens_for):
        """Grow every decoding slot's pages to hold ``tokens_for(i)``
        tokens; exhaustion preempts the policy's victim (possibly the
        grower, which drops out of the decode mask)."""
        for i in range(self.cfg.num_slots):
            while (self._decode_mask[i]
                   and not self.kv.ensure_capacity(i, tokens_for(i))):
                if not self.cfg.sched.preempt:
                    raise RuntimeError(
                        "KV arena exhausted with preemption disabled "
                        "(sched.preempt=False); raise --max-pages")
                victim = self.sched.victim(
                    [(s, r) for s, r in enumerate(self.active)
                     if r is not None])
                self._preempt(victim)

    def _run_decode(self) -> int:
        if self._spec is not None and self._decode_mask.any():
            g_eff = min(self._spec.gamma,
                        self.cfg.max_len - 1
                        - max(int(self._pos[i])
                              for i in range(self.cfg.num_slots)
                              if self._decode_mask[i]))
            if g_eff >= 1:
                return self._run_decode_spec(g_eff)
            # a lane is one token from max_len: fall back to a plain step
        return self._run_decode_plain()

    def _run_decode_plain(self) -> int:
        self._grow_or_preempt(lambda i: int(self._pos[i]) + 1)
        if not self._decode_mask.any():
            return 0
        self._sync_control()
        t0 = time.perf_counter()
        first = next(i for i in range(self.cfg.num_slots)
                     if self._decode_mask[i])
        # batched dispatch: attributed to the first decode-ready lane
        with obs.use_context(self._request_context(self.active[first])):
            logits, self.state = self._decode(
                self.params, self.state,
                jnp.asarray(np.array(self._next_tok)))
        logits = np.asarray(logits[:, 0], np.float32)   # device sync
        step_dt = time.perf_counter() - t0
        self._m_disp_decode.inc()
        now = time.monotonic()
        n = 0
        for i in range(self.cfg.num_slots):
            if not self._decode_mask[i]:
                continue
            n += 1
            req = self.active[i]
            self._pos[i] += 1
            self.kv.note_tokens(i, int(self._pos[i]))
            tok = self.sampler.sample(logits[i], req.uid, int(self._pos[i]))
            req.output.append(tok)
            self._next_tok[i, 0] = tok
            self._m_tokens.inc()
            self._m_tok_lat.observe(step_dt)
            self._sk_tok.observe(step_dt)
            if (len(req.output) >= req.max_new_tokens or
                    (req.eos_id is not None and tok == req.eos_id) or
                    int(self._pos[i]) >= self.cfg.max_len - 1):
                self._complete(i, req, now)
        self._page_gauges()
        return n

    def _run_decode_spec(self, g_eff: int) -> int:
        """One speculation window over the decode-ready lanes: grow pages
        for the whole window, draft γ_eff tokens with the draft-tier params,
        verify in ONE batched full-tier multistep dispatch, commit each
        lane's accepted prefix + correcting/bonus token, then trim the
        pages beyond the committed tokens (same tick — rejected drafts
        never hold arena capacity across ticks)."""
        # positions pos .. pos+g_eff are written -> pos+g_eff+1 tokens
        self._grow_or_preempt(lambda i: int(self._pos[i]) + g_eff + 1)
        lanes = [i for i in range(self.cfg.num_slots) if self._decode_mask[i]]
        if not lanes:
            return 0
        self._sync_control()
        pos0 = self._pos.copy()
        t0 = time.perf_counter()
        W = g_eff + 1
        window = np.zeros((self.cfg.num_slots, W), np.int32)
        window[:, 0] = self._next_tok[:, 0]
        d_state = self.state                # self.state stays pre-draft
        window_ctx = self._request_context(self.active[lanes[0]])
        for j in range(g_eff):
            with obs.use_context(window_ctx):
                d_logits, d_state = self._decode(
                    self._draft_params, d_state,
                    jnp.asarray(window[:, j:j + 1]))
            d_logits = np.asarray(d_logits[:, 0], np.float32)
            self._m_disp_draft.inc()
            for i in lanes:
                window[i, j + 1] = self.sampler.sample(
                    d_logits[i], self.active[i].uid, int(pos0[i]) + j + 1)
        with obs.use_context(window_ctx):
            f_logits, new_state = self._verify(self.params, self.state,
                                               jnp.asarray(window))
        f_logits = np.asarray(f_logits, np.float32)
        self._m_disp_verify.inc()
        self.state = new_state
        window_dt = time.perf_counter() - t0
        now = time.monotonic()
        drafted = accepted = committed = 0
        for i in lanes:
            req = self.active[i]
            p = int(pos0[i])
            valid = W                   # window inputs this lane keeps
            finished = False
            lane_accepted = lane_committed = 0
            for j in range(W):
                tok = self.sampler.sample(f_logits[i, j], req.uid, p + j + 1)
                if j < g_eff:
                    drafted += 1
                    ok = int(window[i, j + 1]) == tok
                    accepted += ok
                    lane_accepted += ok
                req.output.append(tok)
                committed += 1
                lane_committed += 1
                self._m_tokens.inc()
                if (len(req.output) >= req.max_new_tokens or
                        (req.eos_id is not None and tok == req.eos_id) or
                        p + j + 1 >= self.cfg.max_len - 1):
                    valid = j + 1
                    finished = True
                    self._complete(i, req, now)
                    break
                if j < g_eff and int(window[i, j + 1]) != tok:
                    valid = j + 1       # first mismatch truncates
                    self._next_tok[i, 0] = tok
                    break
                if j == g_eff:
                    self._next_tok[i, 0] = tok   # bonus token
            if not finished:
                # roll back to the last valid input and free the tail pages
                self._pos[i] = p + valid
                self.kv.note_tokens(i, p + valid)
                self.kv.trim(i, p + valid)
            # every draft lane proposed g_eff tokens; the uncommitted ones
            # (incl. drafts past a truncation point) are discarded work
            lane_rejected = g_eff - lane_accepted
            if lane_rejected > 0:
                req.rejected_draft_tokens += lane_rejected
                self._spec_metrics.observe_wasted(lane_rejected)
            if lane_committed:
                self.trace.event("spec_commit", uid=req.uid,
                                 trace_id=req.trace_id,
                                 committed=lane_committed,
                                 accepted=lane_accepted,
                                 rejected=lane_rejected)
        if committed:
            per_tok = window_dt / committed
            for _ in range(committed):
                self._m_tok_lat.observe(per_tok)
                self._sk_tok.observe(per_tok)
        self._spec_metrics.observe_window(drafted, accepted, committed)
        self._page_gauges()
        return len(lanes)

    # -- public loop --------------------------------------------------------

    def step(self) -> int:
        """One engine tick (admit → prefill → decode).  Returns the number
        of occupied slots after the tick."""
        t_tick = time.perf_counter()
        self._beat()
        self.tick_count += 1
        self._admit()
        self._run_prefill()
        self._run_decode()
        n_active = sum(r is not None for r in self.active)
        self._m_slots.set(n_active)
        self._m_queue_depth.set(len(self.sched))
        self._m_tick.observe(time.perf_counter() - t_tick)
        return n_active

    def run_until_drained(self, max_ticks: int = 10000):
        ticks = 0
        t0 = time.perf_counter()
        tok0 = self._m_tokens.value
        while (len(self.sched) or any(r is not None for r in self.active)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        dt = time.perf_counter() - t0
        if dt > 0:
            self._m_tps.set((self._m_tokens.value - tok0) / dt)
        return ticks
