"""Admission + scheduling policy for the paged serve engine.

The scheduler is pure host logic: it owns the wait queue, the request
lifecycle stages, and the preemption-victim policy; the engine owns slots,
pages, and device state.  Two policies:

* ``fcfs``     — strict arrival order; preemption (decode page growth when
  the arena is full) evicts the *youngest* active request.
* ``priority`` — lower ``Request.priority`` number wins; ties break by
  arrival order.  Admission may preempt a strictly lower-priority active
  request; decode-growth preemption evicts the worst (priority, youngest).

A preempted request keeps its original arrival sequence number, so on
requeue it sorts ahead of later arrivals of the same priority — combined
with greedy decoding and re-prefill of prompt + generated-so-far, the
preempt/resume cycle is deterministic and token-identical (DESIGN.md §13).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, Iterable, List, Optional, Tuple


class Stage:
    """Request lifecycle stages (trace-event / test vocabulary)."""

    QUEUED = "queued"
    SCHEDULED = "scheduled"
    PREFILL = "prefill"
    DECODE = "decode"
    PREEMPTED = "preempted"
    COMPLETE = "complete"


@dataclasses.dataclass
class SchedConfig:
    policy: str = "fcfs"              # "fcfs" | "priority"
    preempt: bool = True              # page-eviction preemption allowed
    prefill_chunks_per_tick: int = 4  # prefill/decode interleave budget

    def __post_init__(self):
        if self.policy not in ("fcfs", "priority"):
            raise ValueError(
                f"scheduler policy must be 'fcfs' or 'priority', got "
                f"{self.policy!r}")
        if self.prefill_chunks_per_tick < 1:
            raise ValueError("prefill_chunks_per_tick must be >= 1")


class Scheduler:
    def __init__(self, cfg: Optional[SchedConfig] = None):
        self.cfg = cfg or SchedConfig()
        self._heap: List[Tuple[Tuple[int, int], object]] = []
        self._arrival = itertools.count()
        self.seq_of: Dict[int, int] = {}      # uid -> arrival seq (stable)
        self.stage: Dict[int, str] = {}       # uid -> Stage.*
        self.preempts_of: Dict[int, int] = {} # uid -> times preempted

    # -- queue --------------------------------------------------------------

    def _key(self, req) -> Tuple[int, int]:
        seq = self.seq_of[req.uid]
        prio = req.priority if self.cfg.policy == "priority" else 0
        return (prio, seq)

    def submit(self, req):
        if req.uid in self.seq_of:
            raise ValueError(f"request uid {req.uid} already submitted")
        self.seq_of[req.uid] = next(self._arrival)
        self.preempts_of[req.uid] = 0
        self.stage[req.uid] = Stage.QUEUED
        heapq.heappush(self._heap, (self._key(req), req))

    def requeue(self, req):
        """Put a preempted request back; its original arrival seq means it
        re-runs before same-priority work that arrived after it."""
        self.preempts_of[req.uid] += 1
        self.stage[req.uid] = Stage.QUEUED
        heapq.heappush(self._heap, (self._key(req), req))

    def peek(self):
        return self._heap[0][1] if self._heap else None

    def pop(self):
        return heapq.heappop(self._heap)[1] if self._heap else None

    def __len__(self):
        return len(self._heap)

    # -- preemption policy --------------------------------------------------

    def victim(self, candidates: Iterable[Tuple[int, object]], *,
               incoming=None) -> Optional[int]:
        """Pick the preemption victim among active ``(slot, request)`` pairs:
        the worst by (priority, youngest arrival).  With ``incoming`` set
        (admission-time preemption) only a strictly lower-priority victim
        qualifies — equal-priority admission never thrashes running work.
        Returns the victim's slot, or None."""
        worst = None
        for slot, req in candidates:
            key = (req.priority if self.cfg.policy == "priority" else 0,
                   self.seq_of[req.uid])
            if worst is None or key > worst[0]:
                worst = (key, slot, req)
        if worst is None:
            return None
        if incoming is not None:
            if self.cfg.policy != "priority":
                return None
            if incoming.priority >= worst[2].priority:
                return None
        return worst[1]
