"""Paged KV cache: fixed-size pages, per-sequence block tables, accounting.

The serving analogue of the paper's decoupled memory block: one physical
*arena* of ``num_pages`` fixed-size pages (per layer, per K/V) is shared by
every logical sequence, and each sequence reaches its tokens through a
block table — a small indirection stream, exactly how DeMM's compute units
reach a packed weight buffer through ``col_idx``.  Concurrency is then
bounded by *actual* tokens resident, not ``num_slots × max_len`` worst-case
reservations: thousands of logical sequences can share an arena sized for
the live working set, with preemption-by-page-eviction as the backpressure
mechanism (``repro.paged.scheduler``).

This module is the host side: :class:`PagedLayout` (static geometry, stored
inside the decode-state pytree via ``Static``), :class:`PageAllocator`
(free-list + accounting), and :class:`PagedKVCache` (allocator + per-slot
block tables + token counts, mirrored to the device as a ``(B, NBLK)``
int32 array).  The device side — gather/scatter indexing and the paged
attention paths — lives in ``repro.models.attention``
(``gather_pages`` / ``scatter_token_pages`` / ``scatter_chunk_pages``).

Page 0 is reserved as the null/scratch page: unallocated block-table
entries point there, masked-lane writes are redirected there, and it is
never read unmasked.  The allocator therefore hands out pages
``1..num_pages-1``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

NULL_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static paged-arena geometry (hashable; jit-safe inside ``Static``).

    * ``page_size``  — tokens per page (P).
    * ``num_pages``  — physical pages in the arena, *including* the reserved
      null page 0; usable pages = ``num_pages - 1``.
    * ``max_blocks`` — block-table width per sequence (NBLK); a sequence can
      grow to ``max_blocks * page_size`` tokens logically, but only pages it
      actually touches are ever allocated.
    """

    page_size: int
    num_pages: int
    max_blocks: int

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages < 2:
            raise ValueError(
                f"num_pages must be >= 2 (page 0 is the reserved null page), "
                f"got {self.num_pages}")
        if self.max_blocks < 1:
            raise ValueError(f"max_blocks must be >= 1, got {self.max_blocks}")

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    @property
    def tokens_per_seq(self) -> int:
        """Logical per-sequence capacity (the dense cache's ``max_len``)."""
        return self.max_blocks * self.page_size

    @classmethod
    def for_serve(cls, max_len: int, page_size: int = 16,
                  num_pages: Optional[int] = None,
                  num_slots: int = 1) -> "PagedLayout":
        """Geometry for a serve engine: NBLK covers ``max_len``; the default
        arena is fully provisioned (``num_slots * NBLK`` pages + null page,
        i.e. no oversubscription — pass a smaller ``num_pages`` to actually
        share)."""
        nblk = -(-max_len // page_size)
        if num_pages is None:
            num_pages = num_slots * nblk + 1
        return cls(page_size=page_size, num_pages=num_pages, max_blocks=nblk)

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` tokens."""
        return -(-tokens // self.page_size)


class PageAllocator:
    """LIFO free-list allocator over pages ``1..num_pages-1`` with
    allocation / free / fragmentation accounting."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(f"num_pages must be >= 2, got {num_pages}")
        self.num_pages = num_pages
        # LIFO: recently freed pages are recycled first (warm-cache friendly)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.alloc_total = 0
        self.free_total = 0
        self.alloc_failures = 0

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_used(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """Allocate ``n`` pages or *none* (no partial allocations — a
        failed allocation is the preemption trigger, and partial grants
        would leave half-admitted sequences)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.alloc_total += n
        return pages

    def free(self, pages: Sequence[int]):
        for p in pages:
            if not 0 < p < self.num_pages:
                raise ValueError(f"free of page {p} outside 1..{self.num_pages - 1}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)
        self.free_total += len(pages)

    def fragmentation(self, tokens_resident: int, page_size: int) -> float:
        """Internal fragmentation: fraction of *allocated* token slots not
        holding a token (last-page slack across all sequences).  0.0 when
        nothing is allocated."""
        cap = self.pages_used * page_size
        if cap <= 0:
            return 0.0
        return 1.0 - min(tokens_resident, cap) / cap


class PagedKVCache:
    """Host-side paged-cache bookkeeping for a slot-batched engine.

    Owns the allocator, the per-slot page lists, and the per-slot resident
    token counts; renders the ``(num_slots, max_blocks)`` int32 block table
    the device programs index with.  All methods are O(pages touched) host
    work — the arena itself lives in the decode-state pytree.
    """

    def __init__(self, layout: PagedLayout, num_slots: int):
        self.layout = layout
        self.num_slots = num_slots
        self.allocator = PageAllocator(layout.num_pages)
        self.table = np.full((num_slots, layout.max_blocks), NULL_PAGE,
                             np.int32)
        self._pages: List[List[int]] = [[] for _ in range(num_slots)]
        self.tokens = np.zeros((num_slots,), np.int64)

    # -- queries ------------------------------------------------------------

    @property
    def pages_free(self) -> int:
        return self.allocator.pages_free

    @property
    def pages_used(self) -> int:
        return self.allocator.pages_used

    def occupancy(self) -> float:
        """Fraction of usable arena pages currently allocated."""
        usable = self.layout.usable_pages
        return self.allocator.pages_used / usable if usable else 0.0

    def fragmentation(self) -> float:
        return self.allocator.fragmentation(int(self.tokens.sum()),
                                            self.layout.page_size)

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._pages[slot])

    # -- mutation -----------------------------------------------------------

    def ensure_capacity(self, slot: int, tokens: int) -> bool:
        """Grow slot ``slot`` so positions ``[0, tokens)`` have pages.
        Returns False (allocating nothing) if the arena cannot satisfy it —
        the caller's cue to preempt or wait."""
        need = self.layout.pages_for(tokens)
        if need > self.layout.max_blocks:
            raise ValueError(
                f"slot {slot} needs {need} pages for {tokens} tokens but "
                f"max_blocks={self.layout.max_blocks} "
                f"(logical capacity {self.layout.tokens_per_seq} tokens)")
        have = len(self._pages[slot])
        if need <= have:
            return True
        got = self.allocator.alloc(need - have)
        if got is None:
            return False
        for i, page in enumerate(got):
            self.table[slot, have + i] = page
        self._pages[slot].extend(got)
        return True

    def note_tokens(self, slot: int, tokens: int):
        """Record the resident token count of ``slot`` (accounting only)."""
        self.tokens[slot] = tokens

    def trim(self, slot: int, tokens: int) -> int:
        """Shrink ``slot`` back to the pages covering ``tokens`` tokens,
        freeing the tail pages and nulling their block-table entries.

        The speculative-decode rollback (repro.spec): a verify window grows
        the slot to ``pos + γ + 1`` tokens so drafted positions have pages
        to write into, but only *accepted* tokens may keep pages — the tail
        beyond the committed count is returned to the allocator here, in the
        same tick, so drafted-but-rejected tokens never hold arena capacity
        across ticks.  Returns the number of pages freed."""
        keep = self.layout.pages_for(tokens)
        pages = self._pages[slot]
        if keep >= len(pages):
            return 0
        tail = pages[keep:]
        self.allocator.free(tail)
        self._pages[slot] = pages[:keep]
        self.table[slot, keep:] = NULL_PAGE
        return len(tail)

    def release(self, slot: int) -> int:
        """Free every page of ``slot`` (completion or preemption-eviction).
        Returns the number of pages released."""
        pages = self._pages[slot]
        n = len(pages)
        if n:
            self.allocator.free(pages)
        self._pages[slot] = []
        self.table[slot, :] = NULL_PAGE
        self.tokens[slot] = 0
        return n
