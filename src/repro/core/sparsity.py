"""Relaxed N:M structured sparsity — formats, pruning, packing.

This module is the data-format half of the paper's contribution: a matrix A
follows *relaxed structured sparsity* N:M when every group of M contiguous
elements along the contraction dimension of each row holds at most N
non-zeros.  The packed representation stores, per (row, group), exactly N
``{value, col_idx}`` pairs (zero-padded when fewer non-zeros exist), which is
what the DeMM engine streams: values feed the multipliers, indices feed the
read ports.

Shapes
------
dense   A        : (R, K)            with K % M == 0, G = K // M groups
packed  values   : (R, G, N)         same dtype as A
packed  indices  : (R, G, N) int32   local column index within the group,
                                     in [0, M); padded slots point at 0 with
                                     value 0 (contributing nothing).

The k-reconfiguration of the paper (a DeMM(N, M, C, k) engine serving kN:M
patterns by time-sharing its N read ports over k cycles) is mirrored by
``reconfigure_k``: a packed (R, G, kN) tensor is viewed as k passes of
(R, G, N), preserving the engine-config semantics.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_static
class Static:
    """Hashable static metadata stored inside a params pytree (not traced).

    Lives here (not in ``models.layers``) so core/serialization code never
    has to import the model layer package; ``models.layers.Static`` re-exports
    this class for backward compatibility.
    """

    def __init__(self, value):
        self.value = value

    def __eq__(self, other):
        return isinstance(other, Static) and self.value == other.value

    def __hash__(self):
        return hash(("Static", self.value))

    def __repr__(self):
        return f"Static({self.value!r})"


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Relaxed structured sparsity pattern N:M with k-reconfiguration.

    The *native* engine pattern is ``n:m``.  ``k`` > 1 means the engine is
    reconfigured to serve the denser ``k*n : m`` pattern in ``k`` passes over
    the same pre-loaded B block (paper §II-B).  The *effective* number of
    non-zeros per group is ``n_effective = n * k``.
    """

    n: int = 8
    m: int = 128
    k: int = 1

    def __post_init__(self):
        if self.n < 1 or self.m < 1 or self.k < 1:
            raise ValueError(f"n, m, k must be >= 1, got {self}")
        if self.n * self.k > self.m:
            raise ValueError(
                f"effective non-zeros n*k={self.n * self.k} exceeds group size m={self.m}"
            )

    @property
    def n_effective(self) -> int:
        return self.n * self.k

    @property
    def density(self) -> float:
        return self.n_effective / self.m

    @property
    def sparsity(self) -> float:
        return 1.0 - self.density

    def pattern_name(self) -> str:
        if self.k == 1:
            return f"{self.n}:{self.m}"
        return f"{self.n_effective}:{self.m} (as {self.k}x{self.n}:{self.m})"

    def packed_bytes(self, rows: int, cols: int, value_bytes: int = 2,
                     index_bytes: int = 1) -> int:
        """HBM footprint of the packed representation."""
        groups = cols // self.m
        return rows * groups * self.n_effective * (value_bytes + index_bytes)

    def dense_bytes(self, rows: int, cols: int, value_bytes: int = 2) -> int:
        return rows * cols * value_bytes

    def compression_ratio(self, value_bytes: int = 2, index_bytes: int = 1) -> float:
        """Dense/packed byte ratio — the memory-roofline lever on TPU."""
        return (self.m * value_bytes) / (self.n_effective * (value_bytes + index_bytes))


# Common named patterns from the paper.
PATTERNS = {
    "8:128": SparsityConfig(8, 128, 1),
    "8:256": SparsityConfig(8, 256, 1),
    "4:64": SparsityConfig(4, 64, 1),
    "1:2": SparsityConfig(1, 2, 1),
    "1:4": SparsityConfig(1, 4, 1),
    "1:8": SparsityConfig(1, 8, 1),
    "2:4": SparsityConfig(2, 4, 1),
    # DeMM(8,128,·,8) reconfigured to fine-grained-equivalent densities:
    "64:128 (as 8x8:128)": SparsityConfig(8, 128, 8),
}


def _check_dims(shape, m: int):
    if len(shape) != 2:
        raise ValueError(f"expected 2-D matrix, got shape {shape}")
    if shape[1] % m == 0:
        return
    raise ValueError(f"contraction dim {shape[1]} not divisible by group size {m}")


# ---------------------------------------------------------------------------
# Pattern validation / mask utilities
# ---------------------------------------------------------------------------

def group_nonzero_counts(a: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """Non-zero count per (row, group): shape (R, G)."""
    _check_dims(a.shape, cfg.m)
    r, kdim = a.shape
    g = kdim // cfg.m
    return jnp.sum((a.reshape(r, g, cfg.m) != 0).astype(jnp.int32), axis=-1)


def satisfies_pattern(a: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """True iff every (row, group) has at most n_effective non-zeros."""
    return jnp.all(group_nonzero_counts(a, cfg) <= cfg.n_effective)


def prune_mask(a: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """Magnitude top-``n_effective``-per-group boolean mask with A's shape.

    This is the pruning rule used to derive relaxed-structured-sparse models
    (keep the largest-|w| N elements of every M-block of every row).  Ties are
    broken deterministically by column order (first occurrence wins), matching
    ``jax.lax.top_k`` semantics.
    """
    _check_dims(a.shape, cfg.m)
    r, kdim = a.shape
    g = kdim // cfg.m
    ne = cfg.n_effective
    mag = jnp.abs(a.reshape(r, g, cfg.m))
    # Threshold = value of the ne-th largest magnitude in each group.
    top_vals, _ = jax.lax.top_k(mag, ne)
    thresh = top_vals[..., ne - 1 : ne]  # (R, G, 1)
    # Exact zeros are never kept — and must be excluded *before* the tie
    # resolution: an under-full group (fewer than ne non-zeros — the relaxed
    # "at most N" case) has threshold 0, and counting its zeros as tie
    # candidates used to crowd out the genuine non-zeros sitting later in
    # the group.
    keep = (mag >= thresh) & (mag > 0)
    # Resolve ties: if >ne elements meet the threshold, keep the first ones.
    over = jnp.cumsum(keep.astype(jnp.int32), axis=-1)
    keep = keep & (over <= ne)
    return keep.reshape(r, kdim)


def prune(a: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """Magnitude-prune ``a`` to the N:M pattern (dense output, zeros inserted)."""
    return jnp.where(prune_mask(a, cfg), a, jnp.zeros((), a.dtype))


# ---------------------------------------------------------------------------
# Pack / unpack
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedSparse:
    """Packed relaxed-structured-sparse matrix (the DeMM input stream)."""

    values: jax.Array   # (R, G, Ne)
    indices: jax.Array  # (R, G, Ne) int32, local in [0, M)
    cfg: SparsityConfig
    shape: tuple        # dense (R, K)

    @property
    def dense_shape(self):
        return self.shape

    def tree_flatten(self):
        return (self.values, self.indices), (self.cfg, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, indices = children
        cfg, shape = aux
        return cls(values=values, indices=indices, cfg=cfg, shape=shape)


jax.tree_util.register_pytree_node(
    PackedSparse, PackedSparse.tree_flatten, PackedSparse.tree_unflatten
)


@partial(jax.jit, static_argnames=("cfg",))
def pack(a: jax.Array, cfg: SparsityConfig) -> PackedSparse:
    """Pack a dense matrix that satisfies (or is pruned to) N:M into
    ``{values, indices}``.

    Elements beyond the ``n_effective`` magnitude-largest per group are
    dropped (i.e. ``pack(prune(a)) == pack(a)``); use :func:`satisfies_pattern`
    first if lossless packing must be asserted.
    """
    _check_dims(a.shape, cfg.m)
    r, kdim = a.shape
    g = kdim // cfg.m
    ne = cfg.n_effective
    grp = a.reshape(r, g, cfg.m)
    mag = jnp.abs(grp)
    # top_k by magnitude; indices are positions within the group.
    _, idx = jax.lax.top_k(mag, ne)                      # (R, G, Ne)
    idx = jnp.sort(idx, axis=-1)                          # canonical order
    vals = jnp.take_along_axis(grp, idx, axis=-1)         # (R, G, Ne)
    # Padded slots (zero values) are pointed at column 0 with value 0.
    vals = jnp.where(vals != 0, vals, jnp.zeros((), a.dtype))
    idx = jnp.where(vals != 0, idx, jnp.zeros((), jnp.int32))
    return PackedSparse(values=vals, indices=idx.astype(jnp.int32), cfg=cfg,
                        shape=(r, kdim))


@partial(jax.jit, static_argnames=("cfg", "shape"))
def unpack(values: jax.Array, indices: jax.Array, cfg: SparsityConfig,
           shape: tuple) -> jax.Array:
    """Scatter a packed representation back to a dense (R, K) matrix."""
    r, kdim = shape
    g = kdim // cfg.m
    ne = cfg.n_effective
    assert values.shape == (r, g, ne), (values.shape, (r, g, ne))
    # One-hot scatter: out[r, g, m] = sum_n values[r, g, n] * [indices==m]
    iota = jnp.arange(cfg.m, dtype=jnp.int32)
    onehot = (indices[..., None] == iota).astype(values.dtype)  # (R,G,Ne,M)
    dense = jnp.einsum("rgn,rgnm->rgm", values, onehot)
    return dense.reshape(r, kdim)


def unpack_packed(p: PackedSparse) -> jax.Array:
    return unpack(p.values, p.indices, p.cfg, p.shape)


# ---------------------------------------------------------------------------
# PackedWeight — the first-class packed-weight pytree
# ---------------------------------------------------------------------------

# Known packed layouts.  ``xwT`` is the serving orientation (y = x @ W^T with
# W row-sparse along the contraction dim); ``block`` is the two-level
# block-sparse format of kernels/demm_block_spmm.py — per row-block
# active-group lists (level 1) over the usual relaxed N:M packed pairs
# (level 2), converted ahead of time by :func:`pack_block`.
LAYOUT_XWT = "xwT"
LAYOUT_BLOCK = "block"
LAYOUTS = (LAYOUT_XWT, LAYOUT_BLOCK)

# Row-block height for the block layout: the MXU tile on TPU.  pack_block
# clamps it to the largest power-of-two divisor of the row count.
DEFAULT_BLOCK_R = 128

# Known quantized value dtypes.  ``None`` (the default) means the values
# child carries full-precision floats; ``"int8"`` means symmetric int8 with
# a traced ``scales`` child (per output row for the xwT layout, per
# (row-block, group, row) for the block layout) — see ``repro.quant``.
QDTYPE_INT8 = "int8"
QDTYPES = (QDTYPE_INT8,)


def expand_scales(scales: jax.Array, values: jax.Array) -> jax.Array:
    """Broadcast per-unit quantization scales over the packed value axes.

    The single home for the rank rule every dequant site shares
    (``repro.quant``, the kernels' references, ``sparsetrain.vjp``): the
    scale shape is a prefix of the values shape, so units owning one
    trailing axis (per-group xwT, the block layout's per-(row-block, group,
    row)) add one axis and per-row xwT units add two.
    """
    if scales.ndim == values.ndim - 1:
        return scales[..., None]
    return scales[..., None, None]


class PackedWeight:
    """A packed relaxed-N:M sparse weight as a registered JAX pytree.

    This is the paper's ``{value, col_idx}`` stream as a first-class object:
    ``values``/``indices`` are traced children (so ``jax.tree.map``, scan
    stacking, optimizers, and shardings all see them), while the
    :class:`SparsityConfig` (including k-reconfiguration), the per-layer
    dense ``(out, in)`` shape, and the ``layout`` tag ride along as static
    aux data — available at trace time for kernel dispatch and autotuning.

    Shapes: for the ``xwT`` layout ``values``/``indices`` are
    ``(*stack, O, G, Ne)`` with ``G = in_features // cfg.m`` and
    ``Ne = cfg.n_effective``.  For the ``block`` layout they are
    ``(RB, A_max, block_r, Ne)`` with a third traced child
    ``active_groups (RB, A_max) int32`` — the level-1 address stream that
    gates which B blocks the kernel DMAs at all — and the static block
    geometry ``block_geom = (block_r, a_max)`` rides in the aux data.
    ``dense_shape`` is always the per-layer 2-D ``(O, K)`` (leading stack
    dims — e.g. the scan-stacked layer axis — do not change it).

    Quantization (``repro.quant``): when ``qdtype`` is set (static aux, e.g.
    ``"int8"``) the ``values`` child holds quantized integers and a fourth
    traced child ``scales`` carries the symmetric dequantization scales —
    ``(*stack, O)`` float32 (per output row, the default) or
    ``(*stack, O, G)`` (per group) for ``xwT``,
    ``(*stack, RB, A_max, block_r)`` (per row-block × group × row) for
    ``block``.  The dense weight is ``scales ⊙ values`` broadcast over the
    packed axes; kernels dequantize in-register (w8a16).

    Contraction-dim sharding (``repro.sharding``): ``shard_axis`` (static,
    e.g. ``"model"``) marks the *shard-stacked* form produced by
    :func:`shard_packed_row_parallel` — the children carry an extra dim of
    size ``shards`` **between** the stack dims and the layout core, each
    slice locally renumbered over its ``K // shards`` column chunk, so a
    mesh can place one slice per device and combine partial products with
    ``psum``.  ``dense_shape`` stays the *global* ``(O, K)``; for the
    ``block`` layout ``block_geom[1]`` becomes the shared per-shard
    ``a_max``.  A *local* per-shard slice (inside ``shard_map``, see
    :func:`shard_slice`) instead has ``shard_axis=None`` with a local
    ``dense_shape`` and keeps ``shards`` as provenance so kernel dispatch
    and tune-cache keys can tell a shard-local problem from a global one.
    """

    __slots__ = ("values", "indices", "cfg", "dense_shape", "layout",
                 "active_groups", "block_geom", "scales", "qdtype",
                 "shard_axis", "shards", "tier_ne")

    def __init__(self, values, indices, *, cfg: SparsityConfig, dense_shape,
                 layout: str = LAYOUT_XWT, active_groups=None,
                 block_geom=None, scales=None, qdtype=None,
                 shard_axis=None, shards: int = 1, tier_ne=None):
        if not isinstance(cfg, SparsityConfig):
            raise TypeError(f"cfg must be a SparsityConfig, got {type(cfg)}")
        if layout not in LAYOUTS:
            raise ValueError(f"unknown layout {layout!r}; expected {LAYOUTS}")
        if qdtype is None:
            if scales is not None:
                raise ValueError(
                    "scales only apply to quantized weights; set qdtype "
                    "(repro.quant.quantize_packed does both)")
        else:
            if qdtype not in QDTYPES:
                raise ValueError(
                    f"unknown qdtype {qdtype!r}; expected one of {QDTYPES}")
            if scales is None:
                raise ValueError(
                    f"qdtype={qdtype!r} needs the scales child; quantize "
                    "with repro.quant.quantize_packed")
        dense_shape = tuple(int(d) for d in dense_shape)
        if len(dense_shape) != 2:
            raise ValueError(f"dense_shape must be 2-D (out, in), got "
                             f"{dense_shape}")
        shards = int(shards)
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if shard_axis is not None:
            if not isinstance(shard_axis, str):
                raise TypeError(f"shard_axis must be a mesh axis name "
                                f"(str) or None, got {shard_axis!r}")
            if shards < 2:
                raise ValueError(
                    "shard_axis set but shards < 2; the shard-stacked form "
                    "needs the shard-count dim (shard_packed_row_parallel)")
        vshape = getattr(values, "shape", None)
        if layout == LAYOUT_BLOCK:
            if active_groups is None:
                raise ValueError(
                    "block layout needs the active_groups child (the level-1 "
                    "address stream); pack with pack_block")
            if block_geom is None:
                if vshape is None or len(vshape) < 4:
                    raise ValueError(
                        "block layout needs block_geom=(block_r, a_max) when "
                        "values carry no shape to derive it from")
                block_geom = (int(vshape[-2]), int(vshape[-3]))
            block_geom = (int(block_geom[0]), int(block_geom[1]))
            if vshape is not None and len(vshape) >= 4:
                rb, amax, br, ne = (int(d) for d in vshape[-4:])
                if (ne != cfg.n_effective or br != block_geom[0]
                        or amax != block_geom[1] or rb * br != dense_shape[0]):
                    raise ValueError(
                        f"values shape {tuple(vshape)} is inconsistent with "
                        f"block_geom={block_geom} over dense {dense_shape} "
                        f"at cfg={cfg.pattern_name()}: expected "
                        f"(*, {dense_shape[0] // block_geom[0]}, "
                        f"{block_geom[1]}, {block_geom[0]}, "
                        f"{cfg.n_effective})")
            if (shard_axis is not None and vshape is not None
                    and len(vshape) >= 5 and int(vshape[-5]) != shards):
                raise ValueError(
                    f"shard-stacked block values {tuple(vshape)} carry "
                    f"{int(vshape[-5])} shard slices, expected shards="
                    f"{shards}")
        else:
            if active_groups is not None or block_geom is not None:
                raise ValueError(
                    f"active_groups/block_geom only apply to the "
                    f"{LAYOUT_BLOCK!r} layout, not {layout!r}")
            if vshape is not None and len(vshape) >= 3:
                g, ne = int(vshape[-2]), int(vshape[-1])
                # Shard-stacked values hold G // shards groups per slice.
                span = shards if shard_axis is not None else 1
                if ne != cfg.n_effective or g * cfg.m * span != dense_shape[1]:
                    raise ValueError(
                        f"values shape {tuple(vshape)} is inconsistent with "
                        f"the packed layout of cfg={cfg.pattern_name()} over "
                        f"dense {dense_shape}: expected "
                        f"(*, {dense_shape[1] // (cfg.m * span)}, "
                        f"{cfg.n_effective})")
            if (shard_axis is not None and vshape is not None
                    and len(vshape) >= 4 and int(vshape[-4]) != shards):
                raise ValueError(
                    f"shard-stacked xwT values {tuple(vshape)} carry "
                    f"{int(vshape[-4])} shard slices, expected shards="
                    f"{shards}")
        sshape = getattr(scales, "shape", None)
        if qdtype is not None and sshape is not None and vshape is not None:
            if layout == LAYOUT_BLOCK:
                want = (tuple(vshape[:-1]),)
            else:
                # xwT grants two granularities (repro.quant): per output
                # row (*stack, O) or per (row, group) (*stack, O, G).
                want = (tuple(vshape[:-2]), tuple(vshape[:-1]))
            if tuple(sshape) not in want:
                raise ValueError(
                    f"scales shape {tuple(sshape)} does not match values "
                    f"{tuple(vshape)} for the {layout!r} layout: expected "
                    f"one of {want} (per output row / per group for xwT, "
                    f"per row-block × group × row for block)")
        if tier_ne is not None:
            tier_ne = int(tier_ne)
            if not 1 <= tier_ne <= cfg.n_effective:
                raise ValueError(
                    f"tier_ne={tier_ne} outside [1, n_effective="
                    f"{cfg.n_effective}] of cfg={cfg.pattern_name()}")
            if tier_ne == cfg.n_effective:
                tier_ne = None      # the full tier is the canonical no-view
        self.values = values
        self.indices = indices
        self.cfg = cfg
        self.dense_shape = dense_shape
        self.layout = layout
        self.active_groups = active_groups
        self.block_geom = block_geom
        self.scales = scales
        self.qdtype = qdtype
        self.shard_axis = shard_axis
        self.shards = shards
        self.tier_ne = tier_ne

    # ---- static geometry -------------------------------------------------
    @property
    def out_features(self) -> int:
        return self.dense_shape[0]

    @property
    def in_features(self) -> int:
        return self.dense_shape[1]

    @property
    def groups(self) -> int:
        return self.in_features // self.cfg.m

    @property
    def stack_dims(self) -> tuple:
        """Leading (scan/vmap) stack dims in front of the layout's core:
        (O, G, Ne) for ``xwT``, (RB, A_max, block_r, Ne) for ``block``.
        The shard-stacked form's shard dim sits between the stack dims and
        the core (so layer-scan still slices axis 0) and is not a stack
        dim."""
        shape = getattr(self.values, "shape", None)
        if shape is None:
            return ()
        core = 4 if self.layout == LAYOUT_BLOCK else 3
        if self.shard_axis is not None:
            core += 1
        return tuple(shape[:-core])

    def replace(self, **kw) -> "PackedWeight":
        out = {"values": self.values, "indices": self.indices,
               "cfg": self.cfg, "dense_shape": self.dense_shape,
               "layout": self.layout, "active_groups": self.active_groups,
               "block_geom": self.block_geom, "scales": self.scales,
               "qdtype": self.qdtype, "shard_axis": self.shard_axis,
               "shards": self.shards, "tier_ne": self.tier_ne}
        out.update(kw)
        return PackedWeight(out.pop("values"), out.pop("indices"), **out)

    def __repr__(self):
        vs = getattr(self.values, "shape", "?")
        geom = f", block_geom={self.block_geom}" if self.block_geom else ""
        q = f", qdtype={self.qdtype!r}" if self.qdtype else ""
        sh = ""
        if self.shards > 1:
            sh = f", shards={self.shards}"
            if self.shard_axis is not None:
                sh += f" over {self.shard_axis!r}"
        tier = f", tier_ne={self.tier_ne}" if self.tier_ne else ""
        return (f"PackedWeight(values={vs}, cfg={self.cfg.pattern_name()!r}, "
                f"dense_shape={self.dense_shape}, layout={self.layout!r}"
                f"{geom}{q}{sh}{tier})")

    # ---- conversions -----------------------------------------------------
    @classmethod
    def from_dense(cls, w: jax.Array, cfg: SparsityConfig,
                   layout: str = LAYOUT_XWT, *, block_r: "int | None" = None,
                   a_max: "int | None" = None) -> "PackedWeight":
        """Prune (if needed) and pack a dense 2-D weight into ``layout``."""
        if layout == LAYOUT_BLOCK:
            return pack_block(w, cfg, block_r=block_r, a_max=a_max)
        p = pack(prune(w, cfg), cfg)
        return cls(p.values, p.indices, cfg=cfg, dense_shape=w.shape,
                   layout=layout)

    def dequantized_values(self) -> jax.Array:
        """The values child with quantization scales applied (float32 for a
        quantized weight; the raw values otherwise).  The scale shape is a
        prefix of the values shape, so per-row vs per-group xwT scales (and
        the block layout's per-(row-block, group, row) scales) are told
        apart by rank alone."""
        if self.qdtype is None:
            return self.values
        vals = self.values.astype(jnp.float32)
        return vals * expand_scales(self.scales, vals)

    def to_dense(self) -> jax.Array:
        """Scatter back to the dense weight (dequantizing if needed),
        restoring any stack dims.  Shard-stacked weights are merged back to
        the global packing first (concrete data only for ``block``); a
        draft-tier view (``tier_ne``) densifies only its tier prefix."""
        if self.tier_ne is not None:
            return narrow_tier(self).to_dense()
        if self.shard_axis is not None:
            return unshard_packed(self).to_dense()
        o, k = self.dense_shape
        if self.layout == LAYOUT_BLOCK:
            stack = self.stack_dims
            ag, vals, idxs = (self.active_groups, self.dequantized_values(),
                              self.indices)
            if stack:
                ag = ag.reshape(-1, *ag.shape[-2:])
                vals = vals.reshape(-1, *vals.shape[-4:])
                idxs = idxs.reshape(-1, *idxs.shape[-4:])
                dense = jax.vmap(lambda a, v, i: unpack_block(
                    a, v, i, self.cfg, self.dense_shape))(ag, vals, idxs)
                return dense.reshape(*stack, o, k)
            return unpack_block(ag, vals, idxs, self.cfg, self.dense_shape)
        vals, idxs = self.dequantized_values(), self.indices
        stack = self.stack_dims
        if stack:
            vals = vals.reshape(-1, *vals.shape[-2:])
            idxs = idxs.reshape(-1, *idxs.shape[-2:])
        dense = unpack(vals, idxs, self.cfg, (vals.shape[0], k))
        return dense.reshape(*stack, o, k) if stack else dense


def _pw_flatten(pw: PackedWeight):
    aux = (pw.cfg, pw.dense_shape, pw.layout, pw.block_geom, pw.qdtype,
           pw.shard_axis, pw.shards, pw.tier_ne)
    children = [pw.values, pw.indices]
    if pw.layout == LAYOUT_BLOCK:
        children.append(pw.active_groups)
    if pw.qdtype is not None:
        children.append(pw.scales)
    return tuple(children), aux


def _pw_flatten_with_keys(pw: PackedWeight):
    keyed = [(jax.tree_util.GetAttrKey("values"), pw.values),
             (jax.tree_util.GetAttrKey("indices"), pw.indices)]
    if pw.layout == LAYOUT_BLOCK:
        keyed.append((jax.tree_util.GetAttrKey("active_groups"),
                      pw.active_groups))
    if pw.qdtype is not None:
        keyed.append((jax.tree_util.GetAttrKey("scales"), pw.scales))
    return tuple(keyed), (pw.cfg, pw.dense_shape, pw.layout, pw.block_geom,
                          pw.qdtype, pw.shard_axis, pw.shards, pw.tier_ne)


def _pw_unflatten(aux, children) -> PackedWeight:
    # Raw rebuild, no __init__ validation: tree transforms routinely carry
    # non-array leaves (None results, PartitionSpecs, sentinel objects) and
    # the aux was validated when the weight was packed.
    cfg, dense_shape, layout, block_geom, qdtype, shard_axis, shards, \
        tier_ne = aux
    pw = object.__new__(PackedWeight)
    children = list(children)
    scales = children.pop() if qdtype is not None else None
    if layout == LAYOUT_BLOCK:
        values, indices, active_groups = children
    else:
        (values, indices), active_groups = children, None
    pw.values = values
    pw.indices = indices
    pw.cfg = cfg
    pw.dense_shape = dense_shape
    pw.layout = layout
    pw.active_groups = active_groups
    pw.block_geom = block_geom
    pw.scales = scales
    pw.qdtype = qdtype
    pw.shard_axis = shard_axis
    pw.shards = shards
    pw.tier_ne = tier_ne
    return pw


jax.tree_util.register_pytree_with_keys(
    PackedWeight, _pw_flatten_with_keys, _pw_unflatten, _pw_flatten)


# ---------------------------------------------------------------------------
# Two-level block packing (the "block" layout)
# ---------------------------------------------------------------------------

def _choose_block_r(rows: int, cap: int = DEFAULT_BLOCK_R) -> int:
    """Largest power-of-two divisor of ``rows``, capped at ``cap``."""
    br = 1
    while br * 2 <= cap and rows % (br * 2) == 0:
        br *= 2
    return br


def _group_activity(w: jax.Array, block_r: int, m: int) -> jax.Array:
    """Active-group mask ``(..., RB, G)`` of ``(..., R, K)``: a group is
    active when any row of the row block has a non-zero in it.  The single
    home for the level-1 activity definition, shared by the stacked and
    unstacked packers so their ``a_max`` bounds can never diverge."""
    *lead, r, k = w.shape
    blocks = w.reshape(*lead, r // block_r, block_r, k // m, m)
    return jnp.any(blocks != 0, axis=(-3, -1))


def _needed_a_max(activity: jax.Array) -> int:
    """Max active groups over every row block (>= 1; concrete data only)."""
    return max(1, int(jnp.max(jnp.sum(activity, axis=-1))))


def pack_block(a: jax.Array, cfg: SparsityConfig, *,
               block_r: "int | None" = None,
               a_max: "int | None" = None) -> PackedWeight:
    """Ahead-of-time two-level conversion to the ``block`` layout.

    Level 1: per ``block_r``-row block, the sorted list of *active* M-groups
    (groups where any row of the block has a non-zero) — the address stream
    that gates which B blocks the kernel DMAs from HBM at all.  Level 2:
    within each active group, the usual relaxed N:M ``{values, indices}``
    pairs (magnitude top-``n_effective`` per row, like :func:`pack`).

    ``a_max`` bounds the active-group list length (static — it shapes the
    packed arrays).  When ``None`` it is computed from the data; under
    tracing (``jax.eval_shape`` dry-runs) data is unavailable, so the
    conservative upper bound ``G`` is used — pass ``a_max`` explicitly for
    shape-exact dry-runs.  An ``a_max`` larger than ``G`` pads with
    inactive slots (matching an existing checkpoint's geometry); an
    undersized ``a_max`` raises on concrete inputs, but **cannot be checked
    under tracing** (the bound is data-dependent): a traced call with an
    explicit ``a_max`` below the true active count silently truncates, so
    the caller owns that bound — pack on concrete weights (the AOT path)
    when in doubt.  Padded slots point at group 0 with all-zero values and
    contribute nothing.

    Returns a :class:`PackedWeight` with ``layout="block"``, traced children
    ``values``/``indices`` ``(RB, A_max, block_r, Ne)`` +
    ``active_groups (RB, A_max) int32``, and static
    ``block_geom=(block_r, a_max)`` in the aux.
    """
    _check_dims(a.shape, cfg.m)
    r, kdim = a.shape
    g = kdim // cfg.m
    ne = cfg.n_effective
    if block_r is None:
        block_r = _choose_block_r(r)
    if r % block_r:
        raise ValueError(f"rows {r} not divisible by block_r={block_r}")
    rb = r // block_r
    concrete = not isinstance(a, jax.core.Tracer)

    blocks = jnp.asarray(a).reshape(rb, block_r, g, cfg.m)
    activity = _group_activity(jnp.asarray(a), block_r, cfg.m)  # (RB, G)
    if a_max is None:
        a_max = _needed_a_max(activity) if concrete else g
    a_max = int(a_max)
    if concrete:
        needed = _needed_a_max(activity)
        if needed > a_max:
            raise ValueError(f"a_max={a_max} < {needed} active groups in the "
                             "densest row block")

    # Stable sort by (active desc, group id asc): actives first, ascending.
    sel_w = min(a_max, g)
    order = jnp.argsort(-activity.astype(jnp.int32), axis=-1,
                        stable=True)[:, :sel_w]                # (RB, sel_w)
    active = jnp.take_along_axis(activity, order, axis=-1)     # bool
    if a_max > sel_w:
        # a_max beyond the group count (e.g. matching an existing
        # checkpoint's geometry): pad with inactive slots.
        order = jnp.pad(order, ((0, 0), (0, a_max - sel_w)))
        active = jnp.pad(active, ((0, 0), (0, a_max - sel_w)))
    ag = jnp.where(active, order, 0).astype(jnp.int32)

    grp = jnp.swapaxes(blocks, 1, 2)                           # (RB, G, br, M)
    sel = jnp.take_along_axis(
        grp, order[:, :, None, None].astype(jnp.int32), axis=1
    )                                                          # (RB, A, br, M)
    mag = jnp.abs(sel)
    _, idx = jax.lax.top_k(mag, ne)                            # (RB, A, br, Ne)
    idx = jnp.sort(idx, axis=-1)
    vals = jnp.take_along_axis(sel, idx, axis=-1)
    # Padded slots alias group 0: zero them so duplicates contribute nothing.
    vals = jnp.where(active[:, :, None, None], vals, jnp.zeros((), a.dtype))
    idx = jnp.where(vals != 0, idx, jnp.zeros((), jnp.int32))
    return PackedWeight(vals, idx.astype(jnp.int32), cfg=cfg,
                        dense_shape=(r, kdim), layout=LAYOUT_BLOCK,
                        active_groups=ag, block_geom=(block_r, a_max))


def pack_block_stacked(w: jax.Array, cfg: SparsityConfig, *,
                       block_r: "int | None" = None,
                       a_max: "int | None" = None) -> PackedWeight:
    """:func:`pack_block` for layer-stacked weights ``(*lead, O, K)``.

    All slices share one static ``a_max`` (the max active-group count over
    the stack) so the packed children stack to ``(*lead, RB, A_max, block_r,
    Ne)`` / ``(*lead, RB, A_max)`` and ``jax.lax.scan`` can slice the layer
    axis off exactly as for the xwT layout; ``dense_shape``/``block_geom``
    stay the per-layer statics."""
    lead = tuple(w.shape[:-2])
    if not lead:
        return pack_block(w, cfg, block_r=block_r, a_max=a_max)
    o, kdim = int(w.shape[-2]), int(w.shape[-1])
    _check_dims((o, kdim), cfg.m)
    if block_r is None:
        block_r = _choose_block_r(o)
    g = kdim // cfg.m
    wf = jnp.asarray(w).reshape(-1, o, kdim)
    concrete = not isinstance(w, jax.core.Tracer)
    if a_max is None:
        a_max = (_needed_a_max(_group_activity(wf, block_r, cfg.m))
                 if concrete else g)
    elif concrete:
        # Validate here: the per-slice packers below run under vmap, where
        # every input is a tracer and pack_block's own too-small-a_max check
        # is skipped — without this, an undersized a_max would silently drop
        # weights from the densest slice.
        needed = _needed_a_max(_group_activity(wf, block_r, cfg.m))
        if needed > int(a_max):
            raise ValueError(f"a_max={a_max} < {needed} active groups in "
                             "the densest row block of the stack")
    packed = jax.vmap(
        lambda a: pack_block(a, cfg, block_r=block_r, a_max=a_max))(wf)

    def fix(x):
        return x.reshape(*lead, *x.shape[1:])

    return packed.replace(values=fix(packed.values),
                          indices=fix(packed.indices),
                          active_groups=fix(packed.active_groups))


@partial(jax.jit, static_argnames=("cfg", "shape"))
def unpack_block(active_groups: jax.Array, values: jax.Array,
                 indices: jax.Array, cfg: SparsityConfig,
                 shape: tuple) -> jax.Array:
    """Scatter a two-level block packing back to a dense (R, K) matrix.
    Duplicate active-group ids accumulate (matching the kernel's
    revisit-accumulate semantics); padded all-zero slots contribute 0."""
    r, kdim = shape
    rb, a_max, block_r, ne = values.shape
    g = kdim // cfg.m
    assert rb * block_r == r, (values.shape, shape)
    iota = jnp.arange(cfg.m, dtype=jnp.int32)
    onehot = (indices[..., None] == iota).astype(values.dtype)
    per_slot = jnp.einsum("rabn,rabnm->rabm", values, onehot)  # (RB,A,br,M)

    def per_block(ag_b, slot_b):
        dense_b = jnp.zeros((block_r, g, cfg.m), values.dtype)
        return dense_b.at[:, ag_b, :].add(jnp.swapaxes(slot_b, 0, 1))

    dense = jax.vmap(per_block)(active_groups, per_slot)       # (RB,br,G,M)
    return dense.reshape(r, kdim)


# ---------------------------------------------------------------------------
# Contraction-dim sharding: the per-shard active-group renumbering pass
# ---------------------------------------------------------------------------
#
# Row-parallel (y = x @ W^T with the contraction dim split across devices)
# is where packed weights resist GSPMD: xwT indices are group-local so the
# G axis slices consistently, but the block layout's active_groups hold
# data-dependent *global* group ids — a device owning columns
# [s*K/S, (s+1)*K/S) must drop foreign groups and renumber the rest to its
# local coordinate frame before the kernel's address stream makes sense.
# These host-side passes produce the shard-stacked form consumed by the
# shard_map island in kernels/ops.py: children gain a size-S dim between
# the stack dims and the layout core, each slice renumbered over
# K_local = K/S, partial products combined with psum.

def _block_shard_arrays(pw: "PackedWeight", num_shards: int):
    """Concrete host arrays + the per-slot validity mask for block resharding.

    A slot is live iff any of its packed values is non-zero — exact for
    float block packings (an active group always keeps >= 1 non-zero;
    padded slots are all-zero by construction), unreliable for int8 where
    quantization may round a group's survivors to zero."""
    if pw.qdtype is not None:
        raise NotImplementedError(
            "renumbering quantized block weights is not supported (the "
            "all-zero-slot liveness test is unreliable under int8); keep "
            "them replicated (ShardingPlan(renumber='replicate'))")
    try:
        vals = np.asarray(pw.values)
        idx = np.asarray(pw.indices)
        ag = np.asarray(pw.active_groups)
    except jax.errors.TracerArrayConversionError as e:
        raise ValueError(
            "shard_packed_row_parallel needs concrete block weights (the "
            "per-shard a_max is data-dependent); reshard outside jit") from e
    return vals, idx, ag, np.any(vals != 0, axis=(-2, -1))


def shard_packed_row_parallel(pw: "PackedWeight", num_shards: int, *,
                              axis: str = "model") -> "PackedWeight":
    """Reshard a row-parallel packed weight over the contraction dim.

    Returns the shard-stacked form: children carry an extra dim of size
    ``num_shards`` between the stack dims and the layout core, slice ``s``
    holding the packing of columns ``[s*K/S, (s+1)*K/S)`` renumbered to its
    local frame.  ``xwT`` needs only a reshape (indices are group-local);
    ``block`` runs the renumbering pass: per (row-block, shard), foreign
    active groups are dropped, surviving global ids are rebased by the
    shard's group offset, and all shards share one static per-shard
    ``a_max`` (the densest local list).  ``dense_shape`` stays global.
    """
    num_shards = int(num_shards)
    if num_shards == 1:
        return pw
    if pw.shard_axis is not None:
        raise ValueError(f"{pw!r} is already shard-stacked")
    g = pw.groups
    if g % num_shards:
        raise ValueError(
            f"cannot split {g} groups (K={pw.in_features}, "
            f"M={pw.cfg.m}) over {num_shards} shards")
    gl = g // num_shards
    nstack = len(pw.stack_dims)

    if pw.layout == LAYOUT_XWT:
        vals, idx = pw.values, pw.indices
        # (*stack, O, G, Ne) -> (*stack, O, S, Gl, Ne) -> swap O and S
        def reshard3(x):
            x = x.reshape(*x.shape[:-2], num_shards, gl, x.shape[-1])
            return jnp.swapaxes(x, -4, -3)
        scales = pw.scales
        if scales is not None:
            if scales.ndim == vals.ndim - 1:      # per-group (*stack, O, G)
                scales = scales.reshape(*scales.shape[:-1], num_shards, gl)
                scales = jnp.swapaxes(scales, -3, -2)
            else:                                  # per-row (*stack, O)
                scales = jnp.broadcast_to(
                    scales[..., None, :],
                    (*scales.shape[:-1], num_shards, scales.shape[-1]))
        return pw.replace(values=reshard3(jnp.asarray(vals)),
                          indices=reshard3(jnp.asarray(idx)),
                          scales=scales, shard_axis=axis, shards=num_shards)

    vals, idx, ag, valid = _block_shard_arrays(pw, num_shards)
    shard_of = ag // gl                                   # (*stack, RB, A)
    per_shard = []
    for s in range(num_shards):
        in_s = valid & (shard_of == s)
        # Stable front-compaction: in-shard slots first, original (ascending
        # global id) order preserved, so local lists stay sorted.
        order = np.argsort(~in_s, axis=-1, kind="stable")
        per_shard.append((order, np.take_along_axis(in_s, order, axis=-1)))
    a_local = max(1, *(int(m.sum(-1).max()) for _, m in per_shard))

    out_v, out_i, out_a = [], [], []
    for s, (order, mask) in enumerate(per_shard):
        order, mask = order[..., :a_local], mask[..., :a_local]
        ag_s = np.take_along_axis(ag, order, axis=-1) - s * gl
        ag_s = np.where(mask, ag_s, 0).astype(np.int32)
        gather = order[..., None, None]
        v_s = np.where(mask[..., None, None],
                       np.take_along_axis(vals, gather, axis=-3), 0)
        i_s = np.where(v_s != 0,
                       np.take_along_axis(idx, gather, axis=-3),
                       0).astype(np.int32)
        out_v.append(v_s)
        out_i.append(i_s)
        out_a.append(ag_s)
    return pw.replace(values=jnp.asarray(np.stack(out_v, axis=nstack)),
                      indices=jnp.asarray(np.stack(out_i, axis=nstack)),
                      active_groups=jnp.asarray(np.stack(out_a, axis=nstack)),
                      block_geom=(pw.block_geom[0], a_local),
                      shard_axis=axis, shards=num_shards)


def unshard_packed(pw: "PackedWeight") -> "PackedWeight":
    """Merge a shard-stacked weight back to the global packing (the inverse
    renumbering).  Exact round trip up to ``a_max`` re-tightening and the
    canonical active-list order — compare via :meth:`PackedWeight.to_dense`.
    Needs concrete data for the ``block`` layout."""
    if pw.shard_axis is None:
        return pw
    s_count = pw.shards
    nstack = len(pw.stack_dims)

    if pw.layout == LAYOUT_XWT:
        def merge3(x):  # (*stack, S, O, Gl, Ne) -> (*stack, O, G, Ne)
            x = jnp.swapaxes(x, -4, -3)
            return x.reshape(*x.shape[:-3], x.shape[-3] * x.shape[-2],
                             x.shape[-1])
        scales = pw.scales
        if scales is not None:
            if scales.ndim == pw.values.ndim - 1:  # per-group
                scales = jnp.swapaxes(scales, -3, -2)
                scales = scales.reshape(*scales.shape[:-2],
                                        scales.shape[-2] * scales.shape[-1])
            else:                                   # per-row: replicated
                scales = jax.lax.index_in_dim(scales, 0, axis=scales.ndim - 2,
                                              keepdims=False)
        return pw.replace(values=merge3(pw.values), indices=merge3(pw.indices),
                          scales=scales, shard_axis=None, shards=1)

    vals, idx, ag, valid = _block_shard_arrays(pw, s_count)
    gl = pw.groups // s_count
    a_loc = pw.block_geom[1]
    # Concatenate the per-shard lists along A in shard order (each slice
    # ascending within its chunk -> the merged list is globally ascending).
    ag_m = np.moveaxis(ag, nstack, -2)                 # (*stack, RB, S, Al)
    ag_m = ag_m + (np.arange(s_count) * gl)[:, None]
    ag_m = ag_m.reshape(*ag_m.shape[:-2], s_count * a_loc)
    vals_m = np.moveaxis(vals, nstack, -4)             # (*stack,RB,S,Al,br,Ne)
    vals_m = vals_m.reshape(*vals_m.shape[:-4],
                            s_count * a_loc, *vals_m.shape[-2:])
    idx_m = np.moveaxis(idx, nstack, -4)
    idx_m = idx_m.reshape(*idx_m.shape[:-4],
                          s_count * a_loc, *idx_m.shape[-2:])
    valid_m = np.moveaxis(valid, nstack, -2)
    valid_m = valid_m.reshape(*valid_m.shape[:-2], s_count * a_loc)

    a_max = max(1, int(valid_m.sum(-1).max()))
    order = np.argsort(~valid_m, axis=-1, kind="stable")[..., :a_max]
    mask = np.take_along_axis(valid_m, order, axis=-1)
    ag_g = np.where(mask, np.take_along_axis(ag_m, order, axis=-1),
                    0).astype(np.int32)
    gather = order[..., None, None]
    v_g = np.where(mask[..., None, None],
                   np.take_along_axis(vals_m, gather, axis=-3), 0)
    i_g = np.where(v_g != 0, np.take_along_axis(idx_m, gather, axis=-3),
                   0).astype(np.int32)
    return pw.replace(values=jnp.asarray(v_g), indices=jnp.asarray(i_g),
                      active_groups=jnp.asarray(ag_g),
                      block_geom=(pw.block_geom[0], a_max),
                      shard_axis=None, shards=1)


def shard_slice(pw: "PackedWeight", s) -> "PackedWeight":
    """Slice ``s`` of a shard-stacked weight as a *local* PackedWeight:
    ``dense_shape`` becomes the shard-local ``(O, K // shards)`` and
    ``shards`` is kept as provenance (tune-cache keys include it), with
    ``shard_axis=None`` so standard kernel dispatch applies.  ``s`` may be
    a traced index (used inside the shard_map island)."""
    if pw.shard_axis is None:
        raise ValueError(f"{pw!r} is not shard-stacked")
    dim = len(pw.stack_dims)
    o, k = pw.dense_shape

    def take(x):
        if x is None:
            return None
        return jnp.take(x, s, axis=dim)

    scales = pw.scales
    if scales is not None:
        scales = take(scales)
    return PackedWeight(
        take(pw.values), take(pw.indices), cfg=pw.cfg,
        dense_shape=(o, k // pw.shards), layout=pw.layout,
        active_groups=take(pw.active_groups), block_geom=pw.block_geom,
        scales=scales, qdtype=pw.qdtype, shard_axis=None, shards=pw.shards)


# ---------------------------------------------------------------------------
# Sparser-tier views (repro.spec): one buffer, two densities
# ---------------------------------------------------------------------------
#
# The inverse direction of the paper's §II-B reconfiguration: where
# ``reconfigure_k`` serves a *denser* kN:M pattern in k passes, a *tier view*
# serves a sparser pattern from the same stored stream by reading only the
# first ``tier_ne`` of the ``n_effective`` {value, col_idx} pairs per group.
# ``tier_ne`` is static aux on PackedWeight — the children are untouched, so
# a draft-tier view aliases the full tier's buffers (``draft.values is
# full.values``) and the narrowing happens at trace time inside kernel
# dispatch.  For the prefix to be the magnitude-top-``tier_ne`` slice, the
# per-group entry order must be magnitude-descending — ``tier_sort_packed``
# establishes that invariant once (full-tier compute is order-independent:
# both the one-hot scatter and the kernels' gather-accumulate sum over the
# Ne axis).

def tier_sort_packed(pw: PackedWeight) -> PackedWeight:
    """Reorder every group's {value, col_idx} pairs by descending |value|.

    Numerically a no-op for full-tier compute; it makes any prefix
    ``[:t]`` of the Ne axis the exact magnitude-top-``t`` sub-pattern, which
    is what a ``tier_ne`` draft view reads.  Sort keys are the raw packed
    magnitudes — valid for quantized weights too, because the dequant scale
    is constant along the Ne axis (per row / per group / per (rb, g, row)).
    Zero-padded slots sort last.  Stable, so equal-magnitude entries keep
    their canonical ascending-index order.
    """
    mag = jnp.abs(pw.values.astype(jnp.float32)
                  if pw.qdtype is not None else pw.values)
    order = jnp.argsort(-mag, axis=-1, stable=True)
    return pw.replace(
        values=jnp.take_along_axis(pw.values, order, axis=-1),
        indices=jnp.take_along_axis(pw.indices, order, axis=-1))


def narrow_tier(pw: PackedWeight) -> PackedWeight:
    """Materialize a ``tier_ne`` view: slice the Ne axis to the tier prefix
    and retag the config as the sparser ``tier_ne:M`` pattern.  Called at
    trace time by kernel dispatch (kernels/ops.py) — outside a trace the
    slice copies, which is exactly why the *view* form (static ``tier_ne``,
    shared buffers) is what lives in the params tree."""
    t = pw.tier_ne
    if t is None:
        return pw
    return pw.replace(
        values=pw.values[..., :t], indices=pw.indices[..., :t],
        cfg=SparsityConfig(n=t, m=pw.cfg.m, k=1), tier_ne=None)


def reconfigure_k(p: PackedSparse, k: int) -> PackedSparse:
    """View a packed kN:M matrix as ``k`` sequential N:M passes.

    Mirrors the paper's §II-B reconfiguration: an engine with N read ports
    serves a kN:M pattern by reading the same B block k times.  The packed
    (R, G, kN) tensors are reshaped to (R, G*k', ...) views consumed pass by
    pass; numerically ``sum_k demm(pass_k) == demm(full)``.
    """
    ne = p.cfg.n_effective
    if ne % k:
        raise ValueError(f"cannot split n_effective={ne} into k={k} passes")
    n_pass = ne // k
    r, g, _ = p.values.shape
    vals = p.values.reshape(r, g, k, n_pass)
    idx = p.indices.reshape(r, g, k, n_pass)
    return dataclasses.replace(
        p,
        values=vals.reshape(r, g * k, n_pass),
        indices=idx.reshape(r, g * k, n_pass),
        cfg=SparsityConfig(n=n_pass, m=p.cfg.m, k=k),
    )


# ---------------------------------------------------------------------------
# Host-side helpers (numpy; used by data/checkpoint tooling and tests)
# ---------------------------------------------------------------------------

def random_sparse_dense(rng: np.random.Generator, rows: int, cols: int,
                        cfg: SparsityConfig, dtype=np.float32) -> np.ndarray:
    """A dense matrix exactly satisfying N:M (each group gets <= n_effective
    non-zeros at uniformly random positions)."""
    _check_dims((rows, cols), cfg.m)
    g = cols // cfg.m
    out = np.zeros((rows, g, cfg.m), dtype=dtype)
    ne = cfg.n_effective
    for rr in range(rows):
        for gg in range(g):
            nnz = rng.integers(0, ne + 1)
            if nnz:
                pos = rng.choice(cfg.m, size=nnz, replace=False)
                out[rr, gg, pos] = rng.standard_normal(nnz).astype(dtype)
    return out.reshape(rows, cols)
