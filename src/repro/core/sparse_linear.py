"""SparseLinear — the paper's technique as a first-class layer.

Pure-functional (pytree params) linear layer with three execution modes:

* ``dense``  — ordinary dense matmul (baseline / non-sparse layers).
* ``masked`` — dense weight projected to N:M with straight-through gradients
               (the training path; XLA sees a dense matmul so TP sharding and
               remat behave exactly as for dense weights).
* ``packed`` — weight stored as DeMM packed {values, indices}; the forward
               pass is a DeMM spmm (the serving path).  HBM traffic for the
               weight drops by ``cfg.compression_ratio()``.

``pack_params`` converts a trained masked layer to the packed serving form.
The matmul convention is ``y = x @ W^T`` with W of shape (out, in): W is the
sparse matrix A of the paper (row-sparse along the contraction dim) and the
activations are the dense matrix B.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pruning import masked_weight
from repro.core.sparsity import PackedSparse, SparsityConfig, pack, prune


def init_dense(key, in_features: int, out_features: int, dtype=jnp.float32,
               scale: Optional[float] = None):
    scale = scale if scale is not None else in_features ** -0.5
    w = jax.random.normal(key, (out_features, in_features), dtype) * scale
    return {"w": w}


def init_sparse(key, in_features: int, out_features: int, cfg: SparsityConfig,
                dtype=jnp.float32, scale: Optional[float] = None):
    """Initialize a masked-mode sparse linear (dense weight, pattern applied
    in the forward pass)."""
    p = init_dense(key, in_features, out_features, dtype, scale)
    return {"w": prune(p["w"], cfg)}


def apply_dense(params, x: jax.Array) -> jax.Array:
    w = params["w"]
    return jnp.einsum("...k,ok->...o", x, w.astype(x.dtype))


def apply_masked(params, x: jax.Array, cfg: SparsityConfig) -> jax.Array:
    w = masked_weight(params["w"], cfg)
    return jnp.einsum("...k,ok->...o", x, w.astype(x.dtype))


def pack_params(params, cfg: SparsityConfig) -> dict:
    """Convert a trained masked layer to the packed DeMM serving form."""
    from repro.models.layers import Static

    w = prune(params["w"], cfg)
    packed = pack(w, cfg)
    return {"values": packed.values, "indices": packed.indices,
            "shape": Static(tuple(w.shape))}


def apply_packed(params, x: jax.Array, cfg: SparsityConfig,
                 backend: str = "reference") -> jax.Array:
    """y = x @ W^T with W packed.

    backend:
      * ``reference``        — jnp one-hot decompress + matmul (used inside
                               jit-compiled distributed steps; XLA fuses the
                               decompress, HBM sees only packed bytes).
      * ``pallas``           — the fused Pallas TPU kernel (real hardware).
      * ``pallas_interpret`` — the same kernel in interpret mode (CPU checks).
      * ``auto``             — per-(shape, dtype, pattern, platform) choice
                               from the ``repro.tune`` cache/heuristics;
                               pre-measure with ``repro.tune.autotune_xwT``
                               or ``benchmarks/kernel_bench.py --autotune``.
    """
    from repro.kernels import ops

    values, indices = params["values"], params["indices"]
    shape = params["shape"]
    out_features, in_features = (shape.value if hasattr(shape, "value")
                                 else shape)
    xs = x.reshape(-1, x.shape[-1])
    y = ops.demm_matmul_xwT(
        xs, values, indices, cfg, (out_features, in_features), backend=backend
    )
    return y.reshape(*x.shape[:-1], out_features).astype(x.dtype)
