"""SparseLinear — the paper's technique as a first-class layer.

Pure-functional (pytree params) linear layer with one entry point,

    y = apply(params, x, policy)

where ``params`` is either

* a dense/masked node ``{"w": (O, K) array[, "sparsity": Static(cfg)]}`` —
  the training form (XLA sees a dense matmul, so TP sharding and remat
  behave exactly as for dense weights), or
* a :class:`~repro.core.sparsity.PackedWeight` — the DeMM packed serving
  form, whose forward pass is a DeMM spmm streaming only packed bytes
  (weight HBM traffic drops by ``cfg.compression_ratio()``),

and :class:`ExecPolicy` carries the execution choice (``mode`` for
dense-weight nodes, kernel ``backend``, optional sparsity-config overrides)
that used to be threaded through the model stack as loose ``mode=``/
``backend=`` string pairs.

``pack_params`` converts a trained masked layer to a ``PackedWeight``.  The
matmul convention is ``y = x @ W^T`` with W of shape (out, in): W is the
sparse matrix A of the paper (row-sparse along the contraction dim) and the
activations are the dense matrix B.

The pre-PackedWeight dict conventions (``{values, indices, shape,
_sparse_m, _sparse_n}`` packed nodes; ``_sparse_m``/``_sparse_n`` masked
metadata) went through one release of deprecation shims and are now
rejected with a ValueError pointing at ``launch.pack_tree`` /
``init_linear``.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Mapping, Optional, Union

if TYPE_CHECKING:   # core must not import sharding at runtime (layering)
    from repro.sharding.plan import ShardingPlan

import jax
import jax.numpy as jnp

from repro.core.pruning import masked_weight
from repro.core.sparsity import (
    LAYOUT_XWT,
    PackedWeight,
    SparsityConfig,
    Static,
    pack,
    prune,
)

MODES = ("dense", "masked", "packed")


@dataclasses.dataclass(frozen=True)
class ExecPolicy:
    """How a (sparse) linear is executed.

    * ``mode``    — ``dense`` | ``masked`` | ``packed``.  Only meaningful for
      dense-weight nodes (``dense`` skips the N:M mask, ``masked``/``packed``
      apply it); a :class:`PackedWeight` node always executes the packed
      DeMM path regardless of mode.
    * ``backend`` — kernel backend for packed matmuls: any name registered
      in ``repro.tune`` (``reference``, ``pallas``, ``pallas_interpret``,
      ...) or ``auto`` (per-(shape, dtype, pattern, platform) resolution
      through the tuning cache).
    * ``cfg_overrides`` — optional :class:`SparsityConfig` field overrides
      (e.g. ``{"k": 2}``) applied to the node's stored config before the
      mask/kernel runs.  For packed nodes the override must preserve
      ``n_effective`` (the packed array layout is fixed at pack time).
    * ``plan`` — optional :class:`~repro.sharding.plan.ShardingPlan`
      describing how the params this policy executes against are
      distributed (TP/PP/DP degrees, mesh axes, renumber policy).  The
      policy itself stays placement-agnostic — engines and step builders
      read the plan to build meshes, renumber packed weights, and install
      the sharding context; a plan is frozen/hashable so it rides along as
      a jit static argument.

    Hashable (static-safe under jit); ``cfg_overrides`` dicts are
    normalized to sorted item tuples.
    """

    mode: str = "masked"
    backend: str = "reference"
    cfg_overrides: Union[tuple, Mapping[str, int]] = ()
    plan: Optional["ShardingPlan"] = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; expected {MODES}")
        if isinstance(self.cfg_overrides, Mapping):
            object.__setattr__(self, "cfg_overrides",
                               tuple(sorted(self.cfg_overrides.items())))
        else:
            object.__setattr__(self, "cfg_overrides",
                               tuple(self.cfg_overrides))

    def replace(self, **kw) -> "ExecPolicy":
        return dataclasses.replace(self, **kw)

    def resolve_cfg(self, cfg: SparsityConfig) -> SparsityConfig:
        if not self.cfg_overrides:
            return cfg
        return dataclasses.replace(cfg, **dict(self.cfg_overrides))


DEFAULT_POLICY = ExecPolicy()
DENSE_POLICY = ExecPolicy(mode="dense")


def resolve_policy(policy: Optional[ExecPolicy] = None,
                   mode: Optional[str] = None,
                   backend: Optional[str] = None) -> ExecPolicy:
    """Normalize the (policy | legacy mode/backend kwargs) calling
    conventions into one :class:`ExecPolicy`."""
    if policy is not None:
        if mode is not None or backend is not None:
            raise ValueError(
                "pass either policy= or the legacy mode=/backend= kwargs, "
                "not both")
        return policy
    if mode is None and backend is None:
        return DEFAULT_POLICY
    return ExecPolicy(mode=mode or DEFAULT_POLICY.mode,
                      backend=backend or DEFAULT_POLICY.backend)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_dense(key, in_features: int, out_features: int, dtype=jnp.float32,
               scale: Optional[float] = None):
    scale = scale if scale is not None else in_features ** -0.5
    w = jax.random.normal(key, (out_features, in_features), dtype) * scale
    return {"w": w}


def init_sparse(key, in_features: int, out_features: int, cfg: SparsityConfig,
                dtype=jnp.float32, scale: Optional[float] = None):
    """Initialize a masked-mode sparse linear (dense weight, pattern applied
    in the forward pass)."""
    p = init_dense(key, in_features, out_features, dtype, scale)
    return {"w": prune(p["w"], cfg)}


# ---------------------------------------------------------------------------
# Node introspection
# ---------------------------------------------------------------------------

def node_sparsity(params) -> Optional[SparsityConfig]:
    """The SparsityConfig of a dense-weight linear node, or None for a plain
    dense linear."""
    if isinstance(params, PackedWeight):
        return params.cfg
    if not isinstance(params, dict):
        return None
    sp = params.get("sparsity")
    if sp is not None:
        return sp.value if isinstance(sp, Static) else sp
    if "_sparse_m" in params:
        raise ValueError(
            "the legacy _sparse_m/_sparse_n metadata keys are no longer "
            "supported; re-init the layer (init_linear stores a single "
            "sparsity=Static(SparsityConfig) entry carrying k) and pack "
            "with launch.pack_tree")
    return None


def _reject_legacy_packed(params):
    if isinstance(params, dict) and "values" in params:
        raise ValueError(
            "legacy packed {values, indices, shape} dicts are no longer "
            "supported; pack with pack_params/launch.pack_tree to get a "
            "PackedWeight")


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def apply(params, x: jax.Array,
          policy: Optional[ExecPolicy] = None) -> jax.Array:
    """Unified linear application: dense, masked, or packed-DeMM, chosen by
    the node's type and the :class:`ExecPolicy`."""
    policy = policy or DEFAULT_POLICY
    if isinstance(params, PackedWeight):
        return _apply_packed(params, x, policy)
    _reject_legacy_packed(params)
    cfg = node_sparsity(params)
    if cfg is None or policy.mode == "dense":
        return apply_dense(params, x)
    return apply_masked(params, x, policy.resolve_cfg(cfg))


def apply_dense(params, x: jax.Array) -> jax.Array:
    w = params["w"]
    return jnp.einsum("...k,ok->...o", x, w.astype(x.dtype))


def apply_masked(params, x: jax.Array, cfg: SparsityConfig) -> jax.Array:
    w = masked_weight(params["w"], cfg)
    return jnp.einsum("...k,ok->...o", x, w.astype(x.dtype))


def _reconfigure(pw: PackedWeight, cfg: SparsityConfig) -> PackedWeight:
    """Re-tag a packed weight with ``cfg``, allowing only layout-preserving
    (same n_effective, same m) reconfigurations — the packed array shape is
    fixed at pack time."""
    if cfg == pw.cfg:
        return pw
    if cfg.n_effective != pw.cfg.n_effective or cfg.m != pw.cfg.m:
        raise ValueError(
            f"config {cfg.pattern_name()} changes the packed layout of a "
            f"{pw.cfg.pattern_name()} weight; only n_effective-preserving "
            "reconfigurations apply to an already-packed weight")
    return pw.replace(cfg=cfg)


def _apply_packed(pw: PackedWeight, x: jax.Array,
                  policy: ExecPolicy) -> jax.Array:
    from repro.kernels import ops

    pw = _reconfigure(pw, policy.resolve_cfg(pw.cfg))
    xs = x.reshape(-1, x.shape[-1])
    y = ops.demm_matmul_packed(xs, pw, backend=policy.backend)
    return y.reshape(*x.shape[:-1], pw.out_features).astype(x.dtype)


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------

def pack_params(params, cfg: Optional[SparsityConfig] = None) -> PackedWeight:
    """Convert a trained masked layer to the packed DeMM serving form."""
    cfg = cfg or node_sparsity(params)
    if cfg is None:
        raise ValueError("pack_params needs a SparsityConfig (node carries "
                         "no sparsity metadata and none was passed)")
    w = prune(params["w"], cfg)
    packed = pack(w, cfg)
    return PackedWeight(packed.values, packed.indices, cfg=cfg,
                        dense_shape=w.shape, layout=LAYOUT_XWT)


def apply_packed(params, x: jax.Array, cfg: Optional[SparsityConfig] = None,
                 backend: str = "reference") -> jax.Array:
    """Packed application of a :class:`PackedWeight`.  New code should call
    :func:`apply` with ``ExecPolicy(backend=...)``."""
    _reject_legacy_packed(params)
    if not isinstance(params, PackedWeight):
        raise TypeError(f"apply_packed expects a PackedWeight, got "
                        f"{type(params)}")
    pw = params
    if cfg is not None:
        pw = _reconfigure(pw, cfg)
    return _apply_packed(pw, x, ExecPolicy(mode="packed", backend=backend))
