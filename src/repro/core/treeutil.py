"""Small shared pytree utilities."""

from __future__ import annotations


def key_path_str(path) -> str:
    """'/'-joined string form of a jax key path.

    Handles DictKey (``.key``), SequenceKey (``.idx``), and GetAttrKey
    (``.name`` — e.g. PackedWeight's values/indices children); anything else
    falls back to ``str``.  The single source of truth for path naming, used
    by both checkpoint leaf files and partitioning rules so the two can
    never silently diverge.
    """
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)
