"""Functional model of the DeMM engine (paper §II).

This is the *architectural* model of the engine: it computes sparse×dense
products in exactly the decoupled, row-wise product-first order the hardware
uses, with the two stages made explicit:

  stage 1 (memory)   — the N read ports: ``col_idx`` addresses the
                       pre-loaded M×C block of B, returning N rows of C
                       elements each;
  stage 2 (compute)  — N×C multipliers scale each read row by its non-zero
                       value; C N-input adder trees reduce to one output row.

The Pallas kernels in ``repro.kernels`` are the TPU-performant versions; this
module is the semantics reference and the engine used by the perf model and
by small-scale (CPU) execution.  All functions are jit-able and
differentiable.

Engine configuration mirrors the paper's DeMM(N, M, C, k):
  N — read ports / multiplier rows (non-zeros processed per cycle)
  M — group width = rows of B pre-loaded per block
  C — columns of B processed in parallel (output lanes)
  k — reconfiguration factor: kN:M patterns run in k passes per row
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparsity import PackedSparse, SparsityConfig


@dataclasses.dataclass(frozen=True)
class DeMMConfig:
    """DeMM(N, M, C, k) — paper §II-B."""

    n: int = 8
    m: int = 128
    c: int = 64
    k: int = 8

    @property
    def multipliers(self) -> int:
        # The paper equalizes designs by MAC count: N*C multipliers.
        return self.n * self.c

    @property
    def sparsity(self) -> SparsityConfig:
        return SparsityConfig(n=self.n, m=self.m, k=1)

    def supports(self, pat: SparsityConfig) -> bool:
        """A DeMM(N,M,·,k) engine serves any pattern n':M with n' <= k*N."""
        return pat.m == self.m and pat.n_effective <= self.n * self.k


# ---------------------------------------------------------------------------
# The two decoupled stages
# ---------------------------------------------------------------------------

def read_ports(b_block: jax.Array, col_idx: jax.Array) -> jax.Array:
    """Stage 1 — the N-read-port memory block.

    b_block : (M, C)  pre-loaded rows of B (the engine's memory contents)
    col_idx : (..., N) int32 addresses
    returns : (..., N, C) — each read port outputs one full row of B.
    """
    return jnp.take(b_block, col_idx, axis=0)


def multiply_reduce(read_rows: jax.Array, values: jax.Array) -> jax.Array:
    """Stage 2 — N×C multipliers + C N-input adder trees.

    read_rows : (..., N, C)
    values    : (..., N)
    returns   : (..., C)
    """
    acc_dtype = jnp.promote_types(values.dtype, jnp.float32)
    prods = read_rows.astype(acc_dtype) * values[..., None].astype(acc_dtype)
    return jnp.sum(prods, axis=-2)


# ---------------------------------------------------------------------------
# Whole-matrix products in row-wise product-first order
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("out_dtype",))
def demm_spmm(packed: PackedSparse, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """C = A_sparse @ B with A packed as {values, indices}.

    A is (R, K) packed to (R, G, Ne); B is (K, Cdim).  The product is formed
    group by group (each group = one pre-loaded M-row memory block of B),
    each group contributing via the two decoupled stages.  Padded slots carry
    value 0 and contribute nothing.
    """
    r, kdim = packed.shape
    g = packed.values.shape[1]
    m = packed.cfg.m
    assert b.shape[0] == kdim, (b.shape, kdim)
    cdim = b.shape[1]

    b_blocks = b.reshape(g, m, cdim)

    def per_group(vals_g, idx_g, b_block):
        # vals_g/idx_g: (R, Ne); b_block: (M, C)
        rows = read_ports(b_block, idx_g)            # (R, Ne, C)
        return multiply_reduce(rows, vals_g)          # (R, C)

    # vmap over groups, then reduce — the engine iterates groups serially in
    # hardware; the sum order is fixed (group-major) either way.
    contribs = jax.vmap(per_group, in_axes=(1, 1, 0))(
        packed.values, packed.indices, b_blocks
    )  # (G, R, C)
    return jnp.sum(contribs, axis=0).astype(out_dtype)


@partial(jax.jit, static_argnames=("out_dtype",))
def demm_spmm_dense_a(a: jax.Array, b: jax.Array, cfg: SparsityConfig,
                      out_dtype=jnp.float32) -> jax.Array:
    """Convenience: prune+pack a dense A on the fly, then demm_spmm."""
    from repro.core.sparsity import pack, prune

    return demm_spmm(pack(prune(a, cfg), cfg), b, out_dtype=out_dtype)


def demm_spmm_k_passes(packed: PackedSparse, b: jax.Array, k: int,
                       out_dtype=jnp.float32) -> jax.Array:
    """The k-reconfigured schedule (paper §II-B): a kN:M packed matrix is
    consumed in k sequential N:M passes that time-share the read ports.

    Numerically identical to ``demm_spmm(packed, b)``; exists to validate the
    reconfiguration semantics and to drive the perf model's cycle counts.
    """
    from repro.core.sparsity import reconfigure_k

    ne = packed.cfg.n_effective
    if ne % k:
        raise ValueError(f"k={k} does not divide n_effective={ne}")
    split = reconfigure_k(packed, k)
    r, kdim = packed.shape
    g = packed.values.shape[1]
    m = packed.cfg.m
    cdim = b.shape[1]
    b_blocks = b.reshape(g, m, cdim)

    vals = split.values.reshape(r, g, k, ne // k)
    idx = split.indices.reshape(r, g, k, ne // k)

    acc = jnp.zeros((r, cdim), jnp.float32)
    for pass_i in range(k):  # k is a static engine parameter (unrolled)
        def per_group(v, i, bb):
            return multiply_reduce(read_ports(bb, i), v)

        contribs = jax.vmap(per_group, in_axes=(1, 1, 0))(
            vals[:, :, pass_i], idx[:, :, pass_i], b_blocks
        )
        acc = acc + jnp.sum(contribs, axis=0)
    return acc.astype(out_dtype)
