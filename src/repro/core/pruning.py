"""Pruning schedules for relaxed N:M structured sparsity.

Two training-time paths, both of which produce weights that satisfy the
pattern and can be packed losslessly for DeMM serving:

* **Straight-through masked training** — the weight is kept dense; the
  forward pass multiplies by the top-N:M magnitude mask, the backward pass
  passes gradients straight through to the dense weight (so pruned weights
  keep receiving gradient and may re-enter the pattern later).  This is the
  standard way N:M models are fine-tuned.

* **RigL-style prune/regrow** — the mask is updated every ``update_every``
  steps: drop the smallest-magnitude kept weights, regrow at the positions
  with the largest dense-gradient magnitude, keeping exactly N per M group
  (Evci et al., the pruning method the paper's 95% ResNet50 workload uses).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig, prune_mask


@dataclasses.dataclass(frozen=True)
class PruneSchedule:
    cfg: SparsityConfig
    update_every: int = 100          # RigL mask-update cadence (steps)
    regrow_fraction: float = 0.3     # fraction of kept slots reconsidered
    stop_update_after: Optional[int] = None  # freeze mask late in training


@jax.custom_vjp
def straight_through_mask(w: jax.Array, mask: jax.Array) -> jax.Array:
    return w * mask.astype(w.dtype)


def _st_fwd(w, mask):
    return w * mask.astype(w.dtype), None


def _st_bwd(_, g):
    # Gradient flows to the dense weight unmasked (straight-through);
    # the mask is not differentiable.
    return g, None


straight_through_mask.defvjp(_st_fwd, _st_bwd)


@partial(jax.jit, static_argnames=("cfg",))
def masked_weight(w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """Forward-time N:M projection with straight-through gradients.

    Recomputes the top-N mask from the current dense weight every call, so
    the pattern tracks weight magnitude during training ("soft" N:M, as used
    by SR-STE-style methods).
    """
    return straight_through_mask(w, prune_mask(w, cfg))


@partial(jax.jit, static_argnames=("sched",), donate_argnums=(1,))
def rigl_update_mask(w: jax.Array, mask: jax.Array, grad: jax.Array,
                     sched: PruneSchedule) -> jax.Array:
    """One RigL mask update: drop smallest kept |w|, regrow largest |grad|.

    Operates per (row, group): scores kept slots by |w|, candidate slots by
    |grad|, and re-selects the top ``n_effective`` of the union with
    ``regrow_fraction`` of the budget reserved for gradient-selected slots.
    The result always satisfies the N:M pattern exactly.
    """
    cfg = sched.cfg
    r, kdim = w.shape
    g = kdim // cfg.m
    ne = cfg.n_effective
    n_regrow = max(1, int(round(sched.regrow_fraction * ne)))
    n_keep = ne - n_regrow

    wg = jnp.abs(w.reshape(r, g, cfg.m))
    gg = jnp.abs(grad.reshape(r, g, cfg.m))
    mg = mask.reshape(r, g, cfg.m).astype(bool)

    # Keep the n_keep largest-|w| currently-active slots...
    w_score = jnp.where(mg, wg, -jnp.inf)
    keep_vals, keep_idx = jax.lax.top_k(w_score, n_keep)
    keep_oh = jnp.zeros_like(mg).at[
        jnp.arange(r)[:, None, None], jnp.arange(g)[None, :, None], keep_idx
    ].set(keep_vals > -jnp.inf)

    # ...and regrow the n_regrow largest-|grad| currently-inactive slots.
    g_score = jnp.where(keep_oh, -jnp.inf, gg)
    grow_vals, grow_idx = jax.lax.top_k(g_score, n_regrow)
    grow_oh = jnp.zeros_like(mg).at[
        jnp.arange(r)[:, None, None], jnp.arange(g)[None, :, None], grow_idx
    ].set(grow_vals > -jnp.inf)

    return (keep_oh | grow_oh).reshape(r, kdim)


def init_mask(w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    return prune_mask(w, cfg)


def maybe_update_mask(step: jax.Array, w: jax.Array, mask: jax.Array,
                      grad: jax.Array, sched: PruneSchedule) -> jax.Array:
    """Conditionally apply the RigL update on schedule (jit-safe)."""
    due = (step % sched.update_every) == 0
    if sched.stop_update_after is not None:
        due = due & (step < sched.stop_update_after)
    return jax.lax.cond(
        due,
        lambda: rigl_update_mask(w, mask, grad, sched),
        lambda: mask,
    )
