"""DeMM core: relaxed N:M structured sparsity + the decoupled engine."""
from repro.core.sparsity import (  # noqa: F401
    PATTERNS,
    PackedSparse,
    PackedWeight,
    SparsityConfig,
    Static,
    pack,
    prune,
    prune_mask,
    satisfies_pattern,
    unpack,
    unpack_packed,
)
from repro.core.sparse_linear import (  # noqa: F401
    DEFAULT_POLICY,
    ExecPolicy,
    resolve_policy,
)
from repro.core.demm import DeMMConfig, demm_spmm, demm_spmm_k_passes  # noqa: F401
