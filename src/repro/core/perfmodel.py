"""Cycle-accurate analytical performance models of the four engines the
paper evaluates: DeMM, S2TA, VEGETA, SPOTS.

This reproduces the paper's evaluation methodology: CNN layers are lowered to
im2col GEMMs ``C[R,P] = A_sparse[R,K] @ B[K,P]`` (A = weights, R = output
channels, K = Ci*kh*kw, P = output spatial positions), a *real* sparsity mask
is drawn per layer, and each engine's schedule is counted in cycles **from
the actual mask** (violations of an engine's native pattern cost extra
passes/cycles, exactly as the paper describes for rows exceeding 8:128).
All engines are resource-equalized at 512 multiply-add units (paper §III-A).

Modeling assumptions (documented per engine below; these are first-order
schedule models, not RTL):

* **DeMM(N, M, C, k)** — input-stationary.  For every (column-tile of C
  outputs) × (M-group of K): pre-load the M×C memory block through the single
  write port (M cycles), then stream the packed rows of A: a row with ``z``
  non-zeros in this group takes ``ceil(z / N)`` cycles (the k-reconfigured
  time-sharing of the N read ports; z <= kN native, arbitrary z still
  processed in consecutive cycles); rows with z = 0 are never streamed.
  A small pipeline drain (mult + log2(N) adder-tree stages) per group-tile.

* **VEGETA-S (32×16, weight-stationary, native ns:ms)** — each PE holds
  ``ns`` non-zeros covering an ``ms``-wide dense K-segment, so one array load
  covers 32*ms of K for 16 output channels.  A group with z > ns non-zeros
  forces ceil(z/ns) sequential passes for the whole tile (the array is
  bulk-synchronous).  Per pass: 32-cycle weight preload + P input columns +
  fill/drain skew of (32+16).

* **S2TA (output-stationary tensor array, DBB ns:ms, 8-MAC dot PEs)** —
  a 4×16 tensor-PE array (the paper's "S2TA-4×16×4_8×4") × 8 lanes =
  512 MACs computing a 4×16 (R×P) output tile with 8-deep dot units; the
  DBB stream covers 8 blocks of ms per cycle when the pattern holds, and a
  block with z > ns non-zeros costs ceil(z/ns) slots.  Successive tiles are
  pipelined; per-tile overhead is the output drain (4 cycles) only.

* **SPOTS (128×4, reconfigured as four 32×4 parallel blocks)** — systolic
  GEMM with zero-*group* skipping at contiguous 1×4 granularity along K,
  decided per row-pair lane (two 2-row lanes per 4-wide tile, synchronous:
  the tile streams the max of its lanes' compressed K).  The paper notes
  this skipping is ineffective for fine-grained N:M where no contiguous
  zero groups exist.  Per tile: 32-cycle preload + compressed input stream +
  (32+4) skew, four unit tiles in flight (LPT-balanced).

Calibration (DESIGN.md §7): with these parameters the
ResNet50 @95%-unstructured (≈8:128) comparison lands at 17.1 / 56.1 / 65.2 %
overall-latency improvement vs S2TA / VEGETA / SPOTS against the paper's
claimed 18 / 54 / 67 % — every engine within ~2 points without per-layer
fitting.  The free parameters are physical (tile shapes, buffer counts,
skew) and were set once, globally, from the engine descriptions.

The models are validated against the paper's headline claims in
``benchmarks/fig6_resnet50.py`` and ``benchmarks/fig8_finegrained.py``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable

import numpy as np

from repro.core.sparsity import SparsityConfig

CLOCK_HZ = 500e6  # paper §III-B: all engines at 500 MHz


# ---------------------------------------------------------------------------
# Workloads: CNN layers as im2col GEMMs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmShape:
    name: str
    r: int       # output channels (rows of sparse A)
    k: int       # Ci * kh * kw (contraction)
    p: int       # output spatial positions (dense columns)
    count: int = 1   # how many identical layers in the network
    sparse: bool = True  # first conv / classifier often kept dense


def resnet50_gemms() -> list[GemmShape]:
    """ResNet50 @ 224×224 — every conv lowered to im2col GEMM."""
    out = [GemmShape("conv1_7x7", 64, 3 * 49, 112 * 112, 1, sparse=False)]
    # (stage, in_ch, mid_ch, out_ch, spatial, blocks)
    stages = [
        ("conv2", 64, 64, 256, 56, 3),
        ("conv3", 256, 128, 512, 28, 4),
        ("conv4", 512, 256, 1024, 14, 6),
        ("conv5", 1024, 512, 2048, 7, 3),
    ]
    for name, cin, mid, cout, hw, blocks in stages:
        p = hw * hw
        # first block: 1x1 reduce from cin, others from cout
        out.append(GemmShape(f"{name}_b0_1x1a", mid, cin, p))
        out.append(GemmShape(f"{name}_1x1a", mid, cout, p, count=blocks - 1))
        out.append(GemmShape(f"{name}_3x3", mid, mid * 9, p, count=blocks))
        out.append(GemmShape(f"{name}_1x1b", cout, mid, p, count=blocks))
        out.append(GemmShape(f"{name}_proj", cout, cin, p))  # downsample proj
    out.append(GemmShape("fc", 1000, 2048, 1, sparse=False))
    return out


def convnext_t_gemms() -> list[GemmShape]:
    """ConvNeXt-T @ 224×224 — stem, downsamples, and per-block
    dwconv7x7 (grouped; modeled per-channel) + pw expand/reduce."""
    dims = [96, 192, 384, 768]
    depths = [3, 3, 9, 3]
    hw = [56, 28, 14, 7]
    out = [GemmShape("stem_4x4", 96, 3 * 16, 56 * 56, 1, sparse=False)]
    for s, (d, n, h) in enumerate(zip(dims, depths, hw)):
        p = h * h
        # depthwise 7x7: per-channel 1×49 dot; modeled as GEMM R=d, K=49
        # with block-diagonal semantics (weights sparse-prunable).
        out.append(GemmShape(f"s{s}_dw7x7", d, 49, p, count=n))
        out.append(GemmShape(f"s{s}_pw_up", 4 * d, d, p, count=n))
        out.append(GemmShape(f"s{s}_pw_down", d, 4 * d, p, count=n))
        if s < 3:
            out.append(GemmShape(f"s{s}_down_2x2", dims[s + 1], d * 4,
                                 hw[s + 1] * hw[s + 1]))
    out.append(GemmShape("head", 1000, 768, 1, sparse=False))
    return out


# ---------------------------------------------------------------------------
# Mask generators
# ---------------------------------------------------------------------------

def unstructured_mask(rng: np.random.Generator, r: int, k: int,
                      sparsity: float) -> np.ndarray:
    """RigL-style unstructured mask at a given sparsity (uniform placement —
    the paper's 95% ResNet50 workload; ERK reweighting is a second-order
    effect for schedule counting)."""
    return rng.random((r, k)) > sparsity


def nm_mask(rng: np.random.Generator, r: int, k: int, n: int, m: int,
            ) -> np.ndarray:
    """Exact fine-grained N:M mask (n non-zeros per m-block, random slots)."""
    g = math.ceil(k / m)
    mask = np.zeros((r, g, m), bool)
    scores = rng.random((r, g, m))
    idx = np.argsort(-scores, axis=-1)[..., :n]
    np.put_along_axis(mask, idx, True, axis=-1)
    return mask.reshape(r, g * m)[:, :k]


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------

def _pad_groups(mask: np.ndarray, m: int) -> np.ndarray:
    """(R, K) -> (R, G, m) with zero padding."""
    r, k = mask.shape
    g = math.ceil(k / m)
    padded = np.zeros((r, g * m), bool)
    padded[:, :k] = mask
    return padded.reshape(r, g, m)


class Engine:
    name: str = "engine"
    macs: int = 512

    def gemm_cycles(self, shape: GemmShape, mask: np.ndarray) -> int:
        raise NotImplementedError

    def network_cycles(self, gemms: Iterable[GemmShape],
                       mask_fn: Callable[[GemmShape], np.ndarray]) -> dict:
        per_layer = {}
        for s in gemms:
            mask = (np.ones((s.r, s.k), bool) if not s.sparse
                    else mask_fn(s))
            per_layer[s.name] = self.gemm_cycles(s, mask) * s.count
        return per_layer


@dataclasses.dataclass
class DeMMEngine(Engine):
    """DeMM(N, M, C, k) — paper §II; input-stationary."""

    n: int = 8
    m: int = 128
    c: int = 64
    k: int = 8
    pipe: int = 6  # read + multiply + ceil(log2(N)) adder stages + writeback

    def __post_init__(self):
        self.name = f"DeMM({self.n},{self.m},{self.c},{self.k})"

    def gemm_cycles(self, shape: GemmShape, mask: np.ndarray) -> int:
        col_tiles = math.ceil(shape.p / self.c)
        groups = _pad_groups(mask, self.m)               # (R, G, M)
        nnz = groups.sum(-1)                              # (R, G)
        # ceil(z/N) cycles per row per group; z=0 rows are not streamed.
        row_cycles = -(-nnz // self.n)                    # ceil div, 0 -> 0
        per_group = self.m + row_cycles.sum(0) + self.pipe  # (G,)
        return int(col_tiles * per_group.sum())


@dataclasses.dataclass
class VegetaEngine(Engine):
    """VEGETA-S (32×16 weight-stationary) with native ns:ms support."""

    ns: int = 1
    ms: int = 16
    rows: int = 32
    cols: int = 16

    def __post_init__(self):
        self.name = f"VEGETA-S({self.ns}:{self.ms})"

    def gemm_cycles(self, shape: GemmShape, mask: np.ndarray) -> int:
        k_cov = self.rows * self.ms                       # K per array load
        groups = _pad_groups(mask, self.ms)               # (R, G, ms)
        nnz = groups.sum(-1)                              # (R, G)
        passes_rg = np.maximum(-(-nnz // self.ns), 1)     # per (row, group)
        g_per_tile = k_cov // self.ms                     # 32 groups per load
        gtot = nnz.shape[1]
        total = 0
        for kt in range(math.ceil(gtot / g_per_tile)):
            gsl = slice(kt * g_per_tile, min((kt + 1) * g_per_tile, gtot))
            for rt in range(math.ceil(shape.r / self.cols)):
                rsl = slice(rt * self.cols, min((rt + 1) * self.cols, shape.r))
                passes = int(passes_rg[rsl, gsl].max())
                total += passes * (self.rows + shape.p + self.rows + self.cols)
        return total


@dataclasses.dataclass
class S2TAEngine(Engine):
    """S2TA output-stationary tensor array with DBB ns:ms, 8-MAC dot PEs."""

    ns: int = 1
    ms: int = 16
    tile_r: int = 4
    tile_p: int = 16
    lanes: int = 8   # blocks processed per cycle when pattern holds
    drain: int = 4

    def __post_init__(self):
        self.name = f"S2TA({self.ns}:{self.ms})"

    def gemm_cycles(self, shape: GemmShape, mask: np.ndarray) -> int:
        groups = _pad_groups(mask, self.ms)
        nnz = groups.sum(-1)                              # (R, G)
        slots_rg = np.maximum(-(-nnz // self.ns), 1)      # DBB slots per block
        gtot = nnz.shape[1]
        total = 0
        p_tiles = math.ceil(shape.p / self.tile_p)
        for rt in range(math.ceil(shape.r / self.tile_r)):
            rsl = slice(rt * self.tile_r, min((rt + 1) * self.tile_r, shape.r))
            # bulk-synchronous across the tile: slots = max over rows
            slots = slots_rg[rsl].max(0)                  # (G,)
            k_cycles = math.ceil(int(slots.sum()) / self.lanes)
            total += p_tiles * (k_cycles + self.drain)
        return total


@dataclasses.dataclass
class SpotsEngine(Engine):
    """SPOTS — 128×4 systolic GEMM as four parallel 32×4 blocks with
    contiguous zero-group skipping (1×4 groups along K, per row-pair lane)."""

    unit_rows: int = 32
    unit_cols: int = 4
    units: int = 4
    group: int = 4
    skip_rows: int = 2   # rows per skipping lane (2 lanes per 4-wide tile)

    def __post_init__(self):
        self.name = "SPOTS"

    def gemm_cycles(self, shape: GemmShape, mask: np.ndarray) -> int:
        groups = _pad_groups(mask, self.group)            # (R, G4, 4)
        any_nz = groups.any(-1)                           # (R, G4)
        tile_cycles = []
        for rt in range(math.ceil(shape.r / self.unit_cols)):
            rsl = slice(rt * self.unit_cols,
                        min((rt + 1) * self.unit_cols, shape.r))
            sub = any_nz[rsl]
            # a K-group is skipped per lane when all lane rows are zero
            # there; the tile's lanes are synchronous -> max over lanes.
            keffs = []
            for lr in range(0, sub.shape[0], self.skip_rows):
                lane = sub[lr:lr + self.skip_rows]
                keffs.append(int(lane.any(0).sum()) * self.group)
            k_eff = max(keffs) if keffs else 0
            k_tiles = max(1, math.ceil(k_eff / self.unit_rows))
            tile_cycles.append(
                k_tiles * (self.unit_rows + shape.p
                           + self.unit_rows + self.unit_cols))
        # four units run tiles in parallel
        tile_cycles = np.asarray(tile_cycles)
        per_unit = np.zeros(self.units)
        for c in np.sort(tile_cycles)[::-1]:              # LPT balance
            per_unit[per_unit.argmin()] += c
        return int(per_unit.max())


# ---------------------------------------------------------------------------
# Tile-ranking estimate for the Pallas kernels (used by repro.tune)
# ---------------------------------------------------------------------------

def demm_tile_cycles(r: int, k: int, p: int, cfg: SparsityConfig,
                     block_cols: int, seed: int = 0) -> int:
    """First-order cycle estimate of the software DeMM schedule for one
    GEMM ``C[r, p] = A_sparse[r, k] @ B[k, p]`` tiled at ``block_cols``
    output columns per step.

    This reuses :class:`DeMMEngine` with its column-tile width C set to the
    Pallas kernel's output-column block (``block_c`` for spmm, ``block_b``
    for the xwT orientation): the engine's pre-load + stream count then
    mirrors the kernel's per-grid-step B-block residency and packed-row
    streaming.  The mask is a representative exact N:M draw at the config's
    density — the estimate ranks tile candidates, it does not predict wall
    time.
    """
    rng = np.random.default_rng(seed)
    mask = nm_mask(rng, r, k, cfg.n_effective, cfg.m)
    eng = DeMMEngine(n=cfg.n_effective, m=cfg.m, c=max(1, block_cols),
                     k=1)
    return eng.gemm_cycles(GemmShape("tile_est", r, k, p), mask)


# ---------------------------------------------------------------------------
# Experiment drivers (used by benchmarks/)
# ---------------------------------------------------------------------------

def PAPER_ENGINES_RELAXED():
    """The four §III-A designs, resource-equalized at 512 MACs.

    S2TA and VEGETA use the paper's "equivalent 1:16 density"; VEGETA-S-·-2's
    two weight buffers per PE make its effective violation-absorbing block
    2:32 (same density, double the per-pass flexibility).
    """
    return [
        DeMMEngine(8, 128, 64, 8),
        S2TAEngine(1, 16),
        VegetaEngine(2, 32),
        SpotsEngine(),
    ]


def FINEGRAINED_ENGINES(n: int, m: int):
    """Fig. 8 setup: VEGETA/S2TA configured natively at the workload's
    fine-grained n:m (their optimal conditions); DeMM(8,128,·,8) serves the
    same density via k-reconfiguration (n:m == (128//m*n):128)."""
    return [
        DeMMEngine(8, 128, 64, 8),
        S2TAEngine(n, m),
        VegetaEngine(n, m),
    ]


def run_network(engines, gemms, mask_fn, seed=0):
    """Returns {engine: {layer: cycles}} with a shared mask draw."""
    rng = np.random.default_rng(seed)
    masks = {}
    for s in gemms:
        masks[s.name] = (np.ones((s.r, s.k), bool) if not s.sparse
                         else mask_fn(rng, s))
    return {
        e.name: e.network_cycles(gemms, lambda s: masks[s.name])
        for e in engines
    }


def improvement(results: dict, ours: str, other: str) -> float:
    """Paper metric: 1 - latency(ours)/latency(other), overall network."""
    t_ours = sum(results[ours].values())
    t_other = sum(results[other].values())
    return 1.0 - t_ours / t_other
