"""EncDecLM (seamless-m4t), HybridLM (zamba2), XLSTMLM (xlstm).

Same interface as DecoderLM (init / train_loss / prefill / decode_step).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.sparse_linear import resolve_policy
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_embedding,
    apply_linear,
    apply_mlp,
    apply_rmsnorm,
    apply_unembedding,
    dtype_of,
    Static,
    init_embedding,
    init_linear,
    init_mlp,
    init_rmsnorm,
)
from repro.models.transformer import (
    FULL_WINDOW,
    _remat,
    apply_tblock_seq,
    init_tblock,
    softmax_xent,
)


# ---------------------------------------------------------------------------
# Encoder-decoder (seamless-m4t): audio-stub encoder + cross-attn decoder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EncDecLM:
    cfg: ArchConfig

    def init(self, key):
        cfg = self.cfg
        dtype = dtype_of(cfg.param_dtype)
        ks = jax.random.split(key, 6)
        enc_layers = jax.vmap(
            lambda k: init_tblock(k, cfg, dtype=dtype))(
            jax.random.split(ks[0], cfg.encoder_layers))
        dec_layers = jax.vmap(
            lambda k: init_tblock(k, cfg, cross=True, dtype=dtype))(
            jax.random.split(ks[1], cfg.num_layers))
        return {
            "frame_proj": init_linear(ks[2], cfg.d_model, cfg.d_model,
                                      sparse=None, dtype=dtype),
            "enc_layers": enc_layers,
            "enc_norm": init_rmsnorm(cfg.d_model, dtype),
            "embed": init_embedding(ks[3], cfg.padded_vocab, cfg.d_model, dtype),
            "unembed": init_embedding(ks[4], cfg.padded_vocab, cfg.d_model, dtype),
            "dec_layers": dec_layers,
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
        }

    def encode(self, params, frames, *, policy=None):
        """frames: (B, S_src, D) stub audio embeddings."""
        cfg = self.cfg
        x = apply_linear(params["frame_proj"],
                         frames.astype(dtype_of(cfg.compute_dtype)))
        t = x.shape[1]

        def body(x, blk):
            x, _ = apply_tblock_seq(blk, x, cfg, window=FULL_WINDOW,
                                    positions=jnp.arange(t), causal=False,
                                    policy=policy)
            return x, None

        x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_layers"])
        return apply_rmsnorm(params["enc_norm"], x)

    def _decode_seq(self, params, tokens, enc_out, *, policy):
        cfg = self.cfg
        x = apply_embedding(params["embed"], tokens).astype(enc_out.dtype)
        t = x.shape[1]

        def body(x, blk):
            x, _ = apply_tblock_seq(blk, x, cfg, window=FULL_WINDOW,
                                    positions=jnp.arange(t), enc_out=enc_out,
                                    policy=policy)
            return x, None

        x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec_layers"])
        return apply_rmsnorm(params["final_norm"], x)

    def train_loss(self, params, batch, *, policy=None,
                         mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        enc_out = self.encode(params, batch["frames"], policy=policy)
        x = self._decode_seq(params, batch["tokens"], enc_out, policy=policy)
        logits = apply_unembedding(params["unembed"], x, self.cfg.vocab_size)
        loss = softmax_xent(logits, batch["targets"])
        return loss, {"xent": loss}

    def prefill(self, params, batch, *, max_len=None, policy=None,
                      mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        enc_out = self.encode(params, batch["frames"], policy=policy)
        x = self._decode_seq(params, batch["tokens"], enc_out, policy=policy)
        logits = apply_unembedding(params["unembed"], x[:, -1:], self.cfg.vocab_size)
        b = x.shape[0]
        state = self.init_decode_state(b, max_len or x.shape[1] + 1,
                                       enc_len=enc_out.shape[1])
        state["enc_out"] = enc_out
        return logits, state

    def init_decode_state(self, batch, max_len, enc_len=None,
                          dtype=jnp.bfloat16, paged=None):
        cfg = self.cfg
        hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        l = cfg.num_layers
        enc_len = enc_len or max_len // cfg.encoder_seq_divisor
        if paged is not None:
            # self-attention caches move to the shared paged arena; the
            # encoder output stays a dense per-slot tensor (it is read-only
            # cross-attn context of fixed length, not a growing cache)
            caches = {
                "kind": Static("paged"),
                "layout": Static(paged),
                "k": jnp.zeros((l, paged.num_pages, paged.page_size, hkv, dh),
                               dtype),
                "v": jnp.zeros((l, paged.num_pages, paged.page_size, hkv, dh),
                               dtype),
                "block_table": jnp.zeros((batch, paged.max_blocks), jnp.int32),
                "active": jnp.zeros((batch,), jnp.bool_),
            }
        else:
            caches = {
                "kind": Static("full"),
                "k": jnp.zeros((l, batch, max_len, hkv, dh), dtype),
                "v": jnp.zeros((l, batch, max_len, hkv, dh), dtype),
            }
        return {
            "caches": caches,
            "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), dtype),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def _cross_ffn(self, blk, x, enc_out, *, policy):
        cfg = self.cfg
        h = apply_rmsnorm(blk["ln_x"], x)
        h = attn.apply_attention(
            blk["xattn"], h,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            causal=False, window=-1, kv_x=enc_out, policy=policy)
        x = x + h
        h = apply_rmsnorm(blk["ln2"], x)
        h = apply_mlp(blk["mlp"], h, policy=policy)
        return x + h

    def decode_step(self, params, state, tokens, *, policy=None,
                          mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        cfg = self.cfg
        dtype = dtype_of(cfg.compute_dtype)
        x = apply_embedding(params["embed"], tokens).astype(dtype)
        pos = state["pos"]
        enc_out = state["enc_out"]
        caches = state["caches"]

        if caches["kind"].value == "paged":
            bt, active = caches["block_table"], caches["active"]

            def body(x, layer):
                blk, ak, av = layer
                h = apply_rmsnorm(blk["ln1"], x)
                h, arenas = attn.apply_attention_decode_paged(
                    blk["attn"], h, ak, av, bt, active, pos,
                    num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim,
                    rope_theta=cfg.rope_theta, window=FULL_WINDOW,
                    policy=policy)
                return self._cross_ffn(blk, x + h, enc_out,
                                       policy=policy), arenas

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["dec_layers"], caches["k"], caches["v"]))
            x = apply_rmsnorm(params["final_norm"], x)
            logits = apply_unembedding(params["unembed"], x,
                                       self.cfg.vocab_size)
            return logits, {"caches": {**caches, "k": ks, "v": vs},
                            "enc_out": enc_out,
                            "pos": pos + active.astype(jnp.int32)}

        def body(x, layer):
            blk, kc, vc = layer
            h = apply_rmsnorm(blk["ln1"], x)
            h, nc = attn.apply_attention_decode(
                blk["attn"], h, {"k": kc, "v": vc}, pos,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                window=FULL_WINDOW, policy=policy)
            return self._cross_ffn(blk, x + h, enc_out, policy=policy), \
                (nc["k"], nc["v"])

        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["dec_layers"], caches["k"],
                                    caches["v"]))
        x = apply_rmsnorm(params["final_norm"], x)
        logits = apply_unembedding(params["unembed"], x, self.cfg.vocab_size)
        return logits, {"caches": {"kind": Static("full"), "k": ks, "v": vs},
                        "enc_out": enc_out, "pos": pos + 1}

    def prefill_chunk(self, params, state, tokens, slot, n_valid, *,
                      policy=None, mode=None, backend=None):
        """Chunked paged prefill of one decoder sequence (see
        ``DecoderLM.prefill_chunk``); cross-attention reads the slot's dense
        ``enc_out`` row."""
        policy = resolve_policy(policy, mode, backend)
        cfg = self.cfg
        caches = state["caches"]
        if caches["kind"].value != "paged":
            raise NotImplementedError(
                "prefill_chunk requires a paged decode state")
        dtype = dtype_of(cfg.compute_dtype)
        slot = jnp.asarray(slot, jnp.int32)
        n_valid = jnp.asarray(n_valid, jnp.int32)
        pos0 = state["pos"][slot]
        row = caches["block_table"][slot]
        enc_slot = state["enc_out"][slot][None]
        x = apply_embedding(params["embed"], tokens[None]).astype(dtype)

        def body(x, layer):
            blk, ak, av = layer
            h = apply_rmsnorm(blk["ln1"], x)
            h, arenas = attn.apply_attention_prefill_paged(
                blk["attn"], h, ak, av, row, pos0, n_valid,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                policy=policy)
            return self._cross_ffn(blk, x + h, enc_slot,
                                   policy=policy), arenas

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_layers"], caches["k"], caches["v"]))
        x = apply_rmsnorm(params["final_norm"], x)
        last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
        logits = apply_unembedding(params["unembed"], last, cfg.vocab_size)
        return logits, {"caches": {**caches, "k": ks, "v": vs},
                        "enc_out": state["enc_out"],
                        "pos": state["pos"].at[slot].add(n_valid)}


# ---------------------------------------------------------------------------
# Hybrid (zamba2): Mamba2 backbone + one SHARED attention+MLP block
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HybridLM:
    """Mamba2 backbone with ONE shared attention+MLP block applied after
    every ``shared_attn_every``-th mamba layer.  The layer stack is scanned
    as cond-free superblocks: n_periods blocks of (every) mamba layers +
    one shared-attn application, plus a tail of leftover mamba layers —
    this keeps HLO while-loops trip-count-exact for the roofline analysis."""

    cfg: ArchConfig

    def _ssm_kwargs(self):
        s = self.cfg.ssm
        return dict(expand=s.expand, state=s.state_dim, head_dim=s.head_dim)

    def _layout(self):
        period = self.cfg.shared_attn_every
        n_p = self.cfg.num_layers // period
        return period, n_p, self.cfg.num_layers - n_p * period

    def init(self, key):
        cfg = self.cfg
        dtype = dtype_of(cfg.param_dtype)
        s = cfg.ssm
        ks = jax.random.split(key, 5)
        layers = jax.vmap(lambda k: {
            "ln": init_rmsnorm(cfg.d_model, dtype),
            "mamba": ssm_mod.init_mamba2(
                k, cfg.d_model, expand=s.expand, state=s.state_dim,
                head_dim=s.head_dim, conv=s.conv_dim,
                sparse=cfg.sparsity if "mlp" in cfg.sparse_scope else None,
                dtype=dtype),
        })(jax.random.split(ks[0], cfg.num_layers))
        return {
            "embed": init_embedding(ks[1], cfg.padded_vocab, cfg.d_model, dtype),
            "unembed": init_embedding(ks[2], cfg.padded_vocab, cfg.d_model, dtype),
            "layers": layers,
            "shared": init_tblock(ks[3], cfg, dtype=dtype),  # ONE param set
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
        }

    def _split_layers(self, params):
        period, n_p, n_tail = self._layout()
        stacked = jax.tree.map(
            lambda a: a[:n_p * period].reshape(n_p, period, *a.shape[1:]),
            params["layers"])
        tail = jax.tree.map(lambda a: a[n_p * period:], params["layers"])
        return stacked, tail

    def _mamba_layer(self, blk, x, *, policy):
        cfg = self.cfg
        h = apply_rmsnorm(blk["ln"], x)
        h = ssm_mod.apply_mamba2_seq(
            blk["mamba"], h, chunk=cfg.ssm.chunk, policy=policy, **self._ssm_kwargs())
        return x + h

    def _seq(self, params, tokens, *, policy):
        cfg = self.cfg
        dtype = dtype_of(cfg.compute_dtype)
        x = apply_embedding(params["embed"], tokens).astype(dtype)
        t = x.shape[1]
        period, n_p, n_tail = self._layout()
        stacked, tail = self._split_layers(params)
        shared = params["shared"]

        def body(x, blks):
            for i in range(period):
                blk = jax.tree.map(lambda a: a[i], blks)
                x = self._mamba_layer(blk, x, policy=policy)
            x, _ = apply_tblock_seq(shared, x, cfg, window=FULL_WINDOW,
                                    positions=jnp.arange(t), policy=policy)
            return x, None

        x, _ = jax.lax.scan(_remat(body, cfg), x, stacked)
        for i in range(n_tail):
            blk = jax.tree.map(lambda a: a[i], tail)
            x = self._mamba_layer(blk, x, policy=policy)
        return apply_rmsnorm(params["final_norm"], x)

    def train_loss(self, params, batch, *, policy=None,
                         mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        x = self._seq(params, batch["tokens"], policy=policy)
        logits = apply_unembedding(params["unembed"], x, self.cfg.vocab_size)
        loss = softmax_xent(logits, batch["targets"])
        return loss, {"xent": loss}

    def prefill(self, params, batch, *, max_len=None, policy=None,
                      mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        x = self._seq(params, batch["tokens"], policy=policy)
        logits = apply_unembedding(params["unembed"], x[:, -1:], self.cfg.vocab_size)
        return logits, self.init_decode_state(
            x.shape[0], max_len or x.shape[1] + 1)

    def init_decode_state(self, batch, max_len, dtype=jnp.bfloat16,
                          paged=None):
        if paged is not None:
            raise NotImplementedError(
                "paged KV cache is attention-only; HybridLM's Mamba2 "
                "backbone carries O(1) recurrent state per slot (nothing to "
                "page) and its single shared-attn cache is future work")
        cfg = self.cfg
        s = cfg.ssm
        di = s.expand * cfg.d_model
        heads = di // s.head_dim
        period, n_p, n_tail = self._layout()
        hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim

        def ssm_state(*lead):
            return {
                "h": jnp.zeros((*lead, batch, heads, s.head_dim, s.state_dim),
                               jnp.float32),
                "conv": jnp.zeros((*lead, batch, s.conv_dim - 1,
                                   di + 2 * s.state_dim), dtype),
            }

        return {
            "ssm": ssm_state(n_p, period),
            "ssm_tail": ssm_state(max(n_tail, 1)),
            "attn": {
                "k": jnp.zeros((n_p, batch, max_len, hkv, dh), dtype),
                "v": jnp.zeros((n_p, batch, max_len, hkv, dh), dtype),
            },
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def _mamba_step(self, blk, x, st, *, policy):
        h = apply_rmsnorm(blk["ln"], x)
        h, st2 = ssm_mod.apply_mamba2_step(
            blk["mamba"], h, st, policy=policy,
            **self._ssm_kwargs())
        return x + h, st2

    def decode_step(self, params, state, tokens, *, policy=None,
                          mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        cfg = self.cfg
        dtype = dtype_of(cfg.compute_dtype)
        x = apply_embedding(params["embed"], tokens).astype(dtype)
        pos = state["pos"]
        period, n_p, n_tail = self._layout()
        stacked, tail = self._split_layers(params)
        shared = params["shared"]

        def body(x, per):
            blks, sst, kc, vc = per
            new_s = []
            for i in range(period):
                blk = jax.tree.map(lambda a: a[i], blks)
                sti = jax.tree.map(lambda a: a[i], sst)
                x, st2 = self._mamba_step(blk, x, sti, policy=policy)
                new_s.append(st2)
            h = apply_rmsnorm(shared["ln1"], x)
            h, nc = attn.apply_attention_decode(
                shared["attn"], h, {"k": kc, "v": vc}, pos,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                window=FULL_WINDOW, policy=policy)
            x = x + h
            h = apply_rmsnorm(shared["ln2"], x)
            h = apply_mlp(shared["mlp"], h, policy=policy)
            x = x + h
            stacked_s = jax.tree.map(lambda *a: jnp.stack(a), *new_s)
            return x, (stacked_s, nc["k"], nc["v"])

        x, (sst, ks, vs) = jax.lax.scan(
            body, x, (stacked, state["ssm"], state["attn"]["k"],
                      state["attn"]["v"]))

        new_tail = []
        for i in range(n_tail):
            blk = jax.tree.map(lambda a: a[i], tail)
            sti = jax.tree.map(lambda a: a[i], state["ssm_tail"])
            x, st2 = self._mamba_step(blk, x, sti, policy=policy)
            new_tail.append(st2)
        tail_s = (jax.tree.map(lambda *a: jnp.stack(a), *new_tail)
                  if new_tail else state["ssm_tail"])

        x = apply_rmsnorm(params["final_norm"], x)
        logits = apply_unembedding(params["unembed"], x, self.cfg.vocab_size)
        return logits, {"ssm": sst, "ssm_tail": tail_s,
                        "attn": {"k": ks, "v": vs}, "pos": pos + 1}


# ---------------------------------------------------------------------------
# xLSTM: periodic superblocks of (slstm_every - 1) mLSTM + 1 sLSTM
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class XLSTMLM:
    cfg: ArchConfig

    @property
    def _period(self):
        return self.cfg.ssm.slstm_every

    @property
    def _n_periods(self):
        assert self.cfg.num_layers % self._period == 0, \
            "xlstm layer count must be a multiple of slstm_every"
        return self.cfg.num_layers // self._period

    def init(self, key):
        cfg = self.cfg
        dtype = dtype_of(cfg.param_dtype)
        sp = cfg.sparsity if "mlp" in cfg.sparse_scope else None
        ks = jax.random.split(key, 4)
        n_m = self._period - 1

        def init_period(k):
            kk = jax.random.split(k, n_m + 1)
            return {
                "mlstm": jax.vmap(lambda kk_: {
                    "ln": init_rmsnorm(cfg.d_model, dtype),
                    "blk": ssm_mod.init_mlstm(kk_, cfg.d_model,
                                              heads=cfg.num_heads,
                                              sparse=sp, dtype=dtype),
                })(kk[:n_m]),
                "slstm": {
                    "ln": init_rmsnorm(cfg.d_model, dtype),
                    "blk": ssm_mod.init_slstm(kk[n_m], cfg.d_model,
                                              heads=cfg.num_heads,
                                              sparse=sp, dtype=dtype),
                },
            }

        periods = jax.vmap(init_period)(
            jax.random.split(ks[0], self._n_periods))
        return {
            "embed": init_embedding(ks[1], cfg.padded_vocab, cfg.d_model, dtype),
            "unembed": init_embedding(ks[2], cfg.padded_vocab, cfg.d_model, dtype),
            "periods": periods,
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
        }

    def _seq(self, params, tokens, *, policy):
        cfg = self.cfg
        dtype = dtype_of(cfg.compute_dtype)
        x = apply_embedding(params["embed"], tokens).astype(dtype)
        n_m = self._period - 1

        def body(x, period):
            for i in range(n_m):
                sub = jax.tree.map(lambda a: a[i], period["mlstm"])
                h = apply_rmsnorm(sub["ln"], x)
                x = x + ssm_mod.apply_mlstm_seq(
                    sub["blk"], h, heads=cfg.num_heads, chunk=cfg.ssm.chunk,
                    policy=policy)
            h = apply_rmsnorm(period["slstm"]["ln"], x)
            x = x + ssm_mod.apply_slstm_seq(
                period["slstm"]["blk"], h, heads=cfg.num_heads, policy=policy)
            return x, None

        x, _ = jax.lax.scan(_remat(body, cfg), x, params["periods"])
        return apply_rmsnorm(params["final_norm"], x)

    def train_loss(self, params, batch, *, policy=None,
                         mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        x = self._seq(params, batch["tokens"], policy=policy)
        logits = apply_unembedding(params["unembed"], x, self.cfg.vocab_size)
        loss = softmax_xent(logits, batch["targets"])
        return loss, {"xent": loss}

    def prefill(self, params, batch, *, max_len=None, policy=None,
                      mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        x = self._seq(params, batch["tokens"], policy=policy)
        logits = apply_unembedding(params["unembed"], x[:, -1:], self.cfg.vocab_size)
        return logits, self.init_decode_state(x.shape[0], max_len or 1)

    def init_decode_state(self, batch, max_len, dtype=jnp.bfloat16,
                          paged=None):
        if paged is not None:
            raise NotImplementedError(
                "paged KV cache is attention-only; xLSTM decode state is "
                "O(1) recurrent per slot (nothing to page)")
        cfg = self.cfg
        d = cfg.d_model
        np_ = self._n_periods
        n_m = self._period - 1
        pf = 2
        di = pf * d
        dh = di // cfg.num_heads
        dhs = d // cfg.num_heads
        conv = cfg.ssm.conv_dim if hasattr(cfg.ssm, "conv_dim") else 4
        return {
            "mlstm": {
                "C": jnp.zeros((np_, n_m, batch, cfg.num_heads, dh, dh), jnp.float32),
                "n": jnp.zeros((np_, n_m, batch, cfg.num_heads, dh), jnp.float32),
                "m": jnp.full((np_, n_m, batch, cfg.num_heads), -1e30, jnp.float32),
                "conv": jnp.zeros((np_, n_m, batch, conv - 1, di), dtype),
            },
            "slstm": {
                "c": jnp.zeros((np_, batch, cfg.num_heads, dhs), jnp.float32),
                "n": jnp.zeros((np_, batch, cfg.num_heads, dhs), jnp.float32),
                "h": jnp.zeros((np_, batch, cfg.num_heads, dhs), jnp.float32),
                "m": jnp.full((np_, batch, cfg.num_heads, dhs), -1e30, jnp.float32),
            },
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def decode_step(self, params, state, tokens, *, policy=None,
                          mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        cfg = self.cfg
        dtype = dtype_of(cfg.compute_dtype)
        x = apply_embedding(params["embed"], tokens).astype(dtype)
        n_m = self._period - 1

        def body(x, layer):
            period, mst, sst = layer
            new_m = []
            for i in range(n_m):
                sub = jax.tree.map(lambda a: a[i], period["mlstm"])
                sti = jax.tree.map(lambda a: a[i], mst)
                h = apply_rmsnorm(sub["ln"], x)
                out, st2 = ssm_mod.apply_mlstm_step(
                    sub["blk"], h, sti, heads=cfg.num_heads, policy=policy)
                x = x + out
                new_m.append(st2)
            h = apply_rmsnorm(period["slstm"]["ln"], x)
            out, sst2 = ssm_mod.apply_slstm_step(
                period["slstm"]["blk"], h, sst, heads=cfg.num_heads,
                policy=policy)
            x = x + out
            stacked_m = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
            return x, (stacked_m, sst2)

        x, (mst, sst) = jax.lax.scan(
            body, x, (params["periods"], state["mlstm"], state["slstm"]))
        x = apply_rmsnorm(params["final_norm"], x)
        logits = apply_unembedding(params["unembed"], x, self.cfg.vocab_size)
        return logits, {"mlstm": mst, "slstm": sst, "pos": state["pos"] + 1}


def build_model(cfg: ArchConfig):
    from repro.models.transformer import DecoderLM

    if cfg.family == "audio":
        return EncDecLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "ssm":
        return XLSTMLM(cfg)
    return DecoderLM(cfg)
