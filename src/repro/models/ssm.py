"""State-space mixers: Mamba2 (SSD chunked form) and xLSTM (mLSTM + sLSTM).

All mixers expose three entry points with a common carry convention:
  init_*           — parameters
  apply_*_seq      — full-sequence (train / prefill): chunked, MXU-friendly
  apply_*_step     — single-token decode with an O(1) recurrent state

Mamba2 follows the SSD formulation: within a chunk the recurrence is
evaluated as a decay-masked attention-like matmul (C·Bᵀ ⊙ L) and states are
carried across chunks — this is the TPU-friendly parallel form.  The mLSTM
chunked form is analogous (gated linear attention with a log-space
stabilizer); sLSTM is inherently sequential (paper's own statement) and uses
a time scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_linear, init_linear, init_rmsnorm, apply_rmsnorm


# ---------------------------------------------------------------------------
# Mamba2 (zamba2's backbone mixer)
# ---------------------------------------------------------------------------

def init_mamba2(key, d: int, *, expand=2, state=64, head_dim=64, conv=4,
                sparse=None, dtype=jnp.float32):
    di = expand * d
    heads = di // head_dim
    ks = jax.random.split(key, 4)
    return {
        # order: [z (di), x (di), B (state), C (state), dt (heads)]
        "in_proj": init_linear(ks[0], d, 2 * di + 2 * state + heads,
                               sparse=sparse, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (conv, di + 2 * state), dtype) * 0.1,
        "A_log": jnp.zeros((heads,), jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": init_linear(ks[2], di, d, sparse=sparse, dtype=dtype),
    }


def _mamba2_split(params, u, *, di, state, heads, policy):
    zxbcdt = apply_linear(params["in_proj"], u, policy=policy)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    return z, xbc, dt


def _causal_conv(x, w):
    """Depthwise causal conv along T.  x: (B, T, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)


def apply_mamba2_seq(params, u, *, expand=2, state=64, head_dim=64,
                     chunk=128, policy=None):
    """Full-sequence Mamba2 (SSD chunked).  u: (B, T, D) -> (B, T, D)."""
    b, t, d = u.shape
    di = expand * d
    heads = di // head_dim
    z, xbc, dt = _mamba2_split(params, u, di=di, state=state, heads=heads,
                               policy=policy)
    xbc = _causal_conv(xbc, params["conv_w"])
    x, bmat, cmat = jnp.split(xbc, [di, di + state], axis=-1)
    x = x.reshape(b, t, heads, head_dim)
    a = -jnp.exp(params["A_log"])                      # (H,) negative
    log_a = (dt * a).astype(jnp.float32)               # (B, T, H) log decay

    # pad to chunk multiple
    nc = -(-t // chunk)
    tp = nc * chunk
    pad = ((0, 0), (0, tp - t))
    xp = jnp.pad(x, pad + ((0, 0), (0, 0))).reshape(b, nc, chunk, heads, head_dim)
    bp = jnp.pad(bmat, pad + ((0, 0),)).reshape(b, nc, chunk, state)
    cp = jnp.pad(cmat, pad + ((0, 0),)).reshape(b, nc, chunk, state)
    dtp = jnp.pad(dt, pad + ((0, 0),)).reshape(b, nc, chunk, heads)
    lap = jnp.pad(log_a, pad + ((0, 0),)).reshape(b, nc, chunk, heads)

    def chunk_step(h_in, inp):
        xc, bc, cc, dtc, lac = inp                     # per-chunk slices
        # cumulative decays within the chunk
        cum = jnp.cumsum(lac, axis=1)                  # (B, c, H)
        total = cum[:, -1]                             # (B, H)
        # intra-chunk: attention-like with decay mask
        # L[t,s] = exp(cum[t]-cum[s]) for s<=t else 0
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]     # (B,c,c,H)
        tri = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(ldiff), 0.0)
        scores = jnp.einsum("btn,bsn->bts", cc, bc)[..., None] * decay
        y_intra = jnp.einsum("btsh,bsh,bshp->bthp", scores,
                             dtc, xc.astype(jnp.float32))
        # contribution of the carried state
        y_state = jnp.einsum("btn,bhpn,bth->bthp", cc, h_in,
                             jnp.exp(cum))
        # new carried state
        w_s = jnp.exp(total[:, None] - cum)            # (B,c,H)
        h_new = h_in * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bsh,bshp,bsn->bhpn", dtc * w_s, xc.astype(jnp.float32), bc)
        return h_new, y_intra + y_state

    h0 = jnp.zeros((b, heads, head_dim, state), jnp.float32)
    inputs = (xp.swapaxes(0, 1), bp.swapaxes(0, 1), cp.swapaxes(0, 1),
              dtp.swapaxes(0, 1), lap.swapaxes(0, 1))
    _, ys = jax.lax.scan(chunk_step, h0, inputs)       # (nc, B, c, H, P)
    y = ys.swapaxes(0, 1).reshape(b, tp, heads, head_dim)[:, :t]
    y = y + x.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(b, t, di).astype(u.dtype)
    y = apply_rmsnorm(params["norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)).astype(u.dtype)
    return apply_linear(params["out_proj"], y, policy=policy)


def init_mamba2_state(batch, d, *, expand=2, state=64, head_dim=64, conv=4,
                      dtype=jnp.float32):
    di = expand * d
    heads = di // head_dim
    return {
        "h": jnp.zeros((batch, heads, head_dim, state), jnp.float32),
        "conv": jnp.zeros((batch, conv - 1, di + 2 * state), dtype),
    }


def apply_mamba2_step(params, u, ssm_state, *, expand=2, state=64,
                      head_dim=64, policy=None):
    """Single-token decode.  u: (B, 1, D); O(1) state update."""
    b, _, d = u.shape
    di = expand * d
    heads = di // head_dim
    z, xbc, dt = _mamba2_split(params, u, di=di, state=state, heads=heads,
                               policy=policy)
    # causal conv over the carried window
    hist = jnp.concatenate([ssm_state["conv"], xbc], axis=1)  # (B, K, C)
    w = params["conv_w"]
    conv_out = jax.nn.silu((hist * w[None]).sum(1,).astype(jnp.float32)
                           ).astype(u.dtype)[:, None, :]
    new_conv = hist[:, 1:]
    x, bmat, cmat = jnp.split(conv_out, [di, di + state], axis=-1)
    x = x.reshape(b, heads, head_dim)
    dt1 = dt[:, 0]                                      # (B, H)
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt1 * a)                            # (B, H)
    h = ssm_state["h"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt1, x.astype(jnp.float32), bmat[:, 0])
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], h)
    y = y + x.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(u.dtype)
    y = apply_rmsnorm(params["norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)).astype(u.dtype)
    out = apply_linear(params["out_proj"], y, policy=policy)
    return out, {"h": h, "conv": new_conv}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM's matrix-memory block)
# ---------------------------------------------------------------------------

def init_mlstm(key, d: int, *, heads=4, pf=2, conv=4, sparse=None,
               dtype=jnp.float32):
    di = pf * d
    dh = di // heads
    ks = jax.random.split(key, 7)
    return {
        "up": init_linear(ks[0], d, 2 * di, sparse=sparse, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (conv, di), dtype) * 0.1,
        "wq": init_linear(ks[2], di, di, sparse=sparse, dtype=dtype),
        "wk": init_linear(ks[3], di, di, sparse=sparse, dtype=dtype),
        "wv": init_linear(ks[4], di, di, sparse=sparse, dtype=dtype),
        "w_if": init_linear(ks[5], di, 2 * heads, sparse=None, dtype=dtype),
        "norm": init_rmsnorm(di, dtype),
        "down": init_linear(ks[6], di, d, sparse=sparse, dtype=dtype),
    }


def _mlstm_qkvif(params, xm, *, heads, policy):
    b, t, di = xm.shape
    dh = di // heads
    conv_x = _causal_conv(xm, params["conv_w"])
    q = apply_linear(params["wq"], conv_x, policy=policy)
    k = apply_linear(params["wk"], conv_x, policy=policy)
    v = apply_linear(params["wv"], xm, policy=policy)
    gif = apply_linear(params["w_if"], xm, policy=policy)
    i_pre, f_pre = jnp.split(gif.astype(jnp.float32), 2, axis=-1)  # (B,T,H)
    q = q.reshape(b, t, heads, dh)
    k = k.reshape(b, t, heads, dh) * dh ** -0.5
    v = v.reshape(b, t, heads, dh)
    log_f = -jax.nn.softplus(-f_pre)       # log sigmoid(f)
    return q, k, v, i_pre, log_f


def apply_mlstm_seq(params, x, *, heads=4, pf=2, chunk=128, policy=None):
    """Full-sequence mLSTM via the stabilized *chunked* parallel form:
    within a chunk, a decay-masked attention-like matmul; across chunks, the
    (C, n, m) matrix-memory carry — O(T·chunk) memory, MXU-friendly."""
    b, t, d = x.shape
    up = apply_linear(params["up"], x, policy=policy)
    xm, z = jnp.split(up, 2, axis=-1)
    di = xm.shape[-1]
    dh = di // heads
    q, k, v, i_pre, log_f = _mlstm_qkvif(params, xm, heads=heads, policy=policy)
    c = min(chunk, t)
    nc = -(-t // c)
    tp = nc * c
    padt = ((0, 0), (0, tp - t))
    qp = jnp.pad(q, padt + ((0, 0), (0, 0))).reshape(b, nc, c, heads, dh)
    kp = jnp.pad(k, padt + ((0, 0), (0, 0))).reshape(b, nc, c, heads, dh)
    vp = jnp.pad(v, padt + ((0, 0), (0, 0))).reshape(b, nc, c, heads, dh)
    # padded steps must not contribute: i -> -inf, log_f -> 0
    ip = jnp.pad(i_pre, padt + ((0, 0),), constant_values=-1e30
                 ).reshape(b, nc, c, heads)
    fp = jnp.pad(log_f, padt + ((0, 0),)).reshape(b, nc, c, heads)
    tri = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(carry, inp):
        C_p, n_p, m_p = carry                          # (B,H,dh,dh) (B,H,dh) (B,H)
        qc, kc, vc, ic, fc = inp
        qc = qc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        cum = jnp.cumsum(fc, axis=1)                   # (B,c,H)
        total = cum[:, -1]                             # (B,H)
        # intra-chunk stabilized decay D[t,s] = cum[t]-cum[s]+i_s  (s<=t)
        dmat = cum[:, :, None, :] - cum[:, None, :, :] + ic[:, None, :, :]
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)                # (B,c,H)
        m_state = cum + m_p[:, None, :]                # carried stabilizer
        m_t = jnp.maximum(m_intra, m_state)            # (B,c,H)
        dstab = jnp.exp(dmat - m_t[:, :, None, :])
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * dstab
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, vc)
        # n accumulates k weighted by the same decays
        n_intra = jnp.einsum("btsh,bshd->bthd", dstab, kc)
        w_state = jnp.exp(m_state - m_t)               # (B,c,H)
        y_state = jnp.einsum("bthd,bhde->bthe", qc, C_p) * w_state[..., None]
        n_state = n_p[:, None] * w_state[..., None]    # (B,c,H,dh)
        n_t = n_intra + n_state
        den = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", qc, n_t)),
                          jnp.exp(-m_t))
        y = (y_intra + y_state) / den[..., None]
        # chunk-end carry
        m_new = jnp.maximum(m_p + total,
                            jnp.max(total[:, None] - cum + ic, axis=1))
        w_kv = jnp.exp(total[:, None] - cum + ic - m_new[:, None])  # (B,c,H)
        C_new = C_p * jnp.exp(m_p + total - m_new)[..., None, None] + \
            jnp.einsum("bsh,bshd,bshe->bhde", w_kv, kc, vc)
        n_new = n_p * jnp.exp(m_p + total - m_new)[..., None] + \
            jnp.einsum("bsh,bshd->bhd", w_kv, kc)
        return (C_new, n_new, m_new), y

    carry0 = (jnp.zeros((b, heads, dh, dh), jnp.float32),
              jnp.zeros((b, heads, dh), jnp.float32),
              jnp.full((b, heads), -1e30, jnp.float32))
    inputs = tuple(a.swapaxes(0, 1) for a in (qp, kp, vp, ip, fp))
    _, ys = jax.lax.scan(chunk_step, carry0, inputs)   # (nc,B,c,H,dh)
    y = ys.swapaxes(0, 1).reshape(b, tp, heads, dh)[:, :t]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = apply_rmsnorm(params["norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)).astype(x.dtype)
    return apply_linear(params["down"], y, policy=policy)


def init_mlstm_state(batch, d, *, heads=4, pf=2, conv=4, dtype=jnp.float32):
    di = pf * d
    dh = di // heads
    return {
        "C": jnp.zeros((batch, heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, heads, dh), jnp.float32),
        "m": jnp.full((batch, heads), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, conv - 1, di), dtype),
    }


def apply_mlstm_step(params, x, st, *, heads=4, pf=2, policy=None):
    b, _, d = x.shape
    up = apply_linear(params["up"], x, policy=policy)
    xm, z = jnp.split(up, 2, axis=-1)
    di = xm.shape[-1]
    dh = di // heads
    hist = jnp.concatenate([st["conv"], xm], axis=1)
    conv_x = jax.nn.silu((hist * params["conv_w"][None]).sum(1)
                         .astype(jnp.float32)).astype(x.dtype)[:, None]
    q = apply_linear(params["wq"], conv_x, policy=policy)
    k = apply_linear(params["wk"], conv_x, policy=policy)
    v = apply_linear(params["wv"], xm, policy=policy)
    gif = apply_linear(params["w_if"], xm, policy=policy)
    i_pre, f_pre = jnp.split(gif[:, 0].astype(jnp.float32), 2, axis=-1)
    log_f = -jax.nn.softplus(-f_pre)                    # (B,H)
    q = q.reshape(b, heads, dh).astype(jnp.float32)
    k = k.reshape(b, heads, dh).astype(jnp.float32) * dh ** -0.5
    v = v.reshape(b, heads, dh).astype(jnp.float32)
    m_new = jnp.maximum(log_f + st["m"], i_pre)
    f_eff = jnp.exp(log_f + st["m"] - m_new)
    i_eff = jnp.exp(i_pre - m_new)
    C = st["C"] * f_eff[..., None, None] + i_eff[..., None, None] * \
        jnp.einsum("bhk,bhv->bhkv", k, v)
    n = st["n"] * f_eff[..., None] + i_eff[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, 1, di).astype(x.dtype)
    y = apply_rmsnorm(params["norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)).astype(x.dtype)
    out = apply_linear(params["down"], y, policy=policy)
    return out, {"C": C, "n": n, "m": m_new, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM's scalar-memory block; sequential by construction)
# ---------------------------------------------------------------------------

def init_slstm(key, d: int, *, heads=4, sparse=None, dtype=jnp.float32):
    dh = d // heads
    ks = jax.random.split(key, 4)
    return {
        "w_in": init_linear(ks[0], d, 4 * d, sparse=sparse, dtype=dtype),
        # block-diagonal recurrent weights, one (4dh, dh) block per head
        "r": jax.random.normal(ks[1], (heads, 4 * dh, dh), dtype) * 0.1,
        "norm": init_rmsnorm(d, dtype),
        "down": init_linear(ks[2], d, d, sparse=sparse, dtype=dtype),
    }


def init_slstm_state(batch, d, *, heads=4, dtype=jnp.float32):
    dh = d // heads
    z = lambda: jnp.zeros((batch, heads, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, heads, dh), -1e30, jnp.float32)}


def _slstm_cell(params, wx_t, st, *, heads):
    """wx_t: (B, 4D) pre-computed input projection for one step."""
    b = wx_t.shape[0]
    d4 = wx_t.shape[-1]
    dh = d4 // 4 // heads
    rec = jnp.einsum("bhd,hgd->bhg", st["h"].astype(params["r"].dtype),
                     params["r"]).astype(jnp.float32)   # (B,H,4dh)
    pre = wx_t.reshape(b, heads, 4 * dh).astype(jnp.float32) + rec
    z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + st["m"], i_pre)
    i_eff = jnp.exp(i_pre - m_new)
    f_eff = jnp.exp(log_f + st["m"] - m_new)
    c = f_eff * st["c"] + i_eff * z
    n = jnp.maximum(f_eff * st["n"] + i_eff, 1e-6)
    h = o * c / n
    return {"c": c, "n": n, "h": h, "m": m_new}


def apply_slstm_seq(params, x, *, heads=4, policy=None):
    b, t, d = x.shape
    wx = apply_linear(params["w_in"], x, policy=policy)

    def step(st, wx_t):
        st2 = _slstm_cell(params, wx_t, st, heads=heads)
        return st2, st2["h"]

    st0 = init_slstm_state(b, d, heads=heads)
    _, hs = jax.lax.scan(step, st0, wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(b, t, d).astype(x.dtype)
    y = apply_rmsnorm(params["norm"], y)
    return apply_linear(params["down"], y, policy=policy)


def apply_slstm_step(params, x, st, *, heads=4, policy=None):
    b, _, d = x.shape
    wx = apply_linear(params["w_in"], x, policy=policy)[:, 0]
    st2 = _slstm_cell(params, wx, st, heads=heads)
    y = st2["h"].reshape(b, 1, d).astype(x.dtype)
    y = apply_rmsnorm(params["norm"], y)
    return apply_linear(params["down"], y, policy=policy), st2
