"""Mixture-of-Experts with sort-based (dropping) token dispatch.

Dispatch is gather/scatter-based — NOT the one-hot dispatch-einsum — so the
compiled FLOPs stay ≈ tokens × top_k × expert_FFN (the dispatch einsum is
O(tokens² · top_k · d) and would destroy the MODEL_FLOPS/HLO ratio; see
DESIGN.md §6).

Expert parallelism: expert weight tensors are (E, ...) sharded over the
'model' mesh axis.  Under jit/SPMD the gather into the (E, C, D) buffer and
the return scatter lower to all-to-alls over 'model'.  Tokens beyond an
expert's capacity C = tokens·top_k/E · capacity_factor are dropped (their
residual passes through), the standard GShard/Switch behaviour.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.sparse_linear import DENSE_POLICY
from repro.models.layers import apply_linear, init_linear


def init_moe(key, d: int, cfg: MoEConfig, *, sparse=None, dtype=jnp.float32):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    e, f = cfg.num_experts, cfg.d_ff_expert
    scale_in = d ** -0.5
    scale_out = f ** -0.5
    return {
        "router": init_linear(kr, d, e, sparse=None, dtype=dtype),
        # expert weights: (E, in, out) — sharded over 'model' on axis 0
        "w_gate": jax.random.normal(k1, (e, d, f), dtype) * scale_in,
        "w_up": jax.random.normal(k2, (e, d, f), dtype) * scale_in,
        "w_down": jax.random.normal(k3, (e, f, d), dtype) * scale_out,
    }


def apply_moe(params, x, cfg: MoEConfig, *, policy=None, capacity: int | None = None):
    """x: (B, T, D) -> (y (B, T, D), aux_loss scalar).

    With an active sharding context, dispatch runs under shard_map: routing
    and scatter are local per data shard; each model rank slices its experts
    from the (replicated-over-model) buffer, computes its expert FFNs, and
    one all-gather over 'model' returns the outputs (DESIGN.md §5 EP).
    """
    from repro.sharding import context as shctx

    ctx = shctx.get_context()
    if ctx is not None and cfg.num_experts % ctx.tp == 0:
        return _apply_moe_ep(params, x, cfg, ctx, policy=policy,
                             capacity=capacity)
    return _apply_moe_local(params, x, cfg, policy=policy,
                            capacity=capacity)


def _apply_moe_local(params, x, cfg: MoEConfig, *, policy=None, capacity: int | None = None):
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    n_tok = b * t
    xf = x.reshape(n_tok, d)

    logits = apply_linear(params["router"], xf, DENSE_POLICY).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (N, E)
    gate_vals, top_e = jax.lax.top_k(probs, k)               # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize

    # ---- load-balancing auxiliary loss (Switch) ----
    me = probs.mean(0)                                        # (E,)
    one_hot_top = jax.nn.one_hot(top_e[:, 0], e)
    ce = one_hot_top.mean(0)
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    if capacity is None:
        capacity = int(cfg.capacity_factor * n_tok * k / e) or 1
    flat_e = top_e.reshape(-1)                                # (N*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n_tok), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, stok = flat_e[order], flat_gate[order], flat_tok[order]
    # position within expert: rank among same-expert entries
    same = jax.nn.one_hot(se, e, dtype=jnp.int32)             # (N*k, E)
    pos = (jnp.cumsum(same, axis=0) - 1)[jnp.arange(se.shape[0]), se]
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, e * capacity)  # overflow slot

    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[stok].astype(x.dtype))          # drop overflow
    buf = buf[:-1].reshape(e, capacity, d)

    # ---- expert FFN (E-sharded einsums; all-to-all at the boundaries) ----
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))

    # ---- return scatter + weighted combine ----
    out_flat = out.reshape(e * capacity, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.minimum(slot, e * capacity - 1)],
                         jnp.zeros((1, d), x.dtype))
    y = jnp.zeros((n_tok, d), jnp.float32)
    y = y.at[stok].add(gathered.astype(jnp.float32) * sg[:, None])
    return y.reshape(b, t, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert-parallel path (shard_map over the active mesh)
# ---------------------------------------------------------------------------

def _apply_moe_ep(params, x, cfg: MoEConfig, ctx, *, policy, capacity):
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    dp = ctx.batch_axes
    dp_deg = ctx.dp_degree()
    tp = ctx.tp
    n_local = max(1, (b // dp_deg)) * t
    if capacity is None:
        capacity = max(1, int(cfg.capacity_factor * n_local * k / e))
    e_local = e // tp

    def local_fn(router_w, w_gate, w_up, w_down, x_loc):
        bl, tl, _ = x_loc.shape
        n_tok = bl * tl
        xf = x_loc.reshape(n_tok, d)
        logits = jnp.einsum("nd,od->no", xf.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, top_e = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(0)
        ce = jax.nn.one_hot(top_e[:, 0], e).mean(0)
        aux = cfg.router_aux_coef * e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, dp[-1])

        flat_e = top_e.reshape(-1)
        flat_gate = gate_vals.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(n_tok), k)
        order = jnp.argsort(flat_e, stable=True)
        se, sg, stok = flat_e[order], flat_gate[order], flat_tok[order]
        same = jax.nn.one_hot(se, e, dtype=jnp.int32)
        pos = (jnp.cumsum(same, axis=0) - 1)[jnp.arange(se.shape[0]), se]
        keep = pos < capacity
        slot = jnp.where(keep, se * capacity + pos, e * capacity)

        buf = jnp.zeros((e * capacity + 1, d), x_loc.dtype)
        buf = buf.at[slot].set(xf[stok].astype(x_loc.dtype))
        buf = buf[:-1].reshape(e, capacity, d)

        # my experts' slice (buffer is replicated over 'model': free slice)
        rank = jax.lax.axis_index("model")
        my = jax.lax.dynamic_slice_in_dim(buf, rank * e_local, e_local, 0)
        g = jnp.einsum("ecd,edf->ecf", my, w_gate.astype(x_loc.dtype))
        u = jnp.einsum("ecd,edf->ecf", my, w_up.astype(x_loc.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * u
        out_loc = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x_loc.dtype))
        # gather every rank's expert outputs: (E, C, D) on all model ranks
        out = jax.lax.all_gather(out_loc, "model", axis=0, tiled=True)

        out_flat = out.reshape(e * capacity, d)
        gathered = jnp.where(
            keep[:, None],
            out_flat[jnp.minimum(slot, e * capacity - 1)],
            jnp.zeros((1, d), x_loc.dtype))
        y = jnp.zeros((n_tok, d), jnp.float32)
        y = y.at[stok].add(gathered.astype(jnp.float32) * sg[:, None])
        return y.reshape(bl, tl, d).astype(x_loc.dtype), aux

    y, aux = shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_rep=False,
    )(params["router"]["w"], params["w_gate"], params["w_up"],
      params["w_down"], x)
    return y, aux
