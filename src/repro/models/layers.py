"""Shared layer primitives: norms, RoPE, embeddings, (sparse) MLP.

Pure-functional: params are nested dicts of arrays; every ``init_*`` has a
matching ``apply_*``.  Weight matrices that fall inside the arch's
``sparse_scope`` are created through the DeMM SparseLinear paths — masked
dense for training, packed for serving (repro.core.sparse_linear).
"""

from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sparse_linear as sl
from repro.core.pruning import masked_weight
from repro.core.sparse_linear import ExecPolicy, resolve_policy
from repro.core.sparsity import PackedWeight, SparsityConfig, Static
from repro.configs.base import choose_group


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Linear with optional DeMM sparsity
# ---------------------------------------------------------------------------

PRODUCTION_TP = 16  # group boundaries must align to TP shards (DESIGN.md §4)


def init_linear(key, in_f: int, out_f: int, *, sparse: Optional[SparsityConfig],
                dtype=jnp.float32, name: str = "linear"):
    """Weight (out_f, in_f).  When ``sparse`` is set, the effective group
    config is adapted to the contraction dim (choose_group) and the weight is
    initialized pre-pruned to the pattern; the resolved config (including
    the requested k-reconfiguration) is stored as ``sparsity`` static
    metadata so it survives pack → serve → checkpoint end to end.

    The group size M must divide the per-TP-shard slice of the contraction
    dim (row-parallel weights shard K over 'model'): otherwise computing the
    N:M mask forces an all-gather of the weight.  We therefore align M to
    ``in_f // PRODUCTION_TP`` whenever the dim is TP-divisible."""
    if sparse is not None:
        k_align = in_f // PRODUCTION_TP if in_f % PRODUCTION_TP == 0 else in_f
        cfg = choose_group(k_align, sparse.density, sparse.m)
        if sparse.k > 1:
            if cfg.n_effective % sparse.k == 0:
                # re-express the adapted pattern with the requested
                # k-reconfiguration (same n_effective, same numerics)
                cfg = SparsityConfig(cfg.n_effective // sparse.k, cfg.m,
                                     sparse.k)
            else:
                warnings.warn(
                    f"requested k={sparse.k} reconfiguration cannot be kept "
                    f"for {name}: the group config adapted to the "
                    f"contraction dim ({cfg.pattern_name()}) has "
                    f"n_effective={cfg.n_effective} not divisible by k; "
                    "storing k=1", stacklevel=2)
        p = sl.init_sparse(key, in_f, out_f, cfg, dtype)
        p["sparsity"] = Static(cfg)   # static metadata (not traced)
        return p
    return sl.init_dense(key, in_f, out_f, dtype)


def apply_linear(params, x, policy: Optional[ExecPolicy] = None, *,
                 mode: Optional[str] = None, backend: Optional[str] = None):
    """Apply a linear node (dense dict, masked-sparse dict, or PackedWeight)
    under an :class:`ExecPolicy`.  ``mode=``/``backend=`` are accepted as
    legacy kwargs and folded into a policy."""
    if mode is not None or backend is not None or policy is None:
        policy = resolve_policy(policy, mode, backend)
    return sl.apply(params, x, policy)


def pack_linear(params):
    """Convert a (sparse) trained linear to the packed DeMM serving form."""
    if isinstance(params, PackedWeight):
        return params
    cfg = sl.node_sparsity(params)
    if cfg is None:
        return params
    return sl.pack_params(params, cfg)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def apply_rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, Dh); positions: (B, T) or (T,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,T,Dh/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def apply_embedding(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def apply_unembedding(params, x, true_vocab: Optional[int] = None):
    """Logits = x @ tableᵀ (vocab-sharded over 'model').  When the table is
    padded (padded_vocab > true_vocab), the padded columns are masked to a
    large negative so neither the loss nor greedy decode can select them."""
    logits = jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))
    v = logits.shape[-1]
    if true_vocab is not None and true_vocab < v:
        pad_mask = jnp.arange(v) >= true_vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


# ---------------------------------------------------------------------------
# Gated MLP (dense or DeMM-sparse)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, *, sparse, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d, d_ff, sparse=sparse, dtype=dtype),
        "up": init_linear(k2, d, d_ff, sparse=sparse, dtype=dtype),
        "down": init_linear(k3, d_ff, d, sparse=sparse, dtype=dtype),
    }


def apply_mlp(params, x, *, policy: Optional[ExecPolicy] = None):
    g = apply_linear(params["gate"], x, policy)
    u = apply_linear(params["up"], x, policy)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u.astype(x.dtype)
    return apply_linear(params["down"], h, policy)
