"""Shared layer primitives: norms, RoPE, embeddings, (sparse) MLP.

Pure-functional: params are nested dicts of arrays; every ``init_*`` has a
matching ``apply_*``.  Weight matrices that fall inside the arch's
``sparse_scope`` are created through the DeMM SparseLinear paths — masked
dense for training, packed for serving (repro.core.sparse_linear).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sparse_linear as sl
from repro.core.pruning import masked_weight
from repro.core.sparsity import SparsityConfig
from repro.configs.base import choose_group


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


@jax.tree_util.register_static
class Static:
    """Hashable static metadata stored inside a params pytree (not traced)."""

    def __init__(self, value):
        self.value = value

    def __eq__(self, other):
        return isinstance(other, Static) and self.value == other.value

    def __hash__(self):
        return hash(("Static", self.value))

    def __repr__(self):
        return f"Static({self.value!r})"


# ---------------------------------------------------------------------------
# Linear with optional DeMM sparsity
# ---------------------------------------------------------------------------

PRODUCTION_TP = 16  # group boundaries must align to TP shards (DESIGN.md §4)


def init_linear(key, in_f: int, out_f: int, *, sparse: Optional[SparsityConfig],
                dtype=jnp.float32, name: str = "linear"):
    """Weight (out_f, in_f).  When ``sparse`` is set, the effective group
    config is adapted to the contraction dim (choose_group) and the weight is
    initialized pre-pruned to the pattern.

    The group size M must divide the per-TP-shard slice of the contraction
    dim (row-parallel weights shard K over 'model'): otherwise computing the
    N:M mask forces an all-gather of the weight.  We therefore align M to
    ``in_f // PRODUCTION_TP`` whenever the dim is TP-divisible."""
    if sparse is not None:
        k_align = in_f // PRODUCTION_TP if in_f % PRODUCTION_TP == 0 else in_f
        cfg = choose_group(k_align, sparse.density, sparse.m)
        p = sl.init_sparse(key, in_f, out_f, cfg, dtype)
        p["_sparse_m"] = Static(cfg.m)   # static metadata (not traced)
        p["_sparse_n"] = Static(cfg.n)
        return p
    return sl.init_dense(key, in_f, out_f, dtype)


def apply_linear(params, x, *, mode: str = "masked", backend: str = "reference"):
    """mode: dense | masked (train) | packed (serve)."""
    if "_sparse_m" not in params and "values" not in params:
        return sl.apply_dense(params, x)
    if "values" in params:  # packed serving form
        cfg = SparsityConfig(params["_sparse_n"].value,
                             params["_sparse_m"].value, 1)
        return sl.apply_packed(params, x, cfg, backend=backend)
    cfg = SparsityConfig(params["_sparse_n"].value, params["_sparse_m"].value, 1)
    if mode == "dense":
        return sl.apply_dense(params, x)
    return sl.apply_masked(params, x, cfg)


def pack_linear(params):
    """Convert a (sparse) trained linear to the packed DeMM serving form."""
    if "_sparse_m" not in params:
        return params
    cfg = SparsityConfig(params["_sparse_n"].value, params["_sparse_m"].value, 1)
    out = sl.pack_params(params, cfg)
    out["_sparse_m"] = Static(cfg.m)
    out["_sparse_n"] = Static(cfg.n)
    return out


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def apply_rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, Dh); positions: (B, T) or (T,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,T,Dh/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def apply_embedding(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def apply_unembedding(params, x, true_vocab: Optional[int] = None):
    """Logits = x @ tableᵀ (vocab-sharded over 'model').  When the table is
    padded (padded_vocab > true_vocab), the padded columns are masked to a
    large negative so neither the loss nor greedy decode can select them."""
    logits = jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))
    v = logits.shape[-1]
    if true_vocab is not None and true_vocab < v:
        pad_mask = jnp.arange(v) >= true_vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


# ---------------------------------------------------------------------------
# Gated MLP (dense or DeMM-sparse)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, *, sparse, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d, d_ff, sparse=sparse, dtype=dtype),
        "up": init_linear(k2, d, d_ff, sparse=sparse, dtype=dtype),
        "down": init_linear(k3, d_ff, d, sparse=sparse, dtype=dtype),
    }


def apply_mlp(params, x, *, mode="masked", backend="reference"):
    g = apply_linear(params["gate"], x, mode=mode, backend=backend)
    u = apply_linear(params["up"], x, mode=mode, backend=backend)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u.astype(x.dtype)
    return apply_linear(params["down"], h, mode=mode, backend=backend)
