"""Attention: GQA with full / sliding-window / local:global patterns, plus
cross-attention and decode (KV-cache) paths.

Training/prefill uses a chunked flash-style attention: an outer scan over Q
chunks and an inner scan over KV chunks with an online-softmax accumulator,
so activation memory is O(T · chunk) instead of O(T²) — required for the
32k-prefill dry-run cells to fit.

Decode computes one new token against a cache of S past tokens; for
long-context decode the KV cache may be *sequence-sharded* over the 'data'
mesh axis — the online-softmax combine is a (max, sum) reduction, which XLA
SPMD turns into the flash-decode all-reduce pattern automatically because we
express it with stable logsumexp accumulation over the (sharded) S axis.

Window semantics: ``window`` < 0 means unbounded (full causal); a positive
window w lets position t attend to [t-w+1, t].
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _windowed(window) -> bool:
    """Static check: is a window mask needed?  ``window`` may be a python int
    (<=0 or None means unbounded) or a traced int32 (always masked; the
    FULL_WINDOW sentinel makes the mask a no-op for global layers)."""
    if window is None:
        return False
    if isinstance(window, (int, float)):
        return window > 0
    return True  # traced value: emit the mask


def _gqa_scores(q, k):
    """q: (B, Tq, Hq, Dh), k: (B, S, Hkv, Dh) -> (B, Hq, Tq, S)."""
    b, tq, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, tq, hkv, group, dh)
    s = jnp.einsum("bthgd,bshd->bhgts", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    return s.reshape(b, hkv * group, tq, k.shape[1])


def _gqa_out(p, v):
    """p: (B, Hq, Tq, S), v: (B, S, Hkv, Dh) -> (B, Tq, Hq, Dh)."""
    b, hq, tq, s = p.shape
    hkv = v.shape[2]
    group = hq // hkv
    pg = p.reshape(b, hkv, group, tq, s)
    o = jnp.einsum("bhgts,bshd->bthgd", pg, v.astype(jnp.float32))
    return o.reshape(b, tq, hq, v.shape[-1])


def flash_attention(
    q: jax.Array,              # (B, T, Hq, Dh)
    k: jax.Array,              # (B, S, Hkv, Dh)
    v: jax.Array,              # (B, S, Hkv, Dh)
    *,
    causal: bool = True,
    window: int = -1,
    static_window: Optional[int] = None,  # python int: banded inner scan
    q_offset: int = 0,         # absolute position of q[0] (prefill continuation)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Chunked online-softmax attention.  Returns (B, T, Hq, Dh) in q.dtype.

    ``static_window``: when the window is known at trace time (SWA archs,
    gemma3 local layers), the inner KV scan only visits the
    ``ceil((W + qc)/kvc) + 1`` chunks that can intersect the band, instead
    of all S/kvc — an ~S/W cut in attention FLOPs, bytes, and (when K/V are
    head_dim-sharded) collectives (DESIGN.md §5)."""
    b, t, hq, dh = q.shape
    s = k.shape[1]
    scale = dh ** -0.5
    q_chunk = min(q_chunk, t)
    kv_chunk = min(kv_chunk, s)
    # pad T and S to chunk multiples
    tp = -(-t // q_chunk) * q_chunk
    sp = -(-s // kv_chunk) * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    n_q, n_kv = tp // q_chunk, sp // kv_chunk

    if static_window is not None and static_window > 0:
        window = static_window
        n_band = min(n_kv, (static_window + q_chunk - 2) // kv_chunk + 2)
    else:
        n_band = n_kv

    q_pos_base = jnp.arange(q_chunk)
    kv_pos_base = jnp.arange(kv_chunk)

    def q_step(_, qi):
        qc = jax.lax.dynamic_slice_in_dim(qp, qi * q_chunk, q_chunk, axis=1)
        q_pos = q_pos_base + qi * q_chunk + q_offset
        if n_band < n_kv:
            # first chunk that can contain position q0 - W + 1
            base = jnp.clip((qi * q_chunk + q_offset - window + 1)
                            // kv_chunk, 0, n_kv - n_band)
        else:
            base = 0

        def kv_step(carry, j):
            acc, m_run, l_run = carry
            ki = base + j
            kc = jax.lax.dynamic_slice_in_dim(kp, ki * kv_chunk, kv_chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(vp, ki * kv_chunk, kv_chunk, 1)
            kv_pos = kv_pos_base + ki * kv_chunk
            logits = _gqa_scores(qc, kc) * scale      # (B,Hq,qc,kc) fp32
            mask = kv_pos[None, :] < s                 # padding
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            if _windowed(window):
                mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, logits.max(-1))          # (B,Hq,qc)
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + _gqa_out(p, vc).swapaxes(1, 2)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, hq, q_chunk, dh), jnp.float32)
        m0 = jnp.full((b, hq, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, q_chunk), jnp.float32)
        (acc, m_run, l_run), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(n_band))
        out = acc / jnp.maximum(l_run[..., None], 1e-30)        # (B,Hq,qc,Dh)
        return (), out.swapaxes(1, 2)                           # (B,qc,Hq,Dh)

    _, outs = jax.lax.scan(q_step, (), jnp.arange(n_q))         # (nq,B,qc,..)
    out = outs.swapaxes(0, 1).reshape(b, tp, hq, dh)[:, :t]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,              # (B, 1, Hq, Dh)
    k_cache: jax.Array,        # (B, S, Hkv, Dh)
    v_cache: jax.Array,        # (B, S, Hkv, Dh)
    cache_len: jax.Array,      # (B,) valid lengths (new token already written)
    *,
    window: int = -1,
) -> jax.Array:
    """One-token attention against the cache.

    Expressed as a single stable-softmax reduction over S so that a
    sequence-sharded cache (long-context decode) lowers to the flash-decode
    partial-softmax + all-reduce combine under SPMD.
    """
    b, s, hkv, dh = k_cache.shape
    scale = dh ** -0.5
    logits = _gqa_scores(q, k_cache) * scale          # (B, Hq, 1, S)
    pos = jnp.arange(s)[None, :]                       # (1, S)
    valid = pos < cache_len[:, None]
    if _windowed(window):
        valid = valid & (pos > cache_len[:, None] - 1 - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    out = _gqa_out(p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30), v_cache)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged KV cache indexing (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The paper's decoupling idea applied to serving state: the KV *arena* is a
# flat pool of fixed-size pages shared by every sequence, and each sequence
# addresses it through a small block table — an indirection stream, exactly
# like the col_idx stream that lets the DeMM compute units read a packed
# weight buffer.  Page 0 is the reserved null/scratch page: block-table
# entries of inactive or not-yet-allocated positions point there, writes for
# masked lanes are redirected there, and it is never read un-masked.

NULL_PAGE = 0


def gather_pages(arena: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialize per-sequence caches from the shared arena.

    arena: (Np, P, Hkv, Dh); block_table: (B, NBLK) physical page ids in
    sequence order.  Returns (B, NBLK*P, Hkv, Dh) where gathered position
    ``s`` holds the KV of absolute token position ``s``.
    """
    b, nblk = block_table.shape
    p = arena.shape[1]
    return arena[block_table].reshape(b, nblk * p, *arena.shape[2:])


def scatter_token_pages(arena: jax.Array, block_table: jax.Array,
                        pos: jax.Array, new: jax.Array,
                        active: Optional[jax.Array] = None) -> jax.Array:
    """Write one token per sequence into its page (decode step).

    new: (B, 1, Hkv, Dh) written at absolute positions pos (B,).  Lanes with
    ``active`` False (empty slots, slots still prefilling) are redirected to
    the null page so a batched decode step cannot corrupt them.
    """
    p = arena.shape[1]
    page = jnp.take_along_axis(block_table, (pos // p)[:, None], axis=1)[:, 0]
    if active is not None:
        page = jnp.where(active, page, NULL_PAGE)
    return arena.at[page, pos % p].set(new[:, 0].astype(arena.dtype))


def scatter_chunk_pages(arena: jax.Array, row_table: jax.Array,
                        pos0: jax.Array, new: jax.Array,
                        n_valid: jax.Array) -> jax.Array:
    """Write a K-token prefill chunk of ONE sequence straight into its pages.

    new: (K, Hkv, Dh) for absolute positions pos0..pos0+K-1; rows >= n_valid
    (padding of the last partial chunk) go to the null page.  row_table:
    (NBLK,) — this sequence's block-table row.
    """
    k = new.shape[0]
    p = arena.shape[1]
    apos = pos0 + jnp.arange(k)
    page = jnp.where(jnp.arange(k) < n_valid, row_table[apos // p], NULL_PAGE)
    return arena.at[page, apos % p].set(new.astype(arena.dtype))


# ---------------------------------------------------------------------------
# Full attention block (init + train/prefill/decode apply)
# ---------------------------------------------------------------------------

from repro.models.layers import apply_linear, apply_rope, init_linear  # noqa: E402


def init_attention(key, d: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, *, sparse=None, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_linear(kq, d, num_heads * head_dim, sparse=sparse, dtype=dtype),
        "wk": init_linear(kk, d, num_kv_heads * head_dim, sparse=sparse, dtype=dtype),
        "wv": init_linear(kv, d, num_kv_heads * head_dim, sparse=sparse, dtype=dtype),
        "wo": init_linear(ko, num_heads * head_dim, d, sparse=sparse, dtype=dtype),
    }


def _constrain_heads(x, *, seq_sharded=False):
    """Pin (B, S, H, Dh) tensors to the TP layout (DESIGN.md §5):

    1. heads over 'model' when this tensor's head count divides TP;
    2. else, if the arch's *Q* head count divides TP, REPLICATE this (K/V)
       tensor — Q carries the sharding and the GQA einsums stay local (the
       per-chunk logits psum of head_dim sharding costs ~1000x more, see
       DESIGN.md §5);
    3. else REPLICATE q/k/v: attention runs replicated over 'model' (one
       gather per projection instead of a psum per flash chunk — §Perf
       iteration 4; these are small-head archs where attention is a minor
       FLOPs fraction, and ring attention is the noted future alternative);

    batch over the DP axes (or seq over 'data' for seq-sharded caches).
    Without an active mesh context this is the identity."""
    from repro.sharding import context as shctx

    ctx = shctx.get_context()
    if ctx is None:
        return x
    tp = ctx.tp
    h, dh = x.shape[2], x.shape[3]
    if h % tp == 0:
        mspec = ("model", None)
    else:
        mspec = (None, None)        # replicated (K/V of GQA, or all three)
    if seq_sharded:
        return shctx.constrain(x, None, "data", *mspec)
    batch = x.shape[0]
    bspec = "BATCH" if batch % ctx.dp_degree() == 0 else None
    return shctx.constrain(x, bspec, None, *mspec)


def _project_qkv(params, x, kv_x, num_heads, num_kv_heads, head_dim,
                 policy):
    b, t, _ = x.shape
    skv = kv_x.shape[1]
    q = apply_linear(params["wq"], x, policy=policy)
    k = apply_linear(params["wk"], kv_x, policy=policy)
    v = apply_linear(params["wv"], kv_x, policy=policy)
    return (_constrain_heads(q.reshape(b, t, num_heads, head_dim)),
            _constrain_heads(k.reshape(b, skv, num_kv_heads, head_dim)),
            _constrain_heads(v.reshape(b, skv, num_kv_heads, head_dim)))


def apply_attention(
    params, x, *, num_heads, num_kv_heads, head_dim, rope_theta,
    positions=None, causal=True, window=-1, static_window=None, kv_x=None,
    policy=None, q_chunk=512, kv_chunk=1024,
):
    """Self- (kv_x=None) or cross- (kv_x=encoder out, causal=False) attention."""
    b, t, _ = x.shape
    cross = kv_x is not None
    kv_src = kv_x if cross else x
    q, k, v = _project_qkv(params, x, kv_src, num_heads, num_kv_heads,
                           head_dim, policy)
    if positions is None:
        positions = jnp.arange(t)
    if not cross:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    out = flash_attention(q, k, v, causal=causal and not cross, window=window,
                          static_window=static_window,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(b, t, num_heads * head_dim)
    return apply_linear(params["wo"], out, policy=policy)


def apply_attention_decode(
    params, x, cache, pos, *, num_heads, num_kv_heads, head_dim, rope_theta,
    window=-1, policy=None,
):
    """One-token decode.  cache: {"k": (B,S,Hkv,Dh), "v": ...}; pos: (B,)
    index at which to write the new KV (== current length).  Returns
    (out (B,1,D), new_cache)."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, x, num_heads, num_kv_heads,
                                   head_dim, policy)
    q = apply_rope(q, pos[:, None], rope_theta)
    k_new = apply_rope(k_new, pos[:, None], rope_theta)
    onehot = jax.nn.one_hot(pos, cache["k"].shape[1],
                            dtype=cache["k"].dtype)    # (B, S)
    # replace (not accumulate) at pos: identical when the slot is zero, but
    # a speculative rollback (repro.spec) re-writes positions whose rejected
    # draft KV is still resident — the write must be idempotent.
    keep = (1.0 - onehot)[:, :, None, None]
    put = onehot[:, :, None, None]
    k_cache = cache["k"] * keep + put * k_new.astype(cache["k"].dtype)
    v_cache = cache["v"] * keep + put * v_new.astype(cache["v"].dtype)
    out = decode_attention(q, k_cache, v_cache, pos + 1, window=window)
    out = out.reshape(b, 1, num_heads * head_dim)
    out = apply_linear(params["wo"], out, policy=policy)
    return out, {"k": k_cache, "v": v_cache}


def apply_attention_decode_paged(
    params, x, arena_k, arena_v, block_table, active, pos, *, num_heads,
    num_kv_heads, head_dim, rope_theta, window=-1, policy=None,
):
    """One-token decode against a paged KV arena (DESIGN.md §13).

    arena_k/arena_v: (Np, P, Hkv, Dh) shared pools; block_table (B, NBLK);
    active (B,) bool decode mask; pos (B,) absolute write position.  The new
    KV is scattered into the owning page (null-redirected for inactive
    lanes), then the per-sequence caches are gathered back and attention
    runs exactly as in the dense-cache path — same masks, same reduction —
    so paged and dense decode are token-identical.  Returns
    (out (B,1,D), (new_arena_k, new_arena_v)).
    """
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, x, num_heads, num_kv_heads,
                                   head_dim, policy)
    q = apply_rope(q, pos[:, None], rope_theta)
    k_new = apply_rope(k_new, pos[:, None], rope_theta)
    arena_k = scatter_token_pages(arena_k, block_table, pos, k_new, active)
    arena_v = scatter_token_pages(arena_v, block_table, pos, v_new, active)
    k_c = gather_pages(arena_k, block_table)
    v_c = gather_pages(arena_v, block_table)
    out = decode_attention(q, k_c, v_c, pos + 1, window=window)
    out = out.reshape(b, 1, num_heads * head_dim)
    out = apply_linear(params["wo"], out, policy=policy)
    return out, (arena_k, arena_v)


def apply_attention_prefill_paged(
    params, x, arena_k, arena_v, row_table, pos0, n_valid, *, num_heads,
    num_kv_heads, head_dim, rope_theta, policy=None, q_chunk=64,
    kv_chunk=128,
):
    """One K-token prefill chunk of ONE sequence against the paged arena.

    x: (1, K, D) embedded chunk for absolute positions pos0..pos0+K-1 (the
    last chunk is padded; rows >= n_valid are masked to the null page).  The
    chunk's KV is scattered into the sequence's pages first, then flash
    attention runs the K queries against the gathered cache with
    ``q_offset=pos0`` — causal masking covers both the intra-chunk triangle
    and earlier chunks, and excludes unwritten (garbage) positions beyond
    pos0 + n_valid.  One call == one compiled dispatch for K tokens: the
    O(prompt_len) token-by-token ingest becomes O(prompt_len / K).
    """
    b, k_tok, _ = x.shape
    q, k_new, v_new = _project_qkv(params, x, x, num_heads, num_kv_heads,
                                   head_dim, policy)
    apos = pos0 + jnp.arange(k_tok)
    q = apply_rope(q, apos[None, :], rope_theta)
    k_new = apply_rope(k_new, apos[None, :], rope_theta)
    arena_k = scatter_chunk_pages(arena_k, row_table, pos0, k_new[0], n_valid)
    arena_v = scatter_chunk_pages(arena_v, row_table, pos0, v_new[0], n_valid)
    k_c = gather_pages(arena_k, row_table[None])
    v_c = gather_pages(arena_v, row_table[None])
    out = flash_attention(q, k_c, v_c, causal=True, q_offset=pos0,
                          q_chunk=min(q_chunk, k_tok), kv_chunk=kv_chunk)
    out = out.reshape(b, k_tok, num_heads * head_dim)
    return apply_linear(params["wo"], out, policy=policy), (arena_k, arena_v)


def init_kv_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
    }
