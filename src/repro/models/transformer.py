"""Model assembly for all assigned architecture families.

Four families share one functional interface:

    model = build_model(cfg)
    params = model.init(key)
    loss, metrics = model.train_loss(params, batch, policy=ExecPolicy(...))
    logits, state = model.prefill(params, inputs)
    logits, state = model.decode_step(params, state, tokens)

* ``DecoderLM``   — dense / moe / vlm (vision stub prepends patch embeddings)
* ``EncDecLM``    — seamless-m4t (audio-stub encoder + cross-attn decoder)
* ``HybridLM``    — zamba2 (Mamba2 backbone + shared attention block)
* ``XLSTMLM``     — xlstm (periodic sLSTM/mLSTM superblocks)

Layers are stacked and scanned (``jax.lax.scan``) with ``jax.checkpoint``
remat so the 81-layer/48-layer configs compile to compact HLO.  Layer-type
variation (gemma3 local:global, zamba shared-attn sites) is handled with
per-layer window values (train) and cond-free superblock scans (decode), so
every HLO while-loop carries an exact known_trip_count for the roofline.

Decode caches:
* full-attention layers — (B, S, Hkv, Dh) append caches;
* windowed layers — (B, W, Hkv, Dh) ring buffers with per-slot positions;
* SSM layers — O(1) recurrent states.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.sparse_linear import ExecPolicy, resolve_policy
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_embedding,
    apply_linear,
    apply_mlp,
    apply_rmsnorm,
    apply_rope,
    apply_unembedding,
    dtype_of,
    Static,
    init_embedding,
    init_linear,
    init_mlp,
    init_rmsnorm,
)

FULL_WINDOW = jnp.int32(2**30)  # "unbounded" window sentinel (traced-safe)


# ---------------------------------------------------------------------------
# Ring-buffer (windowed) KV cache
# ---------------------------------------------------------------------------

def init_ring_cache(batch, window, hkv, dh, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, window, hkv, dh), dtype),
        "v": jnp.zeros((batch, window, hkv, dh), dtype),
        "slot_pos": jnp.full((batch, window), -1, jnp.int32),
    }


def ring_decode_attention(params_block, x, cache, pos, *, cfg: ArchConfig,
                          window, policy):
    """One-token attention against a ring-buffer cache (window W slots)."""
    b = x.shape[0]
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k_new, v_new = attn._project_qkv(params_block, x, x, hq, hkv, dh,
                                        policy)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
    w = cache["k"].shape[1]
    slot = pos % w                                         # (B,)
    onehot = jax.nn.one_hot(slot, w, dtype=cache["k"].dtype)
    keepm = (1.0 - onehot)[:, :, None, None]
    k_c = cache["k"] * keepm + onehot[:, :, None, None] * k_new.astype(cache["k"].dtype)
    v_c = cache["v"] * keepm + onehot[:, :, None, None] * v_new.astype(cache["v"].dtype)
    slot_pos = jnp.where(jax.nn.one_hot(slot, w, dtype=jnp.int32) > 0,
                         pos[:, None], cache["slot_pos"])
    # mask directly from stored absolute positions
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None]) & \
        (slot_pos > pos[:, None] - window)
    logits = attn._gqa_scores(q, k_c) * dh ** -0.5
    logits = jnp.where(valid[:, None, None, :], logits, attn.NEG_INF)
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    out = attn._gqa_out(p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30), v_c)
    out = out.reshape(b, 1, hq * dh).astype(x.dtype)
    out = apply_linear(params_block["wo"], out, policy=policy)
    return out, {"k": k_c, "v": v_c, "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# Standard transformer block (attention + MLP/MoE)
# ---------------------------------------------------------------------------

def init_tblock(key, cfg: ArchConfig, *, cross=False, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    sp = cfg.sparsity
    blk = {
        "ln1": init_rmsnorm(d, dtype),
        "attn": attn.init_attention(
            ks[0], d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
            sparse=sp if "attn_qkv" in cfg.sparse_scope else None, dtype=dtype),
        "ln2": init_rmsnorm(d, dtype),
    }
    if cross:
        blk["ln_x"] = init_rmsnorm(d, dtype)
        blk["xattn"] = attn.init_attention(
            ks[1], d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
            sparse=None, dtype=dtype)
    if cfg.moe is not None:
        blk["moe"] = moe_mod.init_moe(
            ks[2], d, cfg.moe,
            sparse=sp if "mlp" in cfg.sparse_scope else None, dtype=dtype)
    else:
        blk["mlp"] = init_mlp(ks[3], d, cfg.d_ff,
                              sparse=sp if "mlp" in cfg.sparse_scope else None,
                              dtype=dtype)
    return blk


def apply_tblock_seq(blk, x, cfg: ArchConfig, *, window, positions=None,
                     enc_out=None, causal=True, static_window=None,
                     policy):
    h = apply_rmsnorm(blk["ln1"], x)
    h = attn.apply_attention(
        blk["attn"], h,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        positions=positions, causal=causal, window=window,
        static_window=static_window, policy=policy)
    x = x + h
    if "xattn" in blk and enc_out is not None:
        h = apply_rmsnorm(blk["ln_x"], x)
        h = attn.apply_attention(
            blk["xattn"], h,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            causal=False, window=-1, kv_x=enc_out, policy=policy)
        x = x + h
    h = apply_rmsnorm(blk["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in blk:
        h, aux = moe_mod.apply_moe(blk["moe"], h, cfg.moe, policy=policy)
    else:
        h = apply_mlp(blk["mlp"], h, policy=policy)
    return x + h, aux


# ---------------------------------------------------------------------------
# Per-layer window schedule (gemma3 local:global, h2o SWA, full)
# ---------------------------------------------------------------------------

def layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """int32 (L,): attention window per layer (FULL_WINDOW = unbounded)."""
    l = cfg.num_layers
    if cfg.attention == "swa":
        return jnp.full((l,), cfg.window, jnp.int32)
    if cfg.attention == "local_global":
        idx = jnp.arange(l)
        is_global = (idx % (cfg.local_global_ratio + 1)) == cfg.local_global_ratio
        return jnp.where(is_global, FULL_WINDOW, cfg.local_window)
    return jnp.full((l,), FULL_WINDOW, jnp.int32)


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots" else None)
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# DecoderLM: dense / moe / vlm
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DecoderLM:
    cfg: ArchConfig

    def init(self, key):
        cfg = self.cfg
        dtype = dtype_of(cfg.param_dtype)
        k_e, k_u, k_l, k_p = jax.random.split(key, 4)
        layer_keys = jax.random.split(k_l, cfg.num_layers)
        layers = jax.vmap(
            lambda k: init_tblock(k, cfg, dtype=dtype))(layer_keys)
        params = {
            "embed": init_embedding(k_e, cfg.padded_vocab, cfg.d_model, dtype),
            "unembed": init_embedding(k_u, cfg.padded_vocab, cfg.d_model, dtype),
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
            "layers": layers,
        }
        if cfg.frontend == "vision":
            params["patch_proj"] = init_linear(k_p, cfg.d_model, cfg.d_model,
                                               sparse=None, dtype=dtype)
        return params

    # ---- full-sequence forward (train / prefill logits) ----
    def _backbone_seq(self, params, x, *, positions, policy):
        cfg = self.cfg

        if cfg.attention == "local_global":
            # cond-free superblocks with STATIC local windows: local layers
            # run banded flash (DESIGN.md §5).
            period, n_p, n_tail = self._lg_layout()
            stacked = jax.tree.map(
                lambda a: a[:n_p * period].reshape(n_p, period,
                                                   *a.shape[1:]),
                params["layers"])
            tail = jax.tree.map(lambda a: a[n_p * period:], params["layers"])

            def body(carry, blks):
                x, aux = carry
                for i in range(period - 1):
                    blk = jax.tree.map(lambda a: a[i], blks)
                    x, a = apply_tblock_seq(
                        blk, x, cfg, window=cfg.local_window,
                        static_window=cfg.local_window,
                        positions=positions, policy=policy)
                    aux = aux + a
                blk = jax.tree.map(lambda a: a[period - 1], blks)
                x, a = apply_tblock_seq(blk, x, cfg, window=-1,
                                        positions=positions, policy=policy)
                return (x, aux + a), None

            (x, aux), _ = jax.lax.scan(
                _remat(body, cfg), (x, jnp.zeros((), jnp.float32)), stacked)
            for i in range(n_tail):
                blk = jax.tree.map(lambda a: a[i], tail)
                x, a = apply_tblock_seq(
                    blk, x, cfg, window=cfg.local_window,
                    static_window=cfg.local_window, positions=positions,
                    policy=policy)
                aux = aux + a
            return apply_rmsnorm(params["final_norm"], x), aux

        static_window = cfg.window if cfg.attention == "swa" else None
        windows = layer_windows(cfg)

        def body(carry, layer):
            x, aux = carry
            blk, window = layer
            x, a = apply_tblock_seq(blk, x, cfg, window=window,
                                    static_window=static_window,
                                    positions=positions, policy=policy)
            return (x, aux + a), None

        body = _remat(body, cfg)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   (params["layers"], windows))
        return apply_rmsnorm(params["final_norm"], x), aux

    def _embed_inputs(self, params, batch, dtype):
        cfg = self.cfg
        x = apply_embedding(params["embed"], batch["tokens"]).astype(dtype)
        if cfg.frontend == "vision":
            pe = apply_linear(params["patch_proj"],
                              batch["patch_embeds"].astype(dtype))
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def train_loss(self, params, batch, *, policy=None,
                         mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        cfg = self.cfg
        dtype = dtype_of(cfg.compute_dtype)
        x = self._embed_inputs(params, batch, dtype)
        t = x.shape[1]
        x, aux = self._backbone_seq(params, x, positions=jnp.arange(t),
                                    policy=policy)
        if cfg.frontend == "vision":  # only text positions carry loss
            x = x[:, cfg.num_patches:]
        logits = apply_unembedding(params["unembed"], x, self.cfg.vocab_size)
        loss = softmax_xent(logits, batch["targets"])
        return loss + aux, {"xent": loss, "aux": aux}

    # ---- serving ----
    def prefill(self, params, batch, *, max_len=None, policy=None,
                      mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        cfg = self.cfg
        dtype = dtype_of(cfg.compute_dtype)
        x = self._embed_inputs(params, batch, dtype)
        b, t = x.shape[0], x.shape[1]
        x, _ = self._backbone_seq(params, x, positions=jnp.arange(t),
                                  policy=policy)
        logits = apply_unembedding(params["unembed"], x[:, -1:], self.cfg.vocab_size)
        state = self.init_decode_state(b, max_len or t + 1, dtype=dtype)
        # NOTE: serving fills the cache during prefill; for the dry-run cells
        # the decode state is initialized directly (decode-only lowering).
        return logits, state

    def _lg_layout(self):
        """local_global layout: (period, n_periods, n_tail)."""
        cfg = self.cfg
        period = cfg.local_global_ratio + 1
        n_p = cfg.num_layers // period
        return period, n_p, cfg.num_layers - n_p * period

    def init_decode_state(self, batch, max_len, dtype=jnp.bfloat16,
                          paged=None):
        """Decode-state pytree.  ``paged`` (a ``repro.paged.PagedLayout``)
        swaps the dense per-slot KV caches for one shared paged arena +
        per-sequence block tables (DESIGN.md §13); only full-attention
        caches are paged — windowed ring buffers are already O(window)."""
        cfg = self.cfg
        hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        l = cfg.num_layers

        if paged is not None:
            if cfg.attention != "full":
                raise NotImplementedError(
                    f"paged KV cache needs attention='full' (got "
                    f"{cfg.attention!r}): windowed ring buffers are already "
                    f"O(window) per slot; paging the local_global global "
                    f"layers is future work (DESIGN.md §13)")
            return {
                "caches": {
                    "kind": Static("paged"),
                    "layout": Static(paged),
                    "k": jnp.zeros((l, paged.num_pages, paged.page_size,
                                    hkv, dh), dtype),
                    "v": jnp.zeros((l, paged.num_pages, paged.page_size,
                                    hkv, dh), dtype),
                    "block_table": jnp.zeros((batch, paged.max_blocks),
                                             jnp.int32),
                    "active": jnp.zeros((batch,), jnp.bool_),
                },
                "pos": jnp.zeros((batch,), jnp.int32),
            }

        def ring(*lead):
            w = int(cfg.local_window if cfg.attention == "local_global"
                    else cfg.window)
            return {
                "k": jnp.zeros((*lead, batch, w, hkv, dh), dtype),
                "v": jnp.zeros((*lead, batch, w, hkv, dh), dtype),
                "slot_pos": jnp.full((*lead, batch, w), -1, jnp.int32),
            }

        if cfg.attention == "full":
            caches = {
                "kind": Static("full"),
                "k": jnp.zeros((l, batch, max_len, hkv, dh), dtype),
                "v": jnp.zeros((l, batch, max_len, hkv, dh), dtype),
            }
        elif cfg.attention == "swa":
            caches = {"kind": Static("swa"), "ring": ring(l)}
        else:  # local_global: periods of (ratio local + 1 global) + tail
            period, n_p, n_tail = self._lg_layout()
            caches = {
                "kind": Static("local_global"),
                "local": ring(n_p, period - 1),
                "tail": ring(max(n_tail, 1)),
                "global_k": jnp.zeros((max(n_p, 1), batch, max_len, hkv, dh),
                                      dtype),
                "global_v": jnp.zeros((max(n_p, 1), batch, max_len, hkv, dh),
                                      dtype),
            }
        return {"caches": caches, "pos": jnp.zeros((batch,), jnp.int32)}

    def _decode_ffn(self, blk, x, policy):
        cfg = self.cfg
        h = apply_rmsnorm(blk["ln2"], x)
        if "moe" in blk:
            h, _ = moe_mod.apply_moe(blk["moe"], h, cfg.moe, policy=policy)
        else:
            h = apply_mlp(blk["mlp"], h, policy=policy)
        return x + h

    def _decode_full_layer(self, blk, x, cache, pos, window, policy):
        cfg = self.cfg
        h = apply_rmsnorm(blk["ln1"], x)
        h, nc = attn.apply_attention_decode(
            blk["attn"], h, cache, pos,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            window=window, policy=policy)
        return self._decode_ffn(blk, x + h, policy), nc

    def _decode_ring_layer(self, blk, x, cache, pos, window, policy):
        h = apply_rmsnorm(blk["ln1"], x)
        h, nc = ring_decode_attention(blk["attn"], h, cache, pos,
                                      cfg=self.cfg, window=window, policy=policy)
        return self._decode_ffn(blk, x + h, policy), nc

    def _decode_paged_layer(self, blk, x, arena_k, arena_v, bt, active, pos,
                            policy):
        cfg = self.cfg
        h = apply_rmsnorm(blk["ln1"], x)
        h, arenas = attn.apply_attention_decode_paged(
            blk["attn"], h, arena_k, arena_v, bt, active, pos,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            window=FULL_WINDOW, policy=policy)
        return self._decode_ffn(blk, x + h, policy), arenas

    def decode_step(self, params, state, tokens, *, policy=None,
                          mode=None, backend=None):
        policy = resolve_policy(policy, mode, backend)
        cfg = self.cfg
        dtype = dtype_of(cfg.compute_dtype)
        x = apply_embedding(params["embed"], tokens).astype(dtype)
        pos = state["pos"]
        caches = state["caches"]
        kind = caches["kind"].value

        if kind == "full":
            def body(x, layer):
                blk, kc, vc = layer
                x, nc = self._decode_full_layer(
                    blk, x, {"k": kc, "v": vc}, pos, FULL_WINDOW,
                    policy)
                return x, (nc["k"], nc["v"])

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["layers"], caches["k"], caches["v"]))
            new_caches = {"kind": Static("full"), "k": ks, "v": vs}

        elif kind == "paged":
            bt, active = caches["block_table"], caches["active"]

            def body(x, layer):
                blk, ak, av = layer
                x, arenas = self._decode_paged_layer(
                    blk, x, ak, av, bt, active, pos, policy)
                return x, arenas

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["layers"], caches["k"], caches["v"]))
            new_caches = {**caches, "k": ks, "v": vs}
            x = apply_rmsnorm(params["final_norm"], x)
            logits = apply_unembedding(params["unembed"], x,
                                       self.cfg.vocab_size)
            # only lanes decoding this tick advance; prefilling/empty slots
            # keep their position (their pages were null-redirected too)
            return logits, {"caches": new_caches,
                            "pos": pos + active.astype(jnp.int32)}

        elif kind == "swa":
            def body(x, layer):
                blk, ring = layer
                x, nc = self._decode_ring_layer(blk, x, ring, pos,
                                                cfg.window, policy)
                return x, nc

            x, rings = jax.lax.scan(body, x, (params["layers"],
                                              caches["ring"]))
            new_caches = {"kind": Static("swa"), "ring": rings}

        else:  # local_global periods + local tail (cond-free)
            period, n_p, n_tail = self._lg_layout()
            stacked = jax.tree.map(
                lambda a: a[:n_p * period].reshape(n_p, period,
                                                   *a.shape[1:]),
                params["layers"])
            tail = jax.tree.map(lambda a: a[n_p * period:], params["layers"])

            def body(x, per):
                blks, local, gk, gv = per
                new_local = []
                for i in range(period - 1):
                    blk = jax.tree.map(lambda a: a[i], blks)
                    ring = jax.tree.map(lambda a: a[i], local)
                    x, nc = self._decode_ring_layer(
                        blk, x, ring, pos, cfg.local_window, policy)
                    new_local.append(nc)
                # the global layer (full cache, unbounded window)
                blk = jax.tree.map(lambda a: a[period - 1], blks)
                x, nc = self._decode_full_layer(
                    blk, x, {"k": gk, "v": gv}, pos, FULL_WINDOW,
                    policy)
                stacked_local = jax.tree.map(lambda *a: jnp.stack(a),
                                             *new_local)
                return x, (stacked_local, nc["k"], nc["v"])

            x, (locals_, gks, gvs) = jax.lax.scan(
                body, x,
                (stacked, caches["local"], caches["global_k"],
                 caches["global_v"]))

            new_tail = []
            for i in range(n_tail):
                blk = jax.tree.map(lambda a: a[i], tail)
                ring = jax.tree.map(lambda a: a[i], caches["tail"])
                x, nc = self._decode_ring_layer(
                    blk, x, ring, pos, cfg.local_window, policy)
                new_tail.append(nc)
            tail_caches = (jax.tree.map(lambda *a: jnp.stack(a), *new_tail)
                           if new_tail else caches["tail"])
            new_caches = {"kind": Static("local_global"), "local": locals_,
                          "tail": tail_caches, "global_k": gks,
                          "global_v": gvs}

        x = apply_rmsnorm(params["final_norm"], x)
        logits = apply_unembedding(params["unembed"], x, self.cfg.vocab_size)
        return logits, {"caches": new_caches, "pos": pos + 1}

    def decode_step_pipelined(self, params, state, tokens, *, policy=None,
                              pp: int = 2, pp_axis: str = "pipe"):
        """Pipeline-parallel :meth:`decode_step` (full-attention caches).

        The layer stack is split into ``pp`` contiguous stage groups sharded
        over ``pp_axis``; the decode batch is split into ``pp`` slot
        microbatches streamed through the GPipe schedule
        (:func:`repro.sharding.pipeline.pipeline_apply_stateful`).  Each
        stage owns the KV caches of its layer group and updates only the
        slot rows of its live microbatch, so the result — logits *and* new
        caches — is bitwise what the sequential scan produces.

        Embedding and the final norm/unembed run replicated outside the
        pipeline.  Requires ``num_layers % pp == 0`` and
        ``batch % pp == 0``; without a matching mesh in the active
        sharding context it falls back to :meth:`decode_step` (identical
        math, no pipelining) so the engine keeps working on one device.
        """
        from repro.sharding import context as shctx
        from repro.sharding.pipeline import pipeline_apply_stateful

        policy = resolve_policy(policy, None, None)
        cfg = self.cfg
        caches = state["caches"]
        if caches["kind"].value != "full":
            raise NotImplementedError(
                "decode_step_pipelined supports the dense full-attention "
                "cache (windowed/paged layouts pipeline their stages with "
                "different per-stage state; DESIGN.md §14)")
        ctx = shctx.get_context()
        mesh = getattr(ctx, "mesh", None)
        if (mesh is None or pp_axis not in mesh.shape
                or mesh.shape[pp_axis] != pp):
            return self.decode_step(params, state, tokens, policy=policy)
        b = tokens.shape[0]
        l = cfg.num_layers
        if l % pp or b % pp:
            raise ValueError(
                f"decode_step_pipelined: num_layers ({l}) and batch ({b}) "
                f"must both divide pp ({pp})")
        l_loc, mb = l // pp, b // pp
        dtype = dtype_of(cfg.compute_dtype)
        pos = state["pos"]
        x = apply_embedding(params["embed"], tokens).astype(dtype)

        def split(a):      # leading dim L -> (pp, L/pp)
            return a.reshape(pp, l_loc, *a.shape[1:])

        stage_params = jax.tree.map(split, params["layers"])
        stage_state = {"k": split(caches["k"]), "v": split(caches["v"])}

        def stage_fn(layers, st, x_mb, pos_mb, mb_idx):
            start = mb_idx * mb

            def body(x, layer):
                blk, kc, vc = layer      # kc: (B, S, Hkv, Dh)
                k_mb = jax.lax.dynamic_slice_in_dim(kc, start, mb, axis=0)
                v_mb = jax.lax.dynamic_slice_in_dim(vc, start, mb, axis=0)
                x, nc = self._decode_full_layer(
                    blk, x, {"k": k_mb, "v": v_mb}, pos_mb, FULL_WINDOW,
                    policy)
                kc = jax.lax.dynamic_update_slice_in_dim(
                    kc, nc["k"], start, axis=0)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    vc, nc["v"], start, axis=0)
                return x, (kc, vc)

            x_mb, (ks, vs) = jax.lax.scan(
                body, x_mb, (layers, st["k"], st["v"]))
            return x_mb, {"k": ks, "v": vs}

        x_mbs = x.reshape(pp, mb, *x.shape[1:])
        pos_mbs = pos.reshape(pp, mb)
        # shard_map makes every mesh axis manual, so the context's
        # activation constraints are illegal inside the stages — suspend it
        # for the pipeline trace (stage math is unaffected)
        with shctx.suspend():
            y, new_stage = pipeline_apply_stateful(
                stage_fn, stage_params, stage_state, x_mbs, mesh,
                axis=pp_axis, aux=pos_mbs)
        x = y.reshape(b, *y.shape[2:])
        new_caches = {
            "kind": Static("full"),
            "k": new_stage["k"].reshape(l, *caches["k"].shape[1:]),
            "v": new_stage["v"].reshape(l, *caches["v"].shape[1:]),
        }
        x = apply_rmsnorm(params["final_norm"], x)
        logits = apply_unembedding(params["unembed"], x, self.cfg.vocab_size)
        return logits, {"caches": new_caches, "pos": pos + 1}

    def prefill_chunk(self, params, state, tokens, slot, n_valid, *,
                      policy=None, mode=None, backend=None):
        """Ingest one K-token chunk of a single sequence into its pages.

        ``tokens`` is a fixed-size ``(K,)`` int32 chunk (padded past
        ``n_valid``); ``slot`` and ``n_valid`` are traced scalars, so one
        compiled program serves every chunk of every request —
        O(prompt_len / K) dispatches instead of O(prompt_len).  Returns the
        logits at the last *valid* position (shape ``(1, 1, V)``) so the
        final chunk yields the first sampled token for free.
        """
        policy = resolve_policy(policy, mode, backend)
        cfg = self.cfg
        caches = state["caches"]
        if caches["kind"].value != "paged":
            raise NotImplementedError(
                "prefill_chunk requires a paged decode state "
                "(init_decode_state(..., paged=PagedLayout))")
        dtype = dtype_of(cfg.compute_dtype)
        slot = jnp.asarray(slot, jnp.int32)
        n_valid = jnp.asarray(n_valid, jnp.int32)
        pos0 = state["pos"][slot]
        row = caches["block_table"][slot]
        x = apply_embedding(params["embed"], tokens[None]).astype(dtype)

        def body(x, layer):
            blk, ak, av = layer
            h = apply_rmsnorm(blk["ln1"], x)
            h, arenas = attn.apply_attention_prefill_paged(
                blk["attn"], h, ak, av, row, pos0, n_valid,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                policy=policy)
            return self._decode_ffn(blk, x + h, policy), arenas

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], caches["k"], caches["v"]))
        x = apply_rmsnorm(params["final_norm"], x)
        last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
        logits = apply_unembedding(params["unembed"], last, cfg.vocab_size)
        return logits, {"caches": {**caches, "k": ks, "v": vs},
                        "pos": state["pos"].at[slot].add(n_valid)}


# ---------------------------------------------------------------------------
# Cross-entropy (vocab-sharded logits friendly)
# ---------------------------------------------------------------------------

def softmax_xent(logits, targets):
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
