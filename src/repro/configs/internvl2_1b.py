"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655.  InternViT frontend + Qwen2-class LM backbone
[arXiv:2404.16821; hf].  Vision frontend is a stub: input_specs feeds
precomputed patch embeddings prepended to the token stream."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2_1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    attention="full",
    frontend="vision",
    num_patches=256,
    subquadratic=False,
)
