"""seamless-m4t-medium [audio]: enc-dec multimodal backbone.
12L enc + 12L dec, d_model=1024, 16H (GQA kv=16), d_ff=4096, vocab=256206.
[arXiv:2308.11596; hf].  Audio frontend is a stub: input_specs feeds
precomputed frame embeddings (DESIGN.md §4)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless_m4t_medium",
    family="audio",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    attention="full",
    frontend="audio",
    subquadratic=False,       # full attention -> long_500k skipped
)
