"""xlstm-125m [ssm]: 12 blocks d_model=768, 4 heads, sLSTM + mLSTM mix,
d_ff=0 (projections live inside the blocks), vocab=50304
[arXiv:2405.04517].  Recurrent -> long_500k RUNS."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm_125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attention="none",
    ssm=SSMConfig(kind="xlstm", state_dim=192, slstm_every=4, chunk=128),
    subquadratic=True,
)
