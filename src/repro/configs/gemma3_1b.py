"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
5:1 local:global attention, 128k context. [hf:google/gemma-3-1b-pt].
long_500k RUNS: local layers bound the KV cache to the window; the global
layers decode O(seq) against a sequence-sharded cache (DESIGN.md §4)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,             # gemma3 uses wide heads
    d_ff=6912,
    vocab_size=262144,
    attention="local_global",
    local_global_ratio=5,     # 5 local : 1 global
    local_window=512,
    rope_theta=1000000.0,
    subquadratic=True,
)
