"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload shape
is a ``ShapeConfig``.  ``(arch, shape, mesh)`` fully determines a dry-run
cell.  Reduced configs for CPU smoke tests are derived with ``reduced()``.
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Optional, Tuple

from repro.core.sparsity import SparsityConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str            # "mamba2" | "xlstm"
    state_dim: int = 64  # mamba2 N / mLSTM key dim basis
    expand: int = 2      # d_inner = expand * d_model (mamba2)
    head_dim: int = 64   # mamba2 head dim
    conv_dim: int = 4    # depthwise conv width
    slstm_every: int = 4  # xlstm: every k-th block is sLSTM (others mLSTM)
    chunk: int = 128     # chunked-scan length (training/prefill)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention structure
    attention: str = "full"          # full | swa | local_global | none
    window: int = 4096
    local_global_ratio: int = 0      # gemma3: 5 (5 local : 1 global)
    local_window: int = 1024
    rope_theta: float = 10000.0
    # encoder-decoder (audio family)
    encoder_layers: int = 0
    encoder_seq_divisor: int = 4     # frames = seq_len // divisor
    # multimodal stub frontends
    frontend: Optional[str] = None   # "audio" | "vision"
    num_patches: int = 256           # vision stub prefix length
    # MoE / SSM / hybrid structure
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0       # zamba2: shared attn block cadence
    # whether long_500k decode applies (sub-quadratic path exists)
    subquadratic: bool = False
    # the paper's technique: relaxed N:M sparsity on weight matrices
    sparsity: Optional[SparsityConfig] = SparsityConfig(8, 128, 1)
    sparse_scope: Tuple[str, ...] = ("mlp", "attn_qkv", "attn_o")
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so embedding/logit tables shard over TP=16
        (padded logit columns are masked to -inf in the loss/decode)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count N for MODEL_FLOPS = 6·N·D."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qkv = d * hd * (self.num_heads + 2 * self.num_kv_heads) + \
            self.num_heads * hd * d
        mlp = 3 * d * f if f else 0
        per_layer = qkv + mlp
        if self.moe:
            per_layer = qkv + self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        if self.ssm and self.ssm.kind == "mamba2":
            di = self.ssm.expand * d
            per_layer = 2 * d * di + di * self.ssm.state_dim * 2 + di * d
        if self.ssm and self.ssm.kind == "xlstm":
            per_layer = 4 * d * 2 * d + 2 * d * d  # proj up/gates/down approx
        total = self.num_layers * per_layer + 2 * v * d
        total += self.encoder_layers * (qkv + mlp)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense_total = self.param_count()
        all_experts = self.num_layers * self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        active = self.num_layers * self.moe.experts_per_token * 3 * d * self.moe.d_ff_expert
        return int(dense_total - all_experts + active)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            num_layers=min(self.num_layers, 2 if not self.shared_attn_every
                           else self.shared_attn_every + 1),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads > 1 else 1,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            window=min(self.window, 64),
            local_window=32,
            encoder_layers=min(self.encoder_layers, 2),
            num_patches=8,
            sparsity=SparsityConfig(2, 16, 1) if self.sparsity else None,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, experts_per_token=min(
                    self.moe.experts_per_token, 2), d_ff_expert=64)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk=16,
                slstm_every=self.ssm.slstm_every)
            if self.ssm.kind == "xlstm":
                # layer count must stay a multiple of the sLSTM period
                changes["num_layers"] = self.ssm.slstm_every
        if self.shared_attn_every:
            changes["shared_attn_every"] = 2
            changes["num_layers"] = 5   # 2 periods + 1 tail layer
        if self.attention == "local_global":
            changes["local_global_ratio"] = 2
            changes["num_layers"] = 7   # 2 periods + 1 tail layer
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "seamless_m4t_medium",
    "gemma3_1b",
    "internlm2_20b",
    "stablelm_3b",
    "h2o_danube_1_8b",
    "olmoe_1b_7b",
    "llama4_scout_17b_a16e",
    "internvl2_1b",
    "zamba2_7b",
    "xlstm_125m",
]


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The brief's skip rules: long_500k only for sub-quadratic archs."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        shapes.append("long_500k")
    return shapes


def choose_group(k_local: int, target_density: float = 1.0 / 16.0,
                 preferred_m: int = 128) -> SparsityConfig:
    """Pick the largest group size M <= preferred_m dividing ``k_local`` such
    that N = M * density is a positive integer (DESIGN.md §4: TP-sharded
    contraction dims need group boundaries aligned to shard boundaries)."""
    for m in range(min(preferred_m, k_local), 0, -1):
        n = m * target_density
        if k_local % m == 0 and abs(n - round(n)) < 1e-9 and round(n) >= 1:
            return SparsityConfig(int(round(n)), m, 1)
    return SparsityConfig(1, 1, 1)  # degenerate: dense
