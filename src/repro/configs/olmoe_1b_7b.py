"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff(expert)=1024,
vocab=50304, MoE 64 experts top-8 [arXiv:2409.02060; hf]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe_1b_7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,                  # all FFN capacity lives in the experts
    vocab_size=50304,
    attention="full",
    moe=MoEConfig(num_experts=64, experts_per_token=8, d_ff_expert=1024),
    subquadratic=False,
)
