"""zamba2-7b [hybrid]: 81L d_model=3584, Mamba2 backbone (ssm_state=64) with
a SHARED attention+MLP block applied periodically (32H kv=32, d_ff=14336)
[arXiv:2411.15242].  Sub-quadratic -> long_500k RUNS."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2_7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    attention="full",          # the shared block's attention
    ssm=SSMConfig(kind="mamba2", state_dim=64, expand=2, head_dim=64),
    shared_attn_every=6,       # shared block every 6 mamba layers
    subquadratic=True,
)
