"""Replay-safe sampling: counter-based RNG keyed on (request, position).

The paged scheduler's preemption story (DESIGN.md §13) requires that a
preempt → re-prefill → resume cycle replays the *identical* token stream.
Greedy decode gets that for free; stochastic sampling needs the randomness
itself to be a pure function of where in which request it is drawn, not of
how many draws happened before it.  Stateful PRNG streams (split-per-step
jax keys, a shared generator) break on resume; a **counter-based** generator
keyed on ``(seed, request_id, position)`` does not — numpy's Philox is
exactly that (its stream is specified and stable across platforms and
versions), so the noise for token position ``p`` of request ``r`` is the
same no matter when, where, or how many times it is drawn.

Sampling itself is **Gumbel-max coupled**: the committed token at position
``p`` is ``argmax(logits/T + g)`` over the top-k mask, with ``g`` the
position-keyed Gumbel noise.  That is an exact draw from the
temperature/top-k distribution *and* a deterministic function of
``(logits, seed, rid, p)`` — which buys two guarantees at once:

* **replay safety** — resume recomputes the same full-tier logits (greedy
  prefill is deterministic) and the same noise, hence the same token;
* **speculative acceptance** (``repro.spec.decode``) — the draft tier
  proposes with the *same* key on its draft logits, and verification
  accepts iff the proposal equals the full-tier coupled sample.  The
  committed stream is therefore token-identical to the non-speculative
  sampled stream by construction (classical stochastic rejection sampling
  cannot make that bit-exact promise under preemption, because the draft
  distribution depends on how the speculation windows happen to align).

At ``temperature == 0`` every path degenerates to argmax, so speculative
and non-speculative greedy are trivially token-identical.
"""

from __future__ import annotations

import dataclasses

import numpy as np

_EPS = 1e-12


def position_noise(seed: int, rid: int, pos: int, n: int) -> np.ndarray:
    """Gumbel(0, 1) noise of shape ``(n,)`` for token position ``pos`` of
    request ``rid`` — a pure function of ``(seed, rid, pos)``.

    Philox is counter-based: the 2-word key carries (seed, rid), the
    128-bit counter carries the position, so no sequential stream state
    exists to lose on preemption."""
    bits = np.random.Philox(counter=[np.uint64(pos), 0, 0, 0],
                            key=[np.uint64(seed & 0xFFFFFFFFFFFFFFFF),
                                 np.uint64(rid & 0xFFFFFFFFFFFFFFFF)])
    u = np.random.Generator(bits).random(n)
    return -np.log(-np.log(u + _EPS) + _EPS)


@dataclasses.dataclass(frozen=True)
class ReplaySafeSampler:
    """Temperature / top-k token sampler with the replay contract above.

    ``sample(logits_row, rid, pos)`` returns the committed token for
    sequence position ``pos`` (the 0-based index the token occupies in
    prompt+output order) of request ``rid``.  ``temperature == 0`` is
    greedy argmax (``top_k`` ignored).
    """

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = full vocab), got "
                             f"{self.top_k}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    def sample(self, logits_row: np.ndarray, rid: int, pos: int) -> int:
        z = np.asarray(logits_row, np.float64)
        if self.greedy:
            return int(np.argmax(z))
        z = z / self.temperature
        if 0 < self.top_k < z.shape[-1]:
            # deterministic top-k: stable sort breaks value ties by index
            keep = np.argsort(-z, kind="stable")[: self.top_k]
            masked = np.full_like(z, -np.inf)
            masked[keep] = z[keep]
            z = masked
        g = position_noise(self.seed, rid, pos, z.shape[-1])
        return int(np.argmax(z + g))
