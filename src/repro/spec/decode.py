"""Draft → verify decode: the speculative window over one packed tree.

One speculation window per engine tick (both engines share this module):

1. **draft** — γ sequential decode steps run with the *draft-tier* params
   view (``spec.tiers.derive_draft_tier``; same buffers, narrower address
   stream), each proposing the next token with the replay-safe coupled
   sampler.  Draft steps write draft-quality KV into the shared cache —
   deliberately: there is no second decode state, no draft re-prefill
   after preemption, zero extra KV memory.
2. **verify** — ONE batched full-tier dispatch re-feeds the whole window
   (:func:`make_multistep`: a ``lax.scan`` over the γ+1 token columns
   inside a single jitted program), rewriting every window position's KV
   with full-tier values and producing exact logits for each.
3. **accept** — per lane, the committed tokens are the full-tier coupled
   samples; a drafted token survives iff it equals that sample, and the
   first mismatch truncates the window (the mismatching position still
   commits its full-tier token, so every window commits ≥ 1 token and an
   all-accepted window commits γ+1 — the bonus token).  The engine then
   rolls each lane's ``pos`` back to its last *valid* input, so stale
   draft KV beyond it is invisible (attention masks by ``pos``) and is
   overwritten by the next window.

Because the committed token at every position is exactly what the
non-speculative engine would emit (greedy argmax at temperature 0, the
Gumbel-max coupled sample otherwise), speculation changes dispatch count
and latency — never the token stream.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs.

    * ``draft`` — the sparser tier pattern, e.g. ``"8:128"`` to draft at
      8:128 from a tree packed at 16:128 (k-reconfigured).  Every packed
      node sharing the pattern's M and denser than its N drafts at the
      tier; the rest fall back to the full tier.
    * ``gamma`` — tokens drafted per window; a window verifies γ+1
      positions in one full-tier dispatch.
    """

    draft: str = "8:128"
    gamma: int = 4

    def __post_init__(self):
        from repro.spec.tiers import parse_tier

        parse_tier(self.draft)          # validate eagerly
        if self.gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {self.gamma}")


def guard_cache_kinds(state, allowed=("full", "paged")):
    """Refuse decode states speculation cannot roll back.

    The accept step undoes rejected draft writes by resetting ``pos``:
    that only works when history is *position-addressable* — full and paged
    attention caches mask reads by ``pos`` and rewrite any position.  Ring
    buffers (swa / local_global) lose the entries a rejected write
    overwrote, and O(1) recurrent states (SSM / mLSTM) fold every input in
    irreversibly; both would silently diverge from the non-speculative
    stream.  Walks the state pytree's ``{"kind": Static(...)}`` cache tags.
    """
    kinds = set()

    def walk(x):
        if isinstance(x, dict):
            k = x.get("kind")
            if hasattr(k, "value"):
                kinds.add(k.value)
            for v in x.values():
                walk(v)
        elif isinstance(x, (list, tuple)):
            for v in x:
                walk(v)

    walk(state)
    bad = sorted(kinds - set(allowed))
    if bad or not kinds:
        raise NotImplementedError(
            f"speculative decoding requires position-addressable KV caches "
            f"(kinds {sorted(allowed)}); this decode state has "
            f"{bad or 'no tagged caches'} — ring buffers and recurrent "
            f"states cannot roll back rejected draft writes")
    return kinds


def make_multistep(model, policy):
    """The batched verify program: ``(params, state, tokens (B, W)) ->
    (logits (B, W, V), state)`` — W decode steps fused into one jitted
    dispatch via ``lax.scan`` over the token columns.

    Built on ``model.decode_step``, so every cache kind the engines serve
    (dense, ring, paged — with its active-mask/null-page redirection)
    verifies through its ordinary decode path; ``W`` is only a trace-time
    shape, so one program handles every window width the engine clamps to.
    """

    def multistep(params, state, tokens):
        def body(st, tok_col):
            logits, st = model.decode_step(params, st, tok_col[:, None],
                                           policy=policy)
            return st, logits[:, 0]

        state_out, logits = jax.lax.scan(body, state,
                                         jnp.swapaxes(tokens, 0, 1))
        return jnp.swapaxes(logits, 0, 1), state_out

    return jax.jit(multistep)


class SpecMetrics:
    """The obs families of the speculative decoder (DESIGN.md §15)."""

    def __init__(self, registry):
        m = registry
        self.drafted = m.counter(
            "spec_draft_tokens_total",
            help="draft-tier proposals fed to verification")
        self.accepted = m.counter(
            "spec_accepted_tokens_total",
            help="drafted tokens that matched the full-tier sample")
        self.rejected = m.counter(
            "spec_rejected_tokens_total",
            help="drafted tokens replaced by the full-tier sample")
        self.acceptance = m.histogram(
            "spec_acceptance_ratio",
            help="per-window accepted/drafted ratio",
            buckets=(0.1, 0.25, 0.5, 0.75, 0.9, 1.0))
        self.tokens_per_dispatch = m.gauge(
            "spec_tokens_per_dispatch",
            help="committed tokens per full-tier (verify) dispatch, "
                 "running mean")
        # goodput accounting (repro.obs.slo): every drafted-but-uncommitted
        # proposal is draft-tier work thrown away.  Distinct from
        # spec_rejected_tokens_total, which counts only *examined* drafts —
        # drafts past a window truncation point are wasted too.
        self.wasted = m.counter(
            "serve_wasted_tokens_total",
            help="tokens of work the engine re-did or discarded, by cause",
            cause="spec_reject")
        self._committed_total = 0
        self._verify_dispatches = 0

    def observe_wasted(self, n: int):
        """Account ``n`` draft proposals discarded without commit."""
        self.wasted.inc(n)

    def observe_window(self, drafted: int, accepted: int, committed: int):
        """Account one speculation window (one verify dispatch)."""
        self.drafted.inc(drafted)
        self.accepted.inc(accepted)
        self.rejected.inc(drafted - accepted)
        if drafted:
            self.acceptance.observe(accepted / drafted)
        self._committed_total += committed
        self._verify_dispatches += 1
        self.tokens_per_dispatch.set(
            self._committed_total / self._verify_dispatches)
