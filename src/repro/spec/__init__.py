"""``repro.spec`` — self-speculative decoding from one packed tree.

The uniquely-DeMM speculation trick: the draft model is the *same*
:class:`~repro.core.sparsity.PackedWeight` buffers read at a sparser
density tier (``tier_ne`` narrows the per-group address stream at trace
time — no weight copy), the verifier is the full k-reconfigured tier, and
a replay-safe coupled sampler makes the committed stream token-identical
to non-speculative decoding at any temperature.  Enabled through
``serve.make_engine(..., spec=SpecConfig(...))`` or
``launch/serve.py --spec-draft N:M --spec-gamma G``.

* :mod:`repro.spec.tiers`    — draft-tier derivation (buffer-aliasing view)
* :mod:`repro.spec.sampling` — counter-based (request, position)-keyed RNG
* :mod:`repro.spec.decode`   — draft→verify window, batched verify program
"""

from repro.spec.decode import SpecConfig, SpecMetrics, make_multistep
from repro.spec.sampling import ReplaySafeSampler, position_noise
from repro.spec.tiers import (
    TierReport,
    derive_draft_tier,
    parse_tier,
    tier_sort_tree,
)

__all__ = [
    "SpecConfig", "SpecMetrics", "ReplaySafeSampler", "TierReport",
    "derive_draft_tier", "make_multistep", "parse_tier", "position_noise",
    "tier_sort_tree",
]
