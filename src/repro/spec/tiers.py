"""Draft-tier derivation: a sparser view of a packed tree, zero weight copy.

The inverse of the paper's §II-B k-reconfiguration.  ``reconfigure_k`` lets
a DeMM(N, M, C, k) engine serve the *denser* kN:M pattern in k passes over
one stored ``{value, col_idx}`` stream; a **draft tier** reads the *same*
stream at a sparser pattern by consuming only the first ``tier_ne`` pairs
per group.  Because ``tier_ne`` is static aux on
:class:`~repro.core.sparsity.PackedWeight` (the traced children are
untouched), the draft params tree aliases the full tier's buffers —
``draft.values is full.values`` — and the narrowing happens at trace time
inside kernel dispatch (``kernels/ops.demm_matmul_packed``).  One weight
buffer, two density tiers: the self-speculative serving trick that fixed
fine-grained engines (S2TA, FlexSA) cannot express.

The prefix-read is exact magnitude pruning only if each group's pairs are
ordered magnitude-descending; :func:`tier_sort_tree` establishes that
invariant once per tree (full-tier compute is order-independent — both the
one-hot scatter reference and the kernels' gather-accumulate sum over the
Ne axis — so sorting never changes what the full tier computes).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

from repro.core.sparsity import PackedWeight, tier_sort_packed


def parse_tier(spec: str) -> Tuple[int, int]:
    """``"8:128"`` -> ``(8, 128)`` — the draft pattern N:M."""
    try:
        n_s, m_s = spec.split(":")
        n, m = int(n_s), int(m_s)
    except ValueError:
        raise ValueError(
            f"draft tier must be 'N:M' (e.g. '8:128'), got {spec!r}")
    if n < 1 or m < 1 or n > m:
        raise ValueError(f"draft tier {spec!r}: need 1 <= N <= M")
    return n, m


def _is_pw(x) -> bool:
    return isinstance(x, PackedWeight)


def tier_sort_tree(params):
    """Reorder every PackedWeight's per-group pairs magnitude-descending
    (see :func:`~repro.core.sparsity.tier_sort_packed`).  Idempotent."""
    return jax.tree.map(
        lambda x: tier_sort_packed(x) if _is_pw(x) else x,
        params, is_leaf=_is_pw)


@dataclasses.dataclass(frozen=True)
class TierReport:
    """What the derivation pass did to a packed tree."""

    narrowed: int = 0        # nodes retagged to the draft tier
    full: int = 0            # k-reconfigurable nodes left at the full tier
    other: int = 0           # non-PackedWeight-matmul leaves (untouched)

    def __str__(self):
        return (f"{self.narrowed} node(s) at the draft tier, "
                f"{self.full} at the full tier, {self.other} dense")


def derive_draft_tier(params, draft: str):
    """Walk a packed tree and produce the draft-tier view.

    Every k-reconfigurable :class:`PackedWeight` — one whose group size
    matches the draft pattern's M and whose ``n_effective`` exceeds the
    draft N — is retagged with ``tier_ne=N`` (a static-aux change only: the
    returned tree's values/indices ARE the input tree's arrays).  Nodes the
    draft pattern cannot narrow (different M, already at or below the draft
    density, or plain dense arrays) fall back to the full tier unchanged.

    Returns ``(draft_params, TierReport)``.  Raises if the pattern narrows
    nothing — a draft identical to the full tier would verify itself.
    """
    n, m = parse_tier(draft)
    counts = {"narrowed": 0, "full": 0, "other": 0}

    def one(x):
        if not _is_pw(x):
            counts["other"] += 1
            return x
        if x.cfg.m == m and x.cfg.n_effective > n:
            counts["narrowed"] += 1
            return x.replace(tier_ne=n)
        counts["full"] += 1
        return x

    out = jax.tree.map(one, params, is_leaf=_is_pw)
    report = TierReport(**counts)
    if report.narrowed == 0:
        raise ValueError(
            f"draft tier {draft!r} narrows no PackedWeight in this tree "
            f"({report}); the packed pattern must share M with the draft "
            f"and be denser than it (pack with e.g. --sparsity "
            f"{2 * n}:{m})")
    return out, report
