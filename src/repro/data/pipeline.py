"""Deterministic synthetic-token data pipeline with O(1) skip-ahead.

Every batch is a pure function of (seed, step), so fault-tolerant resume is
exact: restoring a checkpoint at step S and continuing produces bitwise the
same training trajectory as an uninterrupted run (tests/test_fault_tolerance
asserts this).  Hosts slice their local shard of the global batch by index,
so no data is exchanged between hosts.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    # noisy-Markov stream: next = (a·prev + c) mod V with prob (1-noise),
    # uniform otherwise — learnable structure (cross-entropy floor well
    # below ln V) while staying a pure function of (seed, step).
    structured: bool = True
    noise: float = 0.2


def _philox(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=[cfg.seed, step]))


def global_batch(cfg: DataConfig, step: int) -> dict:
    """The full (global_batch, seq) batch for a step — deterministic."""
    rng = _philox(cfg, step)
    shape = (cfg.global_batch, cfg.seq_len + 1)
    if not cfg.structured:
        toks = rng.integers(0, cfg.vocab_size, shape, dtype=np.int32)
    else:
        v = cfg.vocab_size
        a, c = 6364136223846793005 % v or 1, 1442695040888963407 % v
        toks = np.empty(shape, np.int32)
        toks[:, 0] = rng.integers(0, v, cfg.global_batch)
        noise = rng.random(shape) < cfg.noise
        rand = rng.integers(0, v, shape, dtype=np.int32)
        for t in range(1, shape[1]):
            nxt = (toks[:, t - 1].astype(np.int64) * a + c) % v
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def host_batch(cfg: DataConfig, step: int, host_index: int,
               host_count: int) -> dict:
    """This host's slice (contiguous rows) of the step's global batch."""
    assert cfg.global_batch % host_count == 0
    per = cfg.global_batch // host_count
    full = global_batch(cfg, step)
    sl = slice(host_index * per, (host_index + 1) * per)
    return {k: v[sl] for k, v in full.items()}


class DataIterator:
    """Stateful view with skip-ahead — the supervisor resumes by seeking."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def seek(self, step: int):
        self.step = step

    def __next__(self):
        b = global_batch(self.cfg, self.step)
        self.step += 1
        return b

    def __iter__(self):
        return self
