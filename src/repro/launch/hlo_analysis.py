"""Loop-exact HLO cost analysis.

``compiled.cost_analysis()`` counts every computation ONCE — while-loop
(scan) bodies are not multiplied by their trip counts, which under-counts
layer-scanned models by orders of magnitude.  This module re-derives the
three roofline inputs by walking the (SPMD-partitioned, per-device) HLO text
with execution-count weighting:

  * ``while`` ops multiply their body/condition by ``known_trip_count``
    (XLA annotates every scan-derived loop; unknown counts default to 1 and
    are reported in ``unknown_trip_loops``);
  * fusion / call computations inherit their caller's multiplier;
  * conditional branches are weighted 1/num_branches (the models avoid
    lax.cond on hot paths, so this only affects glue code);
  * FLOPs: ``dot`` ops contribute 2 · |result| · |contracting dims| using a
    module-wide symbol table for operand shapes; fusions contribute
    |result| as an elementwise estimate;
  * bytes: operand+result sizes of top-level (non-fused) ops, mirroring
    XLA's bytes-accessed convention (per-device, post-SPMD shapes);
  * collectives: result payload per kind; all-reduce weighted 2× (ring
    reduce-scatter + all-gather phases).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)"
    r"\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CONTRACT_RE = re.compile(r"rhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shapes_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        dims = m.group(2)
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _first_shape_elems(text: str) -> Optional[tuple]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_text: str       # lhs type text (may be a tuple type)
    operands: List[str]    # operand op names
    line: str
    called: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]


def parse_module(hlo: str):
    comps: Dict[str, Computation] = {}
    symtab: Dict[str, str] = {}     # op/param name -> result type text
    entry = None
    current = None
    for raw in hlo.splitlines():
        ls = raw.strip()
        if not ls or ls.startswith("//"):
            continue
        # computation header: "[ENTRY] %name (params...) -> type {"
        if ls.endswith("{") and "->" in ls and " = " not in ls:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(", ls)
            if m:
                current = Computation(m.group(2), [])
                comps[current.name] = current
                if m.group(1):
                    entry = current.name
                # parameters: "name: type" pairs inside the header parens
                header = ls[:ls.rfind("->")]
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*([\w\[\],]+)",
                                      header):
                    symtab[pm.group(1)] = pm.group(2)
                continue
        if current is None:
            continue
        m = _OP_RE.match(ls)
        if not m:
            continue
        name, result_text, opcode = m.groups()
        # operand names: inside the opcode's parens (names only, no shapes)
        after = ls.split(opcode + "(", 1)
        operand_text = after[1].split(")", 1)[0] if len(after) == 2 else ""
        operands = _OPERAND_RE.findall(operand_text)
        called = _CALLED_RE.findall(ls) + _COND_RE.findall(ls)
        mb = _BRANCHES_RE.search(ls)
        if mb:
            called += [c.strip().lstrip("%") for c in mb.group(1).split(",")]
        op = Op(name=name, opcode=opcode, result_text=result_text,
                operands=operands, line=ls, called=called)
        comps[current.name].ops.append(op)
        symtab[name] = result_text
    return comps, symtab, entry


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {k: {"count": 0.0, "bytes": 0.0}
                                 for k in _COLL_KINDS})
    unknown_trip_loops: int = 0
    dot_flops_by_name: dict = dataclasses.field(default_factory=dict)
    bytes_by_opcode: dict = dataclasses.field(default_factory=dict)
    collectives_by_name: dict = dataclasses.field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())

    def to_dict(self):
        d = {k: {"count": v["count"], "bytes": v["bytes"]}
             for k, v in self.collectives.items()}
        d["total_bytes"] = self.collective_bytes
        return {"flops": self.flops, "bytes_accessed": self.bytes_accessed,
                "collectives": d,
                "unknown_trip_loops": self.unknown_trip_loops}


# ops whose operand/result bytes approximate real HBM traffic at top level
_SKIP_BYTES_OPCODES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id",
}


def _dot_flops(op: Op, symtab) -> float:
    res = _first_shape_elems(op.result_text)
    if res is None:
        return 0.0
    out_elems = 1
    for d in res:
        out_elems *= d
    mc = _CONTRACT_RE.search(op.line)
    contract = 1
    if mc and len(op.operands) >= 2:
        rhs_type = symtab.get(op.operands[1], "")
        rdims = _first_shape_elems(rhs_type)
        if rdims:
            for ci in mc.group(1).split(","):
                if ci != "" and int(ci) < len(rdims):
                    contract *= rdims[int(ci)]
    return 2.0 * out_elems * contract


def analyze(hlo: str) -> Analysis:
    comps, symtab, entry = parse_module(hlo)
    if entry is None:
        entry = next(iter(comps)) if comps else None
    out = Analysis()
    if entry is None:
        return out

    def _inner_dus_update_bytes(comp_name: str) -> Optional[int]:
        """Bytes of the update operand of a dynamic-update-slice inside a
        fusion computation (DUS is in-place: traffic = slice, not buffer)."""
        comp = comps.get(comp_name)
        if comp is None:
            return None
        for op in comp.ops:
            if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
                t = symtab.get(op.operands[1])
                if t:
                    return _shapes_bytes(t)
        return None

    def op_bytes(op: Op) -> float:
        """TPU-flavored traffic estimate (see module docstring):

        * dynamic-(update-)slice: 2× the slice (in-place aliasing);
        * elementwise/loop fusions: result only — on TPU these chains fuse
          with their producers, so operand re-reads are register traffic
          (the CPU backend's finer fusion boundaries would otherwise
          inflate the estimate ~5-10x);
        * dots, custom-calls, copies, collectives: operands + result
          (MXU/DMA genuinely stream them from HBM).
        """
        if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
            t = symtab.get(op.operands[1])
            if t:
                return 2.0 * _shapes_bytes(t)
        if op.opcode == "dynamic-slice":
            return 2.0 * _shapes_bytes(op.result_text)
        if op.opcode == "fusion":
            if "dynamic-update-slice" in op.line:
                for c in op.called:
                    ub = _inner_dus_update_bytes(c)
                    if ub is not None:
                        return 2.0 * ub
            return float(_shapes_bytes(op.result_text))
        total = _shapes_bytes(op.result_text)
        for o in op.operands:
            t = symtab.get(o)
            if t:
                total += _shapes_bytes(t)
        return float(total)

    def walk(comp_name: str, mult: float, in_fusion: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                mt = _TRIP_RE.search(op.line)
                trip = float(mt.group(1)) if mt else 1.0
                if mt is None:
                    out.unknown_trip_loops += 1
                for c in op.called:
                    walk(c, mult * trip, in_fusion)
                continue
            if oc == "conditional":
                branches = op.called
                w = mult / max(len(branches), 1)
                for c in branches:
                    walk(c, w, in_fusion)
                continue
            if oc == "dot":
                f = mult * _dot_flops(op, symtab)
                out.flops += f
                mo = re.search(r'op_name="([^"]+)"', op.line)
                key = mo.group(1) if mo else op.name.split(".")[0]
                # compress jit scope prefixes: keep the last two scope parts
                key = "/".join(key.split("/")[-2:])
                out.dot_flops_by_name[key] = \
                    out.dot_flops_by_name.get(key, 0.0) + f
            elif oc == "fusion" and not in_fusion:
                res = _first_shape_elems(op.result_text)
                if res:
                    n = 1
                    for d in res:
                        n *= d
                    out.flops += mult * n  # elementwise estimate
            if oc in ("fusion", "call", "map", "reduce", "reduce-window",
                      "scatter", "sort", "select-and-scatter"):
                for c in op.called:
                    walk(c, mult, True)
            if oc in _COLL_KINDS and not in_fusion:
                nbytes = _shapes_bytes(op.result_text)
                w = 2 if oc == "all-reduce" else 1
                out.collectives[oc]["count"] += mult
                out.collectives[oc]["bytes"] += mult * nbytes * w
                mo = re.search(r'op_name="([^"]+)"', op.line)
                key = oc + ":" + "/".join(
                    (mo.group(1) if mo else op.name).split("/")[-2:])[-70:]
                e = out.collectives_by_name.setdefault(
                    key, {"count": 0.0, "bytes": 0.0})
                e["count"] += mult
                e["bytes"] += mult * nbytes * w
            if not in_fusion and oc not in _SKIP_BYTES_OPCODES:
                nb = mult * op_bytes(op)
                out.bytes_accessed += nb
                out.bytes_by_opcode[oc] = out.bytes_by_opcode.get(oc, 0.0) + nb

    walk(entry, 1.0, False)
    return out
