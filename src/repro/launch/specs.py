"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

No device allocation: params, optimizer state, decode states, and batches
are all ``jax.eval_shape`` / ``ShapeDtypeStruct`` stand-ins (weak-type
correct, shardable).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    gb, t = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "vision":
        t_text = t - cfg.num_patches
        return {
            "tokens": sds((gb, t_text), jnp.int32),
            "targets": sds((gb, t_text), jnp.int32),
            "patch_embeds": sds((gb, cfg.num_patches, cfg.d_model),
                                jnp.float32),
        }
    batch = {
        "tokens": sds((gb, t), jnp.int32),
        "targets": sds((gb, t), jnp.int32),
    }
    if cfg.family == "audio":
        batch["frames"] = sds((gb, t // cfg.encoder_seq_divisor, cfg.d_model),
                              jnp.float32)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    b = train_batch_specs(cfg, shape)
    b.pop("targets", None)
    return b


def decode_token_specs(shape: ShapeConfig):
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


def param_shapes(model) -> dict:
    return jax.eval_shape(model.init,
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def decode_state_shapes(model, shape: ShapeConfig) -> dict:
    return jax.eval_shape(
        lambda: model.init_decode_state(shape.global_batch, shape.seq_len))
