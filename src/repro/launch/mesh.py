"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; smoke tests and benchmarks see the default single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for subprocess unit tests (8 host devices)."""
    return jax.make_mesh(shape, axes)
