"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3_1b \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

On the CPU container this runs REDUCED configs on a single device (the
multi-device production mesh is exercised by the dry-run); on a real TPU
fleet the same driver runs full configs by dropping --reduced and letting
``--mesh`` pick the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_arch
from repro.data.pipeline import DataConfig, global_batch
from repro.core.sparse_linear import ExecPolicy
from repro.models.families import build_model
from repro.optim import adamw
from repro.train.fault_tolerance import SupervisorConfig, TrainingSupervisor
from repro.train.train_loop import make_train_step


def add_frontend_inputs(cfg, batch, rng):
    if cfg.frontend == "vision":
        b, t = batch["tokens"].shape
        batch["patch_embeds"] = rng.standard_normal(
            (b, cfg.num_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        b, t = batch["tokens"].shape
        batch["frames"] = rng.standard_normal(
            (b, t // cfg.encoder_seq_divisor, cfg.d_model)).astype(np.float32)
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm_3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", choices=["topk", "int8"], default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params)
                   if hasattr(x, "size"))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"sparsity={cfg.sparsity.pattern_name() if cfg.sparsity else None}")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 5),
                                compression=args.compression)
    opt_state = adamw.init(opt_cfg, params)
    step_fn = jax.jit(make_train_step(
        model, opt_cfg, num_microbatches=args.microbatches,
        policy=ExecPolicy(mode="masked")))

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    rng = np.random.default_rng(0)
    sup = TrainingSupervisor(
        SupervisorConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        step_fn, data_cfg,
        to_batch=lambda b: add_frontend_inputs(cfg, b, rng))

    t0 = time.time()
    losses = []

    orig_step = sup.train_step

    def logging_step(p, o, b, s):
        p, o, m = orig_step(p, o, b, s)
        losses.append(float(m["loss"]))
        if s % args.log_every == 0:
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time()-t0):.1f}s)")
        return p, o, m

    sup.train_step = logging_step
    params, opt_state, metrics, restarts = sup.run(params, opt_state,
                                                   args.steps)
    print(f"done: final loss {losses[-1]:.4f} (first {losses[0]:.4f}), "
          f"restarts={restarts}")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
