"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3_1b \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

Sparsity-aware training (``repro.sparsetrain``)::

    PYTHONPATH=src python -m repro.launch.train --sparsify 8:128 --qat int8

``--sparsify`` drives a gradual magnitude-pruning schedule (default
3-phase anneal dense → N:2M → N:M; explicit phases via
``dense@0,8:256@50,8:128@150``) whose mask state rides every checkpoint;
``--qat int8`` adds straight-through fake quantization on the serving int8
grid.  The final checkpoint has the masks baked in (weights satisfy their
N:M patterns exactly), so it packs + serves directly::

    PYTHONPATH=src python -m repro.launch.serve --ckpt-dir /tmp/repro_ckpt \
        --packed --quantize int8 --backend auto

On the CPU container this runs REDUCED configs on a single device (the
default when no ``--full`` is given off-TPU; the multi-device production
mesh is exercised by the dry-run); on a real TPU fleet the same driver runs
full configs with ``--full`` and lets ``--mesh`` pick the production mesh.

``--metrics-out m.json`` writes the ``repro.obs`` metrics snapshot after
training (step-time and checkpoint-duration histograms, restart/failure
counters, kernel-dispatch counters; DESIGN.md §12).  Step logs go through
the structured logger — ``REPRO_LOG_JSON=1`` switches them to JSON lines.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ARCH_IDS, get_arch
from repro.data.pipeline import DataConfig, global_batch
from repro.core.sparse_linear import ExecPolicy
from repro.models.families import build_model
from repro.optim import adamw
from repro.train.fault_tolerance import SupervisorConfig, TrainingSupervisor
from repro.train.train_loop import make_train_step


def add_frontend_inputs(cfg, batch, rng):
    if cfg.frontend == "vision":
        b, t = batch["tokens"].shape
        batch["patch_embeds"] = rng.standard_normal(
            (b, cfg.num_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        b, t = batch["tokens"].shape
        batch["frames"] = rng.standard_normal(
            (b, t // cfg.encoder_seq_divisor, cfg.d_model)).astype(np.float32)
    return batch


def verify_final_masks(params) -> int:
    """Assert every sparse linear satisfies its stored N:M pattern exactly
    (call after ``SparseTrainer.finalize``).  Returns the node count."""
    from repro.core.sparsity import satisfies_pattern
    from repro.sparsetrain.masks import map_sparse_nodes

    def check(node, cfg):
        w = node["w"]
        flat = w.reshape(-1, w.shape[-1])
        assert bool(satisfies_pattern(flat, cfg)), (
            f"final mask violates {cfg.pattern_name()}")
        return True

    return sum(x is True for x in
               jax.tree.leaves(map_sparse_nodes(params, check)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm_3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (the default off-TPU)")
    ap.add_argument("--full", action="store_true",
                    help="force the full config even on CPU")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", choices=["topk", "int8"], default=None)
    ap.add_argument("--log-every", type=int, default=10)
    # --- sparsity-aware training (repro.sparsetrain) ---
    ap.add_argument("--sparsify", default=None, metavar="SCHEDULE",
                    help="gradual N:M sparsification: a target pattern "
                         "('8:128', '8:128:2') for the default dense → "
                         "N:2M → N:M anneal, or explicit phases "
                         "('dense@0,8:256@50,8:128@150')")
    ap.add_argument("--sparsify-update-every", type=int, default=25,
                    help="within-phase magnitude-mask refresh cadence")
    ap.add_argument("--sparsify-freeze-after", type=int, default=None,
                    help="stop mask refreshes from this step on (default: "
                         "90%% of --steps, so the final support settles "
                         "before baking)")
    ap.add_argument("--qat", choices=("int8",), default=None,
                    help="straight-through fake quantization on the int8 "
                         "serving grid (requires --sparsify)")
    ap.add_argument("--qat-granularity", choices=("per_row", "per_group"),
                    default="per_row")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics snapshot (step-time/checkpoint "
                         "histograms, restart counters, kernel-dispatch "
                         "counters) here after training; .prom/.txt => "
                         "Prometheus text, else JSON")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="attach a flight recorder (repro.obs, DESIGN.md "
                         "§16): a train_step stall watchdog + bounded event "
                         "rings, dumped here on stall/crash/SIGTERM")
    ap.add_argument("--watchdog-threshold", type=float, default=8.0,
                    help="--flight-dir: declare a stall when step silence "
                         "exceeds this multiple of the EWMA step interval "
                         "(floored at 1s)")
    args = ap.parse_args()
    if args.qat and not args.sparsify:
        ap.error("--qat rides the sparsify training path; add --sparsify")
    if args.reduced and args.full:
        ap.error("--reduced and --full are mutually exclusive")
    # Reduced by default only on CPU (this container): GPU/TPU runs keep
    # the full config unless --reduced is given explicitly.
    reduced = args.reduced or (not args.full
                               and jax.default_backend() == "cpu")

    log = obs.get_logger("launch.train")
    cfg = get_arch(args.arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params)
                   if hasattr(x, "size"))
    log.info("arch", name=cfg.name, params_m=round(n_params / 1e6, 1),
             sparsity=(cfg.sparsity.pattern_name() if cfg.sparsity
                       else None),
             reduced=reduced)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 5),
                                compression=args.compression)
    opt_state = adamw.init(opt_cfg, params)

    trainer = None
    if args.sparsify:
        from repro.sparsetrain import SparseTrainRecipe, SparseTrainer
        from repro.sparsetrain.masks import parse_schedule

        schedule = parse_schedule(args.sparsify, args.steps,
                                  update_every=args.sparsify_update_every,
                                  freeze_after=args.sparsify_freeze_after)
        log.info("sparsify schedule", spec=schedule.spec(),
                 **({"qat": f"{args.qat}/{args.qat_granularity}"}
                    if args.qat else {}))
        recipe = SparseTrainRecipe(schedule=schedule, qat=args.qat,
                                   qat_granularity=args.qat_granularity)
        trainer = SparseTrainer(model, opt_cfg, recipe,
                                num_microbatches=args.microbatches)
        trainer.init_state(params)
        step_fn = trainer.train_step
    else:
        step_fn = jax.jit(make_train_step(
            model, opt_cfg, num_microbatches=args.microbatches,
            policy=ExecPolicy(mode="masked")))

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    rng = np.random.default_rng(0)
    recorder = None
    if args.flight_dir:
        recorder = obs.FlightRecorder(
            args.flight_dir, watchdog_threshold=args.watchdog_threshold)
        recorder.install_signal_handlers()
    sup = TrainingSupervisor(
        SupervisorConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        step_fn, data_cfg,
        to_batch=lambda b: add_frontend_inputs(cfg, b, rng),
        extra_state=trainer, recorder=recorder)

    t0 = time.time()
    # keyed by step (not append-ordered) so supervisor restarts replaying
    # steps overwrite instead of duplicating entries
    loss_by_step = {}

    orig_step = sup.train_step

    def logging_step(p, o, b, s):
        p, o, m = orig_step(p, o, b, s)
        loss_by_step[s] = float(m["loss"])
        if s % args.log_every == 0:
            log.info(f"step {s:5d}", loss=round(float(m["loss"]), 4),
                     gnorm=round(float(m["grad_norm"]), 3),
                     lr=float(f"{float(m['lr']):.2e}"),
                     elapsed_s=round(time.time() - t0, 1))
        return p, o, m

    sup.train_step = logging_step
    params, opt_state, metrics, restarts = sup.run(params, opt_state,
                                                   args.steps)
    first, last = loss_by_step[0], loss_by_step[max(loss_by_step)]
    log.info("done", final_loss=round(last, 4), first_loss=round(first, 4),
             restarts=restarts)
    if trainer is None:
        assert last < first, "training must reduce loss"
    else:
        # Pruning phases cause transient loss spikes, so a very short
        # schedule may end above its dense-warmup start; require learning
        # relative to init OR recovery within the final (serving-pattern)
        # phase.
        t_final = min(trainer.recipe.schedule.phases[-1].start,
                      max(loss_by_step))
        assert last < first or last < loss_by_step[t_final], (
            "training must reduce loss (vs step 0 or vs the final "
            "sparsity phase's start)")

    if trainer is not None:
        from repro.train import checkpoint as ckpt

        # Bake the final masks (hard zeros) so the committed checkpoint
        # satisfies the N:M patterns exactly and packs losslessly for
        # launch/serve.py --ckpt-dir ... --packed [--quantize int8].
        params = trainer.finalize(params)
        n_sparse = verify_final_masks(params)
        ckpt.save({"params": params, "opt": opt_state,
                   "extra": trainer.extra_state()},
                  args.ckpt_dir, args.steps)
        log.info("final masks verified; baked checkpoint re-saved",
                 sparse_linears=n_sparse, step=args.steps)

    if args.metrics_out:
        sup.metrics.write(args.metrics_out)
        log.info("wrote metrics snapshot", path=args.metrics_out)
    if recorder is not None:
        recorder.close()


if __name__ == "__main__":
    main()
