"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh)
cell on the production mesh and extract memory / cost / collective analysis.

Usage:
    python -m repro.launch.dryrun --arch gemma3_1b --shape train_4k [--multi-pod]
    python -m repro.launch.dryrun --all [--out results.jsonl]

Each cell prints a JSON record:
    memory_analysis   bytes-per-device breakdown (proves it fits)
    cost_analysis     per-device HLO FLOPs / bytes accessed
    collectives       per-device bytes by collective kind (parsed from HLO)
    roofline          the three §Roofline terms in seconds
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax-importing import: jax locks the device count on
#   first initialization (see the brief).

import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.sparse_linear import ExecPolicy
from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    applicable_shapes,
    get_arch,
)
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models.families import build_model
from repro.optim import adamw
from repro.sharding import context as shctx
from repro.sharding.partitioning import (
    _param_specs_impl,
    batch_axes,
    opt_state_specs,
    shardings_for,
)

# TPU v5e hardware constants (the brief's §Roofline numbers)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


# ---------------------------------------------------------------------------
# Decode-state sharding inference (probe-based, DESIGN.md §5)
# ---------------------------------------------------------------------------

def _axis_of_change(a, b):
    if not hasattr(a, "shape"):
        return None
    return next((i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y), None)


def decode_state_specs(model, shape, mesh, *, seq_shard: bool):
    """Infer PartitionSpecs for the decode state: batch axis over DP (or the
    KV sequence axis over 'data' when batch=1), a model-axis dim preferring
    heads over head_dim, found by divisibility."""
    b = shape.global_batch
    base = jax.eval_shape(lambda: model.init_decode_state(b, shape.seq_len))
    probe_b = jax.eval_shape(
        lambda: model.init_decode_state(b + 1, shape.seq_len))
    probe_s = jax.eval_shape(
        lambda: model.init_decode_state(b, shape.seq_len + 1))

    tp = mesh.shape["model"]
    dp_axes = batch_axes(mesh)
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]
    seq_axes = dp_axes  # when seq-sharding, use all DP axes

    def one(leaf, pb, ps):
        if not hasattr(leaf, "shape"):
            return P()
        nd = len(leaf.shape)
        parts = [None] * nd
        batch_ax = _axis_of_change(leaf, pb)
        seq_ax = _axis_of_change(leaf, ps)
        used = set()
        if seq_shard and seq_ax is not None and \
                leaf.shape[seq_ax] % dp_total == 0:
            parts[seq_ax] = seq_axes if len(seq_axes) > 1 else seq_axes[0]
            used.add(seq_ax)
        elif batch_ax is not None and leaf.shape[batch_ax] % dp_total == 0:
            parts[batch_ax] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            used.add(batch_ax)
        if batch_ax is not None:
            used.add(batch_ax)
        if seq_ax is not None:
            used.add(seq_ax)
        # model axis: prefer second-from-right (heads), then last (head_dim)
        for ax in ([nd - 2, nd - 1] if nd >= 2 else []):
            if ax in used or ax < 0:
                continue
            if leaf.shape[ax] % tp == 0 and leaf.shape[ax] >= tp:
                parts[ax] = "model"
                break
        return P(*parts)

    return jax.tree.map(one, base, probe_b, probe_s), base


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([\d,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "f64": 8}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _result_bytes(line: str) -> int:
    """Total bytes of the op's result tuple/array on the lhs of '='."""
    lhs = line.split(" = ", 1)
    target = lhs[1] if len(lhs) == 2 else line
    # take shapes up to the opcode's '(' operand list start
    head = target.split("(", 1)[0]
    total = 0
    for m in _SHAPE_RE.finditer(head):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device bytes moved per collective kind.

    Ring-cost weighting: all-reduce counts 2× its payload (reduce-scatter +
    all-gather phases); others count their result payload once.  Shapes in
    an SPMD-partitioned module are already per-device."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        opcode_part = ls.split(" = ", 1)[1]
        for kind in _COLL_KINDS:
            # opcode appears right after the shape, e.g. "bf16[8,16] all-reduce("
            if re.search(r"\]\{?[\d,]*\}?\s+%?" + kind + r"[.(]", opcode_part) \
                    or re.search(r"\]\s+" + kind + r"\(", opcode_part):
                nbytes = _result_bytes(ls)
                mult = 2 if kind == "all-reduce" else 1
                out[kind]["count"] += 1
                out[kind]["bytes"] += nbytes * mult
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
               num_microbatches: int = 8, packed: bool = False,
               opt_override=None, arch_override: dict | None = None):
    cfg = get_arch(arch_id)
    if arch_override:
        cfg = dataclasses.replace(cfg, **arch_override)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    dp_axes = batch_axes(mesh)

    seq_shard = shape.kind == "decode" and shape.global_batch == 1
    ctx = shctx.make_context(mesh, num_kv_heads=cfg.num_kv_heads,
                             num_heads=cfg.num_heads,
                             seq_shard_cache=seq_shard)

    tp = mesh.shape["model"]
    kv_repl = (cfg.num_kv_heads % tp != 0 and cfg.num_heads % tp == 0
               and shape.kind != "decode")
    pshapes = specs_mod.param_shapes(model)
    pspecs = _param_specs_impl(pshapes, attn_kv_replicated=kv_repl)
    pshard = shardings_for(mesh, pspecs)

    t0 = time.time()
    with shctx.use_mesh(ctx):
        if shape.kind == "train":
            opt_cfg = opt_override or adamw.AdamWConfig()
            ostate = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), pshapes)
            dd = mesh.shape["data"]
            zspecs = opt_state_specs(pspecs, pshapes, dd)
            ospecs = adamw.AdamWState(
                step=P(), m=zspecs, v=zspecs,
                compression=(zspecs if opt_cfg.compression == "topk"
                             else None))
            oshard = shardings_for(mesh, ospecs)
            batch = specs_mod.train_batch_specs(cfg, shape)
            bshard = jax.tree.map(
                lambda s: NamedSharding(
                    mesh, P(dp_axes, *([None] * (len(s.shape) - 1)))), batch)
            from repro.train.train_loop import make_train_step
            nmb = num_microbatches
            step_fn = make_train_step(model, opt_cfg, num_microbatches=nmb,
                                      policy=ExecPolicy(mode="masked"))
            lowered = jax.jit(
                step_fn,
                in_shardings=(pshard, oshard, bshard, None),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            ).lower(pshapes, ostate, batch, jnp.zeros((), jnp.int32))
        elif shape.kind == "prefill":
            batch = specs_mod.prefill_batch_specs(cfg, shape)
            bshard = jax.tree.map(
                lambda s: NamedSharding(
                    mesh, P(dp_axes, *([None] * (len(s.shape) - 1)))), batch)

            def prefill_fn(params, batch):
                logits, _ = model.prefill(params, batch,
                                          policy=ExecPolicy(mode="masked"))
                return logits

            lowered = jax.jit(
                prefill_fn, in_shardings=(pshard, bshard),
            ).lower(pshapes, batch)
        else:  # decode
            params_in = pshapes
            if packed:
                from repro.launch.pack_tree import pack_tree_shapes
                params_in = pack_tree_shapes(model, pshapes)
                pspecs = _param_specs_impl(params_in)
                pshard = shardings_for(mesh, pspecs)
            sspecs, sshapes = decode_state_specs(model, shape, mesh,
                                                 seq_shard=seq_shard)
            sshard = shardings_for(mesh, sspecs)
            tok = specs_mod.decode_token_specs(shape)
            tok_shard = NamedSharding(
                mesh, P(dp_axes if shape.global_batch % ctx.dp_degree() == 0
                        else None, None))
            # serving baseline: dense weights (masks baked offline); packed =
            # the paper's DeMM serving form
            policy = ExecPolicy(mode="packed" if packed else "dense")

            def decode_fn(params, state, tokens):
                return model.decode_step(params, state, tokens,
                                         policy=policy)

            lowered = jax.jit(
                decode_fn,
                in_shardings=(pshard, sshard, tok_shard),
                out_shardings=(None, sshard),
                donate_argnums=(1,),
            ).lower(params_in, sshapes, tok)

        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # Loop-exact analysis: XLA's cost_analysis counts scan bodies ONCE; the
    # weighted HLO walk multiplies by known_trip_count (hlo_analysis.py).
    from repro.launch import hlo_analysis
    exact = hlo_analysis.analyze(hlo)
    coll = exact.to_dict()["collectives"]

    flops = float(exact.flops)
    bytes_acc = float(exact.bytes_accessed)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll["total_bytes"] / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    record = {
        "arch": arch_id,
        "shape": shape_name,
        "arch_override": arch_override or {},
        "num_microbatches": num_microbatches,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "kind": shape.kind,
        "packed": packed,
        "compile_s": round(t1 - t0, 1),
        "memory_analysis": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "temp_size_in_bytes", 0)) +
            int(getattr(mem, "argument_size_in_bytes", 0)),
        },
        "cost_analysis": {"flops": flops, "bytes_accessed": bytes_acc,
                          "xla_raw_flops": float(cost.get("flops", 0.0)),
                          "unknown_trip_loops": exact.unknown_trip_loops},
        "collectives": coll,
        "roofline": dict(terms, dominant=dominant),
        "model_flops": _model_flops(cfg, shape),
    }
    record["useful_flops_ratio"] = (
        record["model_flops"] / (flops * _n_chips(multi_pod))
        if flops else 0.0)
    return record


def _n_chips(multi_pod: bool) -> int:
    return 512 if multi_pod else 256


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per step; decode
    processes global_batch tokens, train/prefill global_batch×seq."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n * tokens)


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--packed", action="store_true",
                    help="decode cells: DeMM packed weights")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in applicable_shapes(get_arch(a)):
                cells.append((a, s, False))
                cells.append((a, s, True))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    sink = open(args.out, "a") if args.out else sys.stdout
    ok = True
    for arch, shape, mp in cells:
        try:
            rec = lower_cell(arch, shape, multi_pod=mp, packed=args.packed,
                             num_microbatches=args.microbatches)
        except Exception as e:  # noqa: BLE001 — record and continue
            rec = {"arch": arch, "shape": shape,
                   "mesh": "pod2x16x16" if mp else "pod16x16",
                   "error": f"{type(e).__name__}: {e}"}
            ok = False
        print(json.dumps(rec), file=sink, flush=True)
    if args.out:
        sink.close()
    # error cells are recorded in the JSONL; exit 0 so drivers don't
    # double-record
    sys.exit(0)


if __name__ == "__main__":
    main()
