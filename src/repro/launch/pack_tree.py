"""Whole-model conversion to the DeMM packed serving form.

``pack_tree(params)`` walks the param pytree and converts every sparse
linear ({w, _sparse_m, _sparse_n}) to its packed {values, indices, shape}
form; ``pack_tree_shapes`` is the eval_shape twin used by the dry-run."""

from __future__ import annotations

import jax

from repro.models.layers import Static, pack_linear


def _is_sparse_linear(node) -> bool:
    return isinstance(node, dict) and "_sparse_m" in node and "w" in node


def _pack_sparse_linear(node):
    w = node["w"]
    if w.ndim == 2:
        return pack_linear(node)
    # layer-stacked (L, ..., O, K): pack rows flat, restore the stack dims
    lead = w.shape[:-2]
    o, k = w.shape[-2], w.shape[-1]
    out = pack_linear(dict(node, w=w.reshape(-1, k)))
    out["values"] = out["values"].reshape(*lead, o, *out["values"].shape[1:])
    out["indices"] = out["indices"].reshape(*lead, o, *out["indices"].shape[1:])
    out["shape"] = Static((o, k))  # per-layer dense shape (post scan-slice)
    return out


def pack_tree(params):
    if _is_sparse_linear(params):
        return _pack_sparse_linear(params)
    if isinstance(params, dict):
        return {k: pack_tree(v) for k, v in params.items()}
    return params


def pack_tree_shapes(model, param_shapes):
    """ShapeDtypeStruct tree of the packed params (no allocation)."""
    return jax.eval_shape(pack_tree, param_shapes)
