"""Whole-model conversion to the DeMM packed serving form.

``pack_tree(params)`` walks the param pytree and converts every sparse
linear (``{"w": ..., "sparsity": Static(cfg)}``) to a first-class
:class:`~repro.core.sparsity.PackedWeight` node, including the layer-stacked
scan case (leading stack dims are preserved on values/indices while
``dense_shape`` stays the per-layer 2-D shape).  ``pack_tree_shapes`` is the
eval_shape twin used by the dry-run."""

from __future__ import annotations

import warnings

import jax

from repro.core import sparse_linear as sl
from repro.core.sparsity import PackedWeight


def _is_sparse_linear(node) -> bool:
    """Deprecated: the pre-PackedWeight key-sniffing predicate.  Kept for one
    release so external tree-walkers keep working; new code should test
    ``sl.node_sparsity(node) is not None``."""
    warnings.warn(
        "_is_sparse_linear is deprecated; use "
        "repro.core.sparse_linear.node_sparsity(node) is not None",
        DeprecationWarning, stacklevel=2)
    return isinstance(node, dict) and "w" in node and (
        "sparsity" in node or "_sparse_m" in node)


def _pack_sparse_linear(node, cfg) -> PackedWeight:
    w = node["w"]
    if w.ndim == 2:
        return sl.pack_params(node, cfg)
    # layer-stacked (L, ..., O, K): pack rows flat, restore the stack dims
    lead = w.shape[:-2]
    o, k = w.shape[-2], w.shape[-1]
    pw = sl.pack_params({"w": w.reshape(-1, k)}, cfg)
    return PackedWeight(
        pw.values.reshape(*lead, o, *pw.values.shape[1:]),
        pw.indices.reshape(*lead, o, *pw.indices.shape[1:]),
        cfg=cfg, dense_shape=(o, k), layout=pw.layout)


def pack_tree(params):
    if isinstance(params, PackedWeight):
        return params
    if isinstance(params, dict):
        if "w" in params:
            cfg = sl.node_sparsity(params)
            if cfg is not None:
                return _pack_sparse_linear(params, cfg)
        return {k: pack_tree(v) for k, v in params.items()}
    return params


def pack_tree_shapes(model, param_shapes):
    """ShapeDtypeStruct tree of the packed params (no allocation)."""
    return jax.eval_shape(pack_tree, param_shapes)
