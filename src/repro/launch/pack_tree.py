"""Whole-model conversion to the DeMM packed serving form.

``pack_tree(params)`` walks the param pytree and converts every sparse
linear (``{"w": ..., "sparsity": Static(cfg)}``) to a first-class
:class:`~repro.core.sparsity.PackedWeight` node, including the layer-stacked
scan case (leading stack dims are preserved on values/indices while
``dense_shape`` stays the per-layer 2-D shape).  ``layout`` selects the
packed format: ``"xwT"`` (default, the row-packed serving stream) or
``"block"`` (the two-level block format of ``core.sparsity.pack_block`` —
per row-block active-group lists gating the kernel's B DMAs); stacked block
weights share one ``a_max`` across the stack (``pack_block_stacked``) so
scan slicing works unchanged.  ``quantize="int8"`` additionally quantizes
every packed node (``repro.quant``): int8 values + traced scales + static
``qdtype`` aux, served by the w8a16 kernels.  ``pack_tree_shapes`` is the
eval_shape twin used by the dry-run; for shape-exact block dry-runs pass
``a_max`` explicitly (under tracing the active-group count cannot be read
from the data and defaults to all groups)."""

from __future__ import annotations

from typing import Optional

import jax

from repro.core import sparse_linear as sl
from repro.core.sparsity import LAYOUT_BLOCK, LAYOUT_XWT, PackedWeight


def _pack_sparse_linear(node, cfg, layout=LAYOUT_XWT, *, block_r=None,
                        a_max=None) -> PackedWeight:
    from repro.core.sparsity import pack_block_stacked

    w = node["w"]
    if layout == LAYOUT_BLOCK:
        # The block conversion prunes per-(row, group) itself; stacked
        # weights share one a_max so scan bodies slice the layer axis off
        # the packed children exactly as for xwT.
        return pack_block_stacked(w, cfg, block_r=block_r, a_max=a_max)
    if w.ndim == 2:
        return sl.pack_params(node, cfg)
    # layer-stacked (L, ..., O, K): pack rows flat, restore the stack dims
    lead = w.shape[:-2]
    o, k = w.shape[-2], w.shape[-1]
    pw = sl.pack_params({"w": w.reshape(-1, k)}, cfg)
    return PackedWeight(
        pw.values.reshape(*lead, o, *pw.values.shape[1:]),
        pw.indices.reshape(*lead, o, *pw.indices.shape[1:]),
        cfg=cfg, dense_shape=(o, k), layout=pw.layout)


def pack_tree(params, layout: str = LAYOUT_XWT, *, block_r=None, a_max=None,
              quantize: Optional[str] = None, observer=None,
              granularity: str = "per_row"):
    """Convert every sparse linear in ``params`` to a PackedWeight.

    ``quantize`` (e.g. ``"int8"``) quantizes each packed node on the fly;
    ``observer`` is the optional calibration hook forwarded to
    ``repro.quant.quantize_packed`` (e.g. ``quant.activation_calibration``)
    and ``granularity`` the xwT scale unit (``per_row`` | ``per_group``).
    Already-packed nodes pass through (and are quantized if requested).
    """
    def q(pw: PackedWeight) -> PackedWeight:
        if quantize is None or pw.qdtype is not None:
            return pw
        from repro.quant import quantize_packed
        gran = "per_row" if pw.layout == LAYOUT_BLOCK else granularity
        return quantize_packed(pw, quantize, observer=observer,
                               granularity=gran)

    if isinstance(params, PackedWeight):
        return q(params)
    if isinstance(params, dict):
        if "values" in params and "shape" in params:
            raise ValueError(
                "legacy packed {values, indices, shape} dicts are no longer "
                "supported; re-pack the original weights with pack_tree to "
                "get PackedWeight nodes")
        if "w" in params:
            cfg = sl.node_sparsity(params)
            if cfg is not None:
                return q(_pack_sparse_linear(params, cfg, layout,
                                             block_r=block_r, a_max=a_max))
        return {k: pack_tree(v, layout, block_r=block_r, a_max=a_max,
                             quantize=quantize, observer=observer,
                             granularity=granularity)
                for k, v in params.items()}
    return params


def pack_tree_shapes(model, param_shapes, layout: str = LAYOUT_XWT, *,
                     block_r=None, a_max=None,
                     quantize: Optional[str] = None,
                     granularity: str = "per_row"):
    """ShapeDtypeStruct tree of the packed params (no allocation)."""
    return jax.eval_shape(
        lambda p: pack_tree(p, layout, block_r=block_r, a_max=a_max,
                            quantize=quantize, granularity=granularity),
        param_shapes)
