"""Batched serving driver (reduced configs on CPU; production via dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_1b --requests 8 \
        --packed --layout block --quantize int8 --backend auto --autotune

``--packed`` converts every sparse weight to the paper's packed DeMM form
before serving: the decode matmuls then stream only packed bytes.
``--quantize int8`` additionally quantizes the packed values to symmetric
int8 (``repro.quant``) — the decode matmuls then stream int8 bytes and
dequantize in-register (w8a16 kernels).  ``--backend auto`` resolves every
packed matmul through the ``repro.tune`` registry + cache; ``--autotune``
pre-measures tile configs for the decode shapes first (results persist in
the tuning cache for later runs).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_arch
from repro.core.sparse_linear import ExecPolicy
from repro.launch.pack_tree import pack_tree
from repro.models.families import build_model
from repro.serve.serve_loop import Request, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm_3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--layout", choices=("xwT", "block"), default="xwT",
                    help="packed-weight layout for --packed: the row-packed "
                         "xwT stream or the two-level block format "
                         "(pack_block; dispatches the block-spmm kernel)")
    ap.add_argument("--quantize", choices=("int8",), default=None,
                    help="quantize the packed values (repro.quant): int8 "
                         "symmetric with traced scales, served by the "
                         "w8a16 xwT_q8/xwT_block_q8 kernels")
    # valid backends come from the registry, so variants added via
    # repro.tune.register_variant are immediately servable
    from repro import tune
    ap.add_argument("--backend", default="reference",
                    choices=tuple(sorted(
                        {v.name for op in
                         ("xwT", "xwT_block", "xwT_q8", "xwT_block_q8")
                         for v in tune.variants_for(op)}))
                    + ("auto",))
    ap.add_argument("--autotune", action="store_true",
                    help="pre-measure tile configs for the packed decode "
                         "shapes (implies --backend auto)")
    args = ap.parse_args()
    if args.autotune:
        args.backend = "auto"
    if args.quantize and not args.packed:
        ap.error("--quantize applies to the packed serving form; add "
                 "--packed")
    if args.packed and args.backend != "auto":
        # fail invalid layout/backend pairs here, not deep inside the first
        # jitted decode step
        op = "xwT_block" if args.layout == "block" else "xwT"
        if args.quantize:
            op += "_q8"
        valid = {v.name for v in tune.variants_for(op)}
        if args.backend not in valid:
            ap.error(f"--backend {args.backend} is not a registered {op} "
                     f"variant for --layout {args.layout}"
                     + (f" --quantize {args.quantize}" if args.quantize
                        else "")
                     + f" (valid: {sorted(valid)} or 'auto')")

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mode = "masked"
    if args.packed:
        params = pack_tree(params, layout=args.layout,
                           quantize=args.quantize)
        mode = "packed"
    policy = ExecPolicy(mode=mode, backend=args.backend)
    engine = ServeEngine(model, params,
                         ServeConfig(num_slots=args.slots,
                                     max_len=args.max_len),
                         policy=policy,
                         autotune=args.autotune and args.packed)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 12),
                              dtype=np.int32)
        engine.submit(Request(uid=i, prompt=prompt,
                              max_new_tokens=args.max_new))

    t0 = time.time()
    ticks = engine.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in engine.completed)
    tag = mode if not args.quantize else f"{mode}+{args.quantize}"
    print(f"served {len(engine.completed)} requests, {total_tokens} tokens, "
          f"{ticks} engine ticks in {dt:.1f}s "
          f"({total_tokens/max(dt,1e-9):.1f} tok/s, mode={tag})")
    for r in engine.completed[:3]:
        print(f"  req {r.uid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> {r.output[:8]}")


if __name__ == "__main__":
    main()
