"""Batched serving driver (reduced configs on CPU; production via dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_1b --requests 8 \
        --packed --layout block --quantize int8 --backend auto --autotune

``--packed`` converts every sparse weight to the paper's packed DeMM form
before serving: the decode matmuls then stream only packed bytes.
``--quantize int8`` additionally quantizes the packed values to symmetric
int8 (``repro.quant``) — the decode matmuls then stream int8 bytes and
dequantize in-register (w8a16 kernels); ``--quantize-granularity
per_group`` refines the xwT scales from per-row to per-(row, group).
``--backend auto`` resolves every packed matmul through the ``repro.tune``
registry + cache; ``--autotune`` pre-measures tile configs for the decode
shapes first (results persist in the tuning cache for later runs).

``--paged`` swaps the legacy dense-cache loop for the paged serving engine
(``repro.paged``, DESIGN.md §13): a shared paged KV arena sized by
``--page-size``/``--max-pages``, chunked prefill (``--prefill-chunk``
tokens per dispatch), and a ``--scheduler fcfs|priority`` admission/
preemption policy; ``--trace-replay trace.jsonl`` replays a
``benchmarks/serve_bench.py`` trace at its logical arrival ticks, with
prompt tokens derived deterministically from ``(--seed, uid)``.

``--spec-draft N:M`` turns on self-speculative decoding (``repro.spec``,
DESIGN.md §15): ``--spec-gamma`` tokens per window are drafted with the
sparser-tier view of the same packed buffers and verified in one batched
full-tier dispatch; ``--temperature``/``--top-k`` select replay-safe
coupled sampling (token streams are identical with and without
speculation, preemption included).

``--ckpt-dir`` restores trained params from a ``launch/train.py``
checkpoint before packing — the serve half of the dense → prune →
train/QAT → pack → serve pipeline (a ``--sparsify`` run's final checkpoint
has its masks baked in, so it packs losslessly).

Observability (``repro.obs``, DESIGN.md §12): ``--metrics-out m.json``
writes the process-wide metrics snapshot after the drain (request/token
counters, queue-wait/decode-latency histograms, kernel-dispatch and
tune-cache counters; a ``.prom`` suffix selects Prometheus text
exposition), ``--trace-out t.jsonl`` dumps the JSONL event trace, and
``--profile-dir d/`` wraps serving in a jax profiler trace for
TensorBoard/perfetto with every DeMM kernel named via ``obs.annotate``.
"""

from __future__ import annotations

import argparse
import contextlib
import time

import jax
import numpy as np

from repro import obs
from repro.configs.base import ARCH_IDS, get_arch
from repro.core.sparse_linear import ExecPolicy
from repro.launch.pack_tree import pack_tree
from repro.models.families import build_model
from repro.serve import Request, ServeConfig, make_engine


def _load_trace(path: str):
    """benchmarks/serve_bench.py trace format: JSONL rows of
    {uid, arrival_tick, prompt_len, max_new[, priority]}."""
    import json

    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                rows.append(json.loads(line))
    return sorted(rows, key=lambda r: (r["arrival_tick"], r["uid"]))


def _trace_prompt(seed: int, uid: int, length: int, vocab: int):
    """Per-request deterministic prompt, replayable from (seed, uid) —
    matches benchmarks/serve_bench.py so replays are comparable."""
    return np.random.default_rng((seed, uid)).integers(
        0, vocab, length, dtype=np.int32)


def run_serve(model, params, vocab_size: int, *, packed: bool = True,
              layout: str = "xwT", quantize=None,
              granularity: str = "per_row", backend: str = "reference",
              autotune: bool = False, requests: int = 8, slots: int = 4,
              max_new: int = 16, max_len: int = 128, seed: int = 0,
              paged: bool = False, page_size: int = 16, max_pages=None,
              prefill_chunk: int = 32, scheduler: str = "fcfs",
              trace_replay=None, plan=None, replicas: int = 1,
              spec_draft=None, spec_gamma: int = 4,
              temperature: float = 0.0, top_k: int = 0, recorder=None):
    """Pack (optionally) and serve ``requests`` random prompts; returns the
    drained engine.  The reusable core of ``main()`` — the end-to-end
    examples call this directly with their own trained params.

    ``paged=True`` serves through :class:`repro.paged.PagedServeEngine`
    (shared KV arena + chunked prefill + scheduled admission) instead of the
    legacy dense-cache loop; ``trace_replay`` submits a serve_bench-format
    JSONL trace at its logical arrival ticks instead of ``requests`` random
    prompts (prompt tokens derived from ``(seed, uid)`` either way).

    ``plan`` (a :class:`~repro.sharding.plan.ShardingPlan`) distributes the
    engine: TP renumbers + shards packed weights over the mesh, PP runs the
    microbatched pipelined decode step.  ``replicas`` > 1 serves through a
    data-parallel :class:`~repro.serve.ReplicaRouter` — N engines over one
    shared params tree, round-robin admission, merged metrics.

    ``spec_draft`` ("N:M") turns on self-speculative decoding
    (``repro.spec``, DESIGN.md §15): draft ``spec_gamma`` tokens per window
    at the sparser tier of the same packed buffers, verify in one batched
    full-tier dispatch.  ``temperature``/``top_k`` select replay-safe
    coupled sampling (0 = greedy); the token stream is identical with and
    without speculation.
    """
    spec = None
    if spec_draft is not None:
        from repro.spec import SpecConfig
        if not packed:
            raise ValueError(
                "--spec-draft requires --packed: the draft tier is a view "
                "of the packed weight buffers")
        spec = SpecConfig(draft=spec_draft, gamma=spec_gamma)
    mode = "masked"
    if packed:
        params = pack_tree(params, layout=layout, quantize=quantize,
                           granularity=granularity)
        mode = "packed"
    policy = ExecPolicy(mode=mode, backend=backend, plan=plan)
    if paged:
        from repro.paged import PagedServeConfig, SchedConfig
        serve_cfg = PagedServeConfig(
            num_slots=slots, max_len=max_len, page_size=page_size,
            num_pages=max_pages, prefill_chunk=prefill_chunk,
            temperature=temperature, top_k=top_k, seed=seed,
            sched=SchedConfig(policy=scheduler))
    else:
        serve_cfg = ServeConfig(num_slots=slots, max_len=max_len,
                                temperature=temperature, top_k=top_k,
                                seed=seed)
    engine = make_engine(model, params, serve_cfg, policy=policy,
                         autotune=autotune and packed, replicas=replicas,
                         spec=spec, recorder=recorder)
    if trace_replay:
        rows = _load_trace(trace_replay)
        t0 = time.time()
        tick, i = 0, 0
        while i < len(rows):
            while i < len(rows) and rows[i]["arrival_tick"] <= tick:
                r = rows[i]
                engine.submit(Request(
                    uid=r["uid"],
                    prompt=_trace_prompt(seed, r["uid"], r["prompt_len"],
                                         vocab_size),
                    max_new_tokens=r["max_new"],
                    priority=r.get("priority", 1)))
                i += 1
            engine.step()
            tick += 1
        engine.run_until_drained()
    else:
        rng = np.random.default_rng(seed)
        for i in range(requests):
            prompt = rng.integers(0, vocab_size, rng.integers(4, 12),
                                  dtype=np.int32)
            engine.submit(Request(uid=i, prompt=prompt,
                                  max_new_tokens=max_new))
        t0 = time.time()
        engine.run_until_drained()
    # decode-only wall time (packing / engine build / autotune excluded),
    # so reported tok/s stays comparable across runs and releases
    engine.drain_seconds = time.time() - t0
    return engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm_3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0,
                    help="request sampling seed (prompt tokens; trace "
                         "replays derive each prompt from (seed, uid))")
    ap.add_argument("--paged", action="store_true",
                    help="serve through repro.paged.PagedServeEngine: "
                         "shared paged KV arena + chunked prefill + "
                         "scheduled admission/preemption (full-attention "
                         "archs only; DESIGN.md §13)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="--paged: tokens per KV arena page")
    ap.add_argument("--max-pages", type=int, default=None,
                    help="--paged: arena pages incl. the reserved null page "
                         "(default: fully provisioned for num_slots; "
                         "undersize to exercise preemption)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="--paged: prompt tokens per prefill dispatch")
    ap.add_argument("--scheduler", choices=("fcfs", "priority"),
                    default="fcfs",
                    help="--paged: admission policy (priority preempts "
                         "lower-priority requests for higher ones)")
    ap.add_argument("--trace-replay", default=None, metavar="JSONL",
                    help="replay this serve_bench-format trace ({uid, "
                         "arrival_tick, prompt_len, max_new, priority} "
                         "rows) at its logical ticks instead of --requests "
                         "random prompts")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard packed weights over "
                         "a 'model' mesh axis (row-parallel block/xwT "
                         "weights are renumbered per shard); needs tp "
                         "visible devices — on CPU force them with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline-parallel degree: split the layer stack "
                         "into pp stages and run the microbatched pipelined "
                         "decode step (non-paged engine only)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind a "
                         "round-robin router sharing one params tree; "
                         "metrics are merged with a replica=<i> label")
    ap.add_argument("--spec-draft", default=None, metavar="N:M",
                    help="self-speculative decoding (repro.spec, DESIGN.md "
                         "§15): draft at this sparser tier of the packed "
                         "buffers (e.g. 8:128 on a 16:128-packed tree), "
                         "verify windows in one batched full-tier dispatch; "
                         "requires --packed")
    ap.add_argument("--spec-gamma", type=int, default=4,
                    help="tokens drafted per speculation window")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy); sampling is "
                         "replay-safe — randomness is keyed on (seed, "
                         "request, position), so preempt/resume and "
                         "speculative runs commit identical streams")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k mask for temperature sampling (0 = full "
                         "vocab)")
    ap.add_argument("--sparsity", default=None, metavar="N:M",
                    help="override the arch's N:M sparsity pattern before "
                         "init/packing (e.g. 8:16 to leave k-reconfigurable "
                         "headroom for --spec-draft 4:16)")
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--layout", choices=("xwT", "block"), default="xwT",
                    help="packed-weight layout for --packed: the row-packed "
                         "xwT stream or the two-level block format "
                         "(pack_block; dispatches the block-spmm kernel)")
    ap.add_argument("--quantize", choices=("int8",), default=None,
                    help="quantize the packed values (repro.quant): int8 "
                         "symmetric with traced scales, served by the "
                         "w8a16 xwT_q8/xwT_block_q8 kernels")
    ap.add_argument("--quantize-granularity",
                    choices=("per_row", "per_group"), default="per_row",
                    help="xwT scale unit for --quantize (block is always "
                         "per row-block × group × row)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained params from this launch/train.py "
                         "checkpoint directory before packing (--packed "
                         "then serves the trained sparse model)")
    ap.add_argument("--ckpt-step", type=int, default=None,
                    help="checkpoint step to restore (default: latest)")
    ap.add_argument("--full", action="store_true",
                    help="serve the full (non-reduced) config — match this "
                         "to how the checkpoint was trained")
    # valid backends come from the registry, so variants added via
    # repro.tune.register_variant are immediately servable
    from repro import tune
    ap.add_argument("--backend", default="reference",
                    choices=tuple(sorted(
                        {v.name for op in
                         ("xwT", "xwT_block", "xwT_q8", "xwT_block_q8")
                         for v in tune.variants_for(op)}))
                    + ("auto",))
    ap.add_argument("--autotune", action="store_true",
                    help="pre-measure tile configs for the packed decode "
                         "shapes (implies --backend auto)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics snapshot here after the drain "
                         "(.prom/.txt => Prometheus text exposition, "
                         "anything else => JSON)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the JSONL event trace (request lifecycle "
                         "spans/events, autotune measurements) here")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax profiler trace of the serve run "
                         "into this directory (TensorBoard/perfetto)")
    ap.add_argument("--slo-report", action="store_true",
                    help="print the SLO / goodput / phase-latency report "
                         "after the drain (repro.obs.slo, DESIGN.md §16); "
                         "implied by --slo-ttft-ms/--slo-e2e-ms")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="time-to-first-token deadline in ms; completed "
                         "requests are judged pass/fail against it")
    ap.add_argument("--slo-e2e-ms", type=float, default=None,
                    help="end-to-end (submit -> complete) deadline in ms")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="attach a flight recorder (repro.obs, DESIGN.md "
                         "§16): bounded per-subsystem event rings + a "
                         "per-engine tick stall watchdog; stalls, crashes, "
                         "and SIGTERM dump rings+metrics+metadata here")
    ap.add_argument("--watchdog-threshold", type=float, default=8.0,
                    help="--flight-dir: declare a stall when tick silence "
                         "exceeds this multiple of the EWMA tick interval "
                         "(floored at 1s)")
    ap.add_argument("--force-stall", action="store_true",
                    help="--flight-dir: after the drain, stop beating the "
                         "watchdog and wait for it to trip (CI leg that "
                         "proves the stall->dump path); exits nonzero if no "
                         "dump appears")
    args = ap.parse_args()
    if args.autotune:
        args.backend = "auto"
    if args.tp < 1 or args.pp < 1 or args.replicas < 1:
        ap.error("--tp/--pp/--replicas must be >= 1")
    if args.pp > 1 and args.paged:
        ap.error("--pp applies to the non-paged engine (pipelined decode "
                 "over dense caches); drop --paged or --pp")
    plan = None
    if args.tp > 1 or args.pp > 1 or args.replicas > 1:
        from repro.sharding.plan import ShardingPlan
        plan = ShardingPlan(tp=args.tp, pp=args.pp, dp=args.replicas)
        need = args.tp * args.pp
        if need > jax.device_count():
            ap.error(
                f"--tp {args.tp} --pp {args.pp} needs {need} devices but "
                f"only {jax.device_count()} are visible; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    if args.quantize and not args.packed:
        ap.error("--quantize applies to the packed serving form; add "
                 "--packed")
    if args.packed and args.backend != "auto":
        # fail invalid layout/backend pairs here, not deep inside the first
        # jitted decode step
        op = "xwT_block" if args.layout == "block" else "xwT"
        if args.quantize:
            op += "_q8"
        valid = {v.name for v in tune.variants_for(op)}
        if args.backend not in valid:
            ap.error(f"--backend {args.backend} is not a registered {op} "
                     f"variant for --layout {args.layout}"
                     + (f" --quantize {args.quantize}" if args.quantize
                        else "")
                     + f" (valid: {sorted(valid)} or 'auto')")

    if args.spec_draft and not args.packed:
        ap.error("--spec-draft requires --packed (the draft tier is a view "
                 "of the packed weight buffers)")
    if args.force_stall and not args.flight_dir:
        ap.error("--force-stall needs --flight-dir (there is no watchdog "
                 "to trip without a flight recorder)")

    log = obs.get_logger("launch.serve")
    recorder = None
    if args.flight_dir:
        recorder = obs.FlightRecorder(
            args.flight_dir, watchdog_threshold=args.watchdog_threshold)
        recorder.install_signal_handlers()
    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if args.sparsity:
        import dataclasses as _dc
        from repro.core.sparsity import SparsityConfig
        from repro.spec.tiers import parse_tier
        n, m = parse_tier(args.sparsity)
        cfg = _dc.replace(cfg, sparsity=SparsityConfig(n, m, 1))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        from repro.train import checkpoint as ckpt

        step = (args.ckpt_step if args.ckpt_step is not None
                else ckpt.latest_step(args.ckpt_dir))
        if step is None:
            ap.error(f"--ckpt-dir {args.ckpt_dir} holds no checkpoints")
        try:
            restored = ckpt.restore({"params": params}, args.ckpt_dir,
                                    step)["params"]
        except KeyError as e:
            ap.error(
                f"checkpoint {args.ckpt_dir} step {step} is missing leaf "
                f"{e} of the {cfg.name} param tree — was it trained with a "
                "different --arch?")
        # checkpoint.restore trusts the manifest's shapes; fail here with a
        # pointer at the config mismatch instead of deep inside a matmul
        mismatch = [
            f"  {jax.tree_util.keystr(path)}: checkpoint "
            f"{tuple(b.shape)} vs model {tuple(a.shape)}"
            for (path, a), b in zip(
                jax.tree_util.tree_flatten_with_path(params)[0],
                jax.tree.leaves(restored))
            if hasattr(a, "shape") and tuple(a.shape) != tuple(b.shape)]
        if mismatch:
            ap.error(
                f"checkpoint {args.ckpt_dir} step {step} does not fit the "
                f"{'full' if args.full else 'reduced'} {cfg.name} config "
                "(was it trained with the other of --full/--reduced, or a "
                "different --arch?):\n" + "\n".join(mismatch[:8]))
        params = restored
        log.info("restored params", ckpt_dir=args.ckpt_dir, step=step)

    profile_ctx = (obs.profile(args.profile_dir) if args.profile_dir
                   else contextlib.nullcontext())
    guard_ctx = (recorder.guard() if recorder is not None
                 else contextlib.nullcontext())
    with profile_ctx, guard_ctx:
        engine = run_serve(model, params, cfg.vocab_size, packed=args.packed,
                           layout=args.layout, quantize=args.quantize,
                           granularity=args.quantize_granularity,
                           backend=args.backend, autotune=args.autotune,
                           requests=args.requests, slots=args.slots,
                           max_new=args.max_new, max_len=args.max_len,
                           seed=args.seed, paged=args.paged,
                           page_size=args.page_size,
                           max_pages=args.max_pages,
                           prefill_chunk=args.prefill_chunk,
                           scheduler=args.scheduler,
                           trace_replay=args.trace_replay,
                           plan=plan, replicas=args.replicas,
                           spec_draft=args.spec_draft,
                           spec_gamma=args.spec_gamma,
                           temperature=args.temperature, top_k=args.top_k,
                           recorder=recorder)
    dt = engine.drain_seconds
    mode = "packed" if args.packed else "masked"
    total_tokens = sum(len(r.output) for r in engine.completed)
    tag = mode if not args.quantize else f"{mode}+{args.quantize}"
    if args.paged:
        tag += "+paged"
    if args.spec_draft:
        tag += f"+spec{args.spec_draft}"
    if plan is not None:
        tag += f"+tp{args.tp}" if args.tp > 1 else ""
        tag += f"+pp{args.pp}" if args.pp > 1 else ""
        tag += f"+dp{args.replicas}" if args.replicas > 1 else ""
    log.info("served", requests=len(engine.completed), tokens=total_tokens,
             seconds=round(dt, 3),
             tok_s=round(total_tokens / max(dt, 1e-9), 1), mode=tag)
    sm = getattr(engine, "_spec_metrics", None)
    if sm is not None and sm.drafted.value:
        log.info("speculation",
                 drafted=sm.drafted.value, accepted=sm.accepted.value,
                 acceptance=round(sm.accepted.value / sm.drafted.value, 3),
                 tokens_per_dispatch=round(
                     sm._committed_total / max(sm._verify_dispatches, 1), 3))
    for r in engine.completed[:3]:
        log.info(f"  req {r.uid}: prompt[:4]={r.prompt[:4].tolist()} "
                 f"-> {r.output[:8]}")
    slo_cfg = obs.SLOConfig(ttft_ms=args.slo_ttft_ms, e2e_ms=args.slo_e2e_ms)
    if args.slo_report or slo_cfg.enabled():
        import json as _json
        # the DP router's merged facade has no instruments of its own;
        # publish verdicts only on a real registry
        reg = engine.metrics if hasattr(engine.metrics, "gauge") else None
        report = obs.slo_report(engine.completed, slo_cfg, metrics=reg)
        log.info("slo report\n" + _json.dumps(report, indent=2))
    if args.metrics_out:
        engine.metrics.write(args.metrics_out)
        log.info("wrote metrics snapshot", path=args.metrics_out)
    if args.trace_out:
        engine.metrics.trace.write(args.trace_out)
        log.info("wrote event trace", path=args.trace_out)
    if args.profile_dir:
        log.info("wrote profiler trace", dir=args.profile_dir)
    if recorder is not None:
        if args.force_stall:
            # CI leg: the drain is done, nothing beats the watchdogs any
            # more — the stall must be detected and dumped on its own
            log.info("forcing a stall", flight_dir=args.flight_dir)
            if not recorder.wait_for_dump(timeout=30.0):
                recorder.close()
                raise SystemExit(
                    "--force-stall: no flight dump appeared within 30s")
            log.info("flight dump written", dumps=recorder.dumps)
        recorder.close()


if __name__ == "__main__":
    main()
