"""Pallas TPU kernels for DeMM (validated with interpret=True on CPU)."""
