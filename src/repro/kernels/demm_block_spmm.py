"""Pallas TPU kernel: scalar-prefetch block-gather DeMM spmm.

This is the *decoupled-memory* half of the DeMM adaptation (DESIGN.md §2,
row (b)): the column indices of the sparse matrix drive **which blocks of B
are fetched from HBM at all**.  The packed format is two-level:

  level 1 — per row-block, the list of *active* M-groups (groups where at
            least one row of the block has a non-zero).  Groups absent from
            the list are never DMA'd and never touch the MXU: the address
            stream gates the memory system exactly like DeMM's read ports
            gate its SRAM.
  level 2 — within each active group, the usual relaxed N:M packed
            {values, indices} (consumed by the same scatter→MXU body as
            ``demm_spmm``).

The active-group ids are passed through ``PrefetchScalarGridSpec`` so the
BlockSpec ``index_map`` of B reads them *before* the grid step runs — i.e.
the DMA engine is addressed by the sparse metadata, which is the paper's
decoupling, relocated to the HBM→VMEM boundary.

Padded slots (row blocks with fewer than ``a_max`` active groups) point at
group 0 with all-zero values: they cost a redundant (but cheap, VMEM-hit)
step and contribute exactly 0.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparsity import DEFAULT_BLOCK_R, SparsityConfig, pack_block
from repro.kernels.demm_spmm import _CompilerParams, _scatter_matrix

DEFAULT_BLOCK_C = 256


def pack_block_sparse(
    a: np.ndarray, cfg: SparsityConfig, block_r: int = DEFAULT_BLOCK_R,
    a_max: int | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side two-level packing — a numpy adapter over
    :func:`repro.core.sparsity.pack_block` (the single home for the
    active-group / level-2 selection semantics).

    Returns (active_groups (RB, A_max) int32,
             values (RB, A_max, block_r, Ne),
             indices (RB, A_max, block_r, Ne),
             a_max).
    """
    pw = pack_block(jnp.asarray(a), cfg, block_r=block_r, a_max=a_max)
    return (np.asarray(pw.active_groups), np.asarray(pw.values),
            np.asarray(pw.indices), pw.block_geom[1])


def _block_spmm_kernel(ag_ref, values_ref, indices_ref, b_ref, out_ref, *, m, n):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # values_ref block: (1, 1, block_r, N) — squeeze the block-level dims.
    vals = values_ref[0]                                     # (1, block_r, N) -> treat as (block_r,1,N)
    idxs = indices_ref[0]
    s = _scatter_matrix(
        jnp.swapaxes(vals, 0, 1), jnp.swapaxes(idxs, 0, 1), m, n, b_ref.dtype
    )                                                        # (block_r, M)
    out_ref[...] += jax.lax.dot_general(
        s, b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "r", "cd_block", "interpret"),
)
def demm_block_spmm_pallas(
    active_groups: jax.Array,  # (RB, A_max) int32
    values: jax.Array,         # (RB, A_max, block_r, Ne)
    indices: jax.Array,        # (RB, A_max, block_r, Ne)
    b: jax.Array,              # (K, Cd)
    cfg: SparsityConfig,
    *,
    r: int,
    cd_block: int = DEFAULT_BLOCK_C,
    interpret: bool = False,
) -> jax.Array:
    rb, a_max, block_r, n = values.shape
    k, cd = b.shape
    m = cfg.m
    assert rb * block_r == r
    assert n == cfg.n_effective
    cd_block = min(cd_block, cd)
    assert cd % cd_block == 0

    grid = (rb, cd // cd_block, a_max)
    kernel = functools.partial(_block_spmm_kernel, m=m, n=n)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_r, n), lambda i, c, j, ag: (i, j, 0, 0)),
                pl.BlockSpec((1, 1, block_r, n), lambda i, c, j, ag: (i, j, 0, 0)),
                # The decoupled read port: B's DMA address comes from the
                # prefetched active-group id, not from the grid position.
                pl.BlockSpec((m, cd_block), lambda i, c, j, ag: (ag[i, j], c)),
            ],
            out_specs=pl.BlockSpec((block_r, cd_block), lambda i, c, j, ag: (i, c)),
        ),
        out_shape=jax.ShapeDtypeStruct((r, cd), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="demm_block_spmm",
    )(active_groups, values, indices, b)
