"""Pallas TPU kernel: fused decompress→MXU DeMM spmm.

TPU adaptation of the DeMM engine (DESIGN.md §2).  The packed sparse matrix
(values + column indices) is the only representation of A that leaves HBM.
Inside the kernel — i.e. *after* the DMA stage, in VMEM — the N
``{value, col_idx}`` pairs of each row-group are expanded into a (rows, M)
scatter matrix S (the software analogue of DeMM's N read ports selecting N
rows of the pre-loaded B block), and the MXU performs S @ B_block, fusing the
paper's multiplier array and adder trees into the systolic matmul.

Two entry points:

* ``demm_spmm_pallas(values, indices, b)``   — C = A_sparse @ B
  (the paper's orientation: A (R, K) packed, B (K, Cd) dense).
* ``demm_xwT_pallas(x, values, indices)``    — y = x @ W_sparseᵀ
  (the serving hot path: dense activations × packed weightᵀ).

Both tile with explicit BlockSpecs: the B (resp. x) block of one M-group is
resident in VMEM across the inner grid dimension, mirroring the engine's
pre-loaded memory block; the output block is revisited across groups and
accumulated in fp32.

VMEM budget (defaults, bf16): B block M×Ct = 128×256×2 = 64 KiB; A packed
block Rt×N×(2+4) ≈ 6 KiB; out block Rt×Ct×4 = 128 KiB — comfortably inside
the ~16 MiB/core VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparsity import SparsityConfig

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

# MXU/VPU-aligned defaults.  Production dispatch picks per-problem tiles via
# repro.tune (backend="auto"); these remain the direct-call defaults.
DEFAULT_BLOCK_R = 128   # rows of the sparse matrix per tile
DEFAULT_BLOCK_C = 256   # dense output columns per tile
DEFAULT_BLOCK_B = 128   # activation rows per tile (xwT orientation)


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple (no-op when aligned).

    Zero rows of packed values scatter to zero contributions and padded
    output rows/columns are sliced away by the caller, so ragged serving
    shapes (batch not a tile multiple) stay exact.
    """
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _scatter_matrix(values_blk, indices_blk, m: int, n: int, dtype):
    """Expand packed (rows, 1, N) values/indices into the (rows, M) scatter
    matrix S — the in-VMEM image of DeMM's N read ports.

    S[r, j] = sum_n values[r, n] * [indices[r, n] == j]

    The N loop is static and small (the paper's read-port count), so it is
    unrolled into N VPU select-accumulate ops over (rows, M) tiles.
    Duplicate indices accumulate, matching the oracle's scatter-add.
    """
    rows = values_blk.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (rows, m), 1)
    s = jnp.zeros((rows, m), dtype)
    for j in range(n):
        v = values_blk[:, 0, j].astype(dtype)[:, None]        # (rows, 1)
        idx = indices_blk[:, 0, j][:, None]                    # (rows, 1)
        s = s + jnp.where(idx == iota, v, jnp.zeros((), dtype))
    return s


# ---------------------------------------------------------------------------
# C = A_sparse @ B (paper orientation)
# ---------------------------------------------------------------------------

def _spmm_kernel(values_ref, indices_ref, b_ref, out_ref, *, m, n, n_groups):
    g = pl.program_id(2)

    @pl.when(g == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = _scatter_matrix(values_ref[...], indices_ref[...], m, n,
                        b_ref.dtype)                            # (Rt, M)
    contrib = jax.lax.dot_general(
        s, b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                           # (Rt, Ct)
    out_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_r", "block_c", "interpret"),
)
def demm_spmm_pallas(
    values: jax.Array,      # (R, G, N)
    indices: jax.Array,     # (R, G, N) int32
    b: jax.Array,           # (K, Cd), K = G * M
    cfg: SparsityConfig,
    *,
    block_r: int = DEFAULT_BLOCK_R,
    block_c: int = DEFAULT_BLOCK_C,
    interpret: bool = False,
) -> jax.Array:
    r, g, n = values.shape
    k, cd = b.shape
    m = cfg.m
    assert k == g * m, (k, g, m)
    assert n == cfg.n_effective, (n, cfg)
    block_r = min(block_r, r)
    block_c = min(block_c, cd)
    # Ragged shapes are zero-padded to the tile grid and sliced back after.
    values = _pad_to(values, 0, block_r)
    indices = _pad_to(indices, 0, block_r)
    b = _pad_to(b, 1, block_c)
    rp, cdp = values.shape[0], b.shape[1]

    grid = (rp // block_r, cdp // block_c, g)
    kernel = functools.partial(_spmm_kernel, m=m, n=n, n_groups=g)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, 1, n), lambda i, j, gg: (i, gg, 0)),
            pl.BlockSpec((block_r, 1, n), lambda i, j, gg: (i, gg, 0)),
            pl.BlockSpec((m, block_c), lambda i, j, gg: (gg, j)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j, gg: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cdp), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="demm_spmm",
    )(values, indices, b)
    return out[:r, :cd]


# ---------------------------------------------------------------------------
# y = x @ W_sparseᵀ (serving orientation: W packed (O, K), x (Bx, K))
# ---------------------------------------------------------------------------

def _xwT_kernel(x_ref, values_ref, indices_ref, out_ref, *, m, n):
    g = pl.program_id(2)

    @pl.when(g == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = _scatter_matrix(values_ref[...], indices_ref[...], m, n,
                        x_ref.dtype)                            # (Ot, M)
    contrib = jax.lax.dot_general(
        x_ref[...], s,
        dimension_numbers=(((1,), (1,)), ((), ())),             # contract M
        preferred_element_type=jnp.float32,
    )                                                           # (Bt, Ot)
    out_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_b", "block_o", "interpret"),
)
def demm_xwT_pallas(
    x: jax.Array,           # (Bx, K) dense activations
    values: jax.Array,      # (O, G, N) packed weight
    indices: jax.Array,     # (O, G, N) int32
    cfg: SparsityConfig,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_o: int = DEFAULT_BLOCK_R,
    interpret: bool = False,
) -> jax.Array:
    bx, k = x.shape
    o, g, n = values.shape
    m = cfg.m
    assert k == g * m, (k, g, m)
    assert n == cfg.n_effective, (n, cfg)
    block_b = min(block_b, bx)
    block_o = min(block_o, o)
    # Ragged serving batches / output dims are zero-padded to the tile grid
    # and sliced back after.
    x = _pad_to(x, 0, block_b)
    values = _pad_to(values, 0, block_o)
    indices = _pad_to(indices, 0, block_o)
    bxp, op = x.shape[0], values.shape[0]

    grid = (bxp // block_b, op // block_o, g)
    kernel = functools.partial(_xwT_kernel, m=m, n=n)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i, j, gg: (i, gg)),
            pl.BlockSpec((block_o, 1, n), lambda i, j, gg: (j, gg, 0)),
            pl.BlockSpec((block_o, 1, n), lambda i, j, gg: (j, gg, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, gg: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bxp, op), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="demm_xwT",
    )(x, values, indices)
    return out[:bx, :o]
