"""jit'd public ops over the DeMM kernels, with sparse-aware gradients.

Backend dispatch routes through the ``repro.tune`` kernel registry:

  * ``reference``        — pure-jnp decompress+matmul (XLA path; used inside
                           distributed jit steps and on CPU).
  * ``pallas``           — the Pallas TPU kernel (real hardware).
  * ``pallas_interpret`` — the Pallas kernel in interpret mode (CPU checks).
  * ``auto``             — resolve (backend, tile params) per problem from
                           the tuning cache (populated by
                           ``benchmarks/kernel_bench.py --autotune`` or
                           ``repro.tune.autotune_*``), falling back to a
                           platform heuristic.  Resolution is a static
                           shape-keyed lookup, safe under jit tracing.

New variants registered via ``repro.tune.register_variant`` become valid
backend strings here with no further changes.

Gradients:
  dL/dx       = dy @ W_dense
  dL/dvalues  = gather of (dyᵀ x) at the packed index positions — i.e. the
                gradient of a sparse weight exists only at its non-zero
                coordinates, which is what keeps DeMM serving and sparse
                fine-tuning consistent.
  indices / active_groups are non-differentiable.

The ``xwT`` custom_vjp lives here; the ``xwT_block`` / ``xwT_q8`` /
``xwT_block_q8`` ops route through ``repro.sparsetrain.vjp`` (dequant-and-
scatter backward through the jnp references), so ``jax.grad`` through
``ExecPolicy(mode="packed")`` is legal for every layout (DESIGN.md §11).

Observability (``repro.obs``, DESIGN.md §12): every dispatch increments a
``kernel_dispatch_total{op, backend}`` counter on the default registry and
runs the selected variant under an ``obs.annotate("demm/<op>/<backend>")``
scope.  Dispatch happens at jit-trace time, so the counters audit *which
variant each traced matmul resolved to* (making ``backend="auto"``
decisions inspectable) at zero steady-state cost, and the named scopes make
the lowered Pallas kernels show up named in TensorBoard/perfetto traces
(``obs.profile``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sparsity import (
    LAYOUT_BLOCK,
    LAYOUT_XWT,
    LAYOUTS,
    PackedWeight,
    SparsityConfig,
    unpack,
)

# Baseline backends always registered; `repro.tune.backend_names("xwT")` has
# the live list (plus "auto", resolved through the tuning cache).
BACKENDS = ("reference", "pallas", "pallas_interpret", "auto")


def _count_dispatch(op: str, backend: str):
    """Trace-time dispatch audit: counter plus a ``kernel_dispatch`` trace
    event.  Fires while jax traces the enclosing jit, i.e. inside whatever
    :mod:`repro.obs.context` the caller entered — under a serving engine
    the event inherits the dispatching request's ``trace_id``, correlating
    kernel compiles to the request that triggered them (DESIGN.md §16)."""
    from repro import obs

    m = obs.metrics()
    m.counter(
        "kernel_dispatch_total",
        help="DeMM matmul dispatches per (registry op, resolved backend)",
        op=op, backend=backend).inc()
    m.trace.event("kernel_dispatch", op=op, backend=backend)


def demm_matmul_packed(x: jax.Array, pw: PackedWeight,
                       backend: str = "reference") -> jax.Array:
    """y = x @ W^T for a first-class :class:`PackedWeight`.

    The layout tag picks the op: ``xwT`` weights run the row-packed DeMM
    matmul, ``block`` weights (two-level ahead-of-time packing from
    ``core.sparsity.pack_block``) run the scalar-prefetch block-spmm family.
    A quantized node (``pw.qdtype`` set, see ``repro.quant``) routes to the
    ``xwT_q8`` / ``xwT_block_q8`` twins, whose kernels dequantize the int8
    values in-register (w8a16); under ``jax.grad`` the quantized ops
    propagate exact dx (through the dequantized weight) and dL/dscales,
    while the int8 values stay non-differentiable — fine-tune values on the
    float packed form and re-quantize (``repro.sparsetrain``).
    The sparsity config (including k-reconfiguration), dense shape, block
    geometry, and qdtype come from the type's static aux data, so call
    sites never re-derive them from loose dict keys.  ``pw`` must be
    unstacked — scan bodies slice the layer axis off stacked weights before
    applying.

    A shard-stacked weight (``pw.shard_axis`` set — the renumbered
    row-parallel form from ``core.sparsity.shard_packed_row_parallel``)
    routes to the shard_map island: each mesh device runs the kernel on its
    local slice and K-chunk of ``x`` and the partial products are combined
    with ``psum``.  Without a matching mesh (single device, tests) the same
    math runs as a sequential sum over slices.
    """
    if getattr(pw, "tier_ne", None) is not None:
        # Draft-tier view (repro.spec): the params tree aliases the full
        # tier's buffers and only this static tag differs; the trace-time
        # slice narrows the address stream to the magnitude-top prefix
        # (tier_sort_packed invariant) before any dispatch decision — a
        # shard-stacked draft weight therefore keeps the single psum island
        # of its full-tier twin.
        from repro.core.sparsity import narrow_tier
        return demm_matmul_packed(x, narrow_tier(pw), backend)
    if getattr(pw, "shard_axis", None) is not None:
        return _demm_matmul_sharded(x, pw, backend)
    if pw.layout == LAYOUT_BLOCK:
        if getattr(pw.values, "ndim", 4) != 4:
            raise ValueError(
                f"demm_matmul_packed needs an unstacked (RB, A_max, block_r, "
                f"Ne) block weight, got values of shape {pw.values.shape}")
        return demm_matmul_block(x, pw, backend)
    if pw.layout != LAYOUT_XWT:
        raise ValueError(
            f"unknown PackedWeight layout {pw.layout!r}; known layouts: "
            f"{LAYOUTS}")
    if getattr(pw.values, "ndim", 3) != 3:
        raise ValueError(
            f"demm_matmul_packed needs an unstacked (O, G, Ne) weight, got "
            f"values of shape {pw.values.shape}; slice the stack axis first")
    if pw.qdtype is not None:
        return demm_matmul_xwT_q8(x, pw.values, pw.indices, pw.scales,
                                  pw.cfg, pw.dense_shape, backend, pw.shards)
    return demm_matmul_xwT(x, pw.values, pw.indices, pw.cfg, pw.dense_shape,
                           backend, pw.shards)


def _demm_matmul_sharded(x: jax.Array, pw: PackedWeight,
                         backend: str = "reference") -> jax.Array:
    """y = x @ W^T over a shard-stacked row-parallel weight.

    With a :class:`~repro.sharding.context.ShardingContext` whose mesh
    carries ``pw.shard_axis`` at size ``pw.shards``, this is the shard_map
    island: ``x`` is split along K (spec ``P(None, axis)``), every child of
    ``pw`` along its shard dim (spec ``P(axis)``), each device dispatches
    the ordinary packed kernel on its locally-renumbered slice, and partial
    products are ``psum``-combined.  Otherwise (single-device tests, meshes
    without the axis) the identical math runs as a sequential
    sum-over-slices, so outputs are bitwise-comparable across the two paths
    up to float summation order.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.sparsity import shard_slice
    from repro.sharding import context as shctx

    if x.ndim != 2:
        raise ValueError(f"sharded packed matmul needs 2-D x, got {x.shape}")
    axis, s_count = pw.shard_axis, pw.shards
    if len(pw.stack_dims):
        raise ValueError(
            f"demm_matmul_packed needs an unstacked shard-stacked weight, "
            f"got values of shape {pw.values.shape}; slice the stack axis "
            f"first")
    k_local = pw.in_features // s_count
    ctx = shctx.get_context()
    mesh = getattr(ctx, "mesh", None)
    if (mesh is None or axis not in mesh.shape
            or int(mesh.shape[axis]) != s_count):
        # No matching mesh: same partial-product math, sequentially.
        parts = [
            demm_matmul_packed(
                jax.lax.slice_in_dim(x, s * k_local, (s + 1) * k_local,
                                     axis=1),
                shard_slice(pw, s), backend)
            for s in range(s_count)
        ]
        return functools.reduce(jnp.add, parts)

    children, treedef = jax.tree_util.tree_flatten(pw)

    def local_fn(xl, *cl):
        pw_local = shard_slice(jax.tree_util.tree_unflatten(treedef, cl), 0)
        y = demm_matmul_packed(xl, pw_local, backend)
        return jax.lax.psum(y, axis)

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(None, axis),) + (P(axis),) * len(children),
                   out_specs=P(None, None), check_rep=False)
    return fn(x, *children)


def demm_matmul_block(x: jax.Array, pw: PackedWeight,
                      backend: str = "reference") -> jax.Array:
    """y = x @ W^T for a ``block``-layout :class:`PackedWeight`.

    The two-level kernel computes the paper orientation C = A_sparse @ B, so
    the serving matmul is evaluated as ``(W_block @ x^T)^T`` with the
    active-group address stream gating which xᵀ blocks are touched at all.
    Dispatch routes through the ``xwT_block`` op of the ``repro.tune``
    registry (``xwT_block_q8`` for a quantized node); ``backend="auto"``
    resolves per (shape, dtype, pattern, block geometry, platform) through
    the tuning cache.  Both ops carry a custom_vjp
    (``repro.sparsetrain.vjp``), so this path is legal inside ``jax.grad``.
    """
    from repro import obs, tune
    from repro.sparsetrain import vjp as st_vjp

    params = {}
    if backend == "auto":
        choice = tune.resolve_xwT_block(x.shape, pw, x.dtype)
        backend, params = choice.backend, choice.params
    ptuple = tuple(sorted(params.items()))
    op = "xwT_block_q8" if pw.qdtype is not None else "xwT_block"
    _count_dispatch(op, backend)
    with obs.annotate(f"demm/{op}/{backend}"):
        if pw.qdtype is not None:
            return st_vjp.xwT_block_q8_grad(x, pw.values, pw.indices,
                                            pw.active_groups, pw.scales,
                                            pw.cfg, tuple(pw.dense_shape),
                                            backend, ptuple)
        return st_vjp.xwT_block_grad(x, pw.values, pw.indices,
                                     pw.active_groups, pw.cfg,
                                     tuple(pw.dense_shape), backend, ptuple)


def _dispatch_xwT(x, values, indices, cfg, w_shape, backend, shards=1):
    from repro import obs, tune

    params = {}
    if backend == "auto":
        choice = tune.resolve_xwT(x.shape, w_shape, cfg, x.dtype, shards)
        backend, params = choice.backend, choice.params
    variant = tune.get_variant("xwT", backend)
    _count_dispatch("xwT", backend)
    with obs.annotate(f"demm/xwT/{backend}"):
        return variant.call(x, values, indices, cfg, tuple(w_shape),
                            **params)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def demm_matmul_xwT(x, values, indices, cfg: SparsityConfig, w_shape,
                    backend: str = "reference", shards: int = 1):
    """y = x @ W_sparseᵀ; x (B, K), W packed (O, G, Ne) for dense (O, K).
    ``shards`` > 1 tags the shard-local problem of a renumbered row-parallel
    weight for ``backend="auto"`` cache keying; the math is unchanged."""
    return _dispatch_xwT(x, values, indices, cfg, w_shape, backend, shards)


def _xwT_fwd(x, values, indices, cfg, w_shape, backend, shards=1):
    y = _dispatch_xwT(x, values, indices, cfg, w_shape, backend, shards)
    return y, (x, values, indices)


def _xwT_bwd(cfg, w_shape, backend, shards, res, dy):
    x, values, indices = res
    o, k = w_shape
    m = cfg.m
    g = k // m
    w = unpack(values, indices, cfg, (o, k))                 # (O, K)
    dx = jnp.dot(dy, w.astype(dy.dtype))                      # (B, K)
    # dW = dyᵀ @ x, needed only at the packed coordinates.
    dw = jnp.dot(dy.T.astype(jnp.float32), x.astype(jnp.float32))  # (O, K)
    dw_g = dw.reshape(o, g, m)
    dvalues = jnp.take_along_axis(dw_g, indices, axis=-1).astype(values.dtype)
    # Padded slots (value 0 at index 0) must not accumulate gradient, or they
    # would densify the pattern.
    dvalues = jnp.where(values != 0, dvalues, jnp.zeros((), values.dtype))
    return dx.astype(x.dtype), dvalues, None


demm_matmul_xwT.defvjp(_xwT_fwd, _xwT_bwd)


def demm_matmul_xwT_q8(x, values, indices, scales, cfg: SparsityConfig,
                       w_shape, backend: str = "reference", shards: int = 1):
    """y = x @ W_q8ᵀ; int8 values (O, G, Ne) + scales (O,) per output row or
    (O, G) per group (``repro.quant`` granularities).

    Carries a custom_vjp (``repro.sparsetrain.vjp``): dx and dL/dscales are
    exact; the int8 values are not a differentiable parameterization —
    fine-tune values on the float packed form and re-quantize with
    ``repro.quant.quantize_packed``.
    """
    from repro import obs, tune
    from repro.sparsetrain import vjp as st_vjp

    params = {}
    if backend == "auto":
        choice = tune.resolve_xwT_q8(x.shape, w_shape, cfg, x.dtype, shards)
        backend, params = choice.backend, choice.params
    _count_dispatch("xwT_q8", backend)
    with obs.annotate(f"demm/xwT_q8/{backend}"):
        return st_vjp.xwT_q8_grad(x, values, indices, scales, cfg,
                                  tuple(w_shape), backend,
                                  tuple(sorted(params.items())))


def demm_spmm(values, indices, b, cfg: SparsityConfig, a_shape,
              backend: str = "reference"):
    """C = A_sparse @ B (paper orientation)."""
    from repro import obs, tune

    params = {}
    if backend == "auto":
        choice = tune.resolve_spmm(a_shape, b.shape, cfg, b.dtype)
        backend, params = choice.backend, choice.params
    variant = tune.get_variant("spmm", backend)
    if variant.measure_only:
        raise ValueError(
            f"backend {backend!r} is measure-only (host repacking); use it "
            "through repro.tune.autotune_spmm or call its kernel directly")
    _count_dispatch("spmm", backend)
    with obs.annotate(f"demm/spmm/{backend}"):
        return variant.call(values, indices, b, cfg, tuple(a_shape),
                            **params)
