"""jit'd public ops over the DeMM kernels, with sparse-aware gradients.

Backend dispatch:
  * ``reference``        — pure-jnp decompress+matmul (XLA path; used inside
                           distributed jit steps and on CPU).
  * ``pallas``           — the Pallas TPU kernel (real hardware).
  * ``pallas_interpret`` — the Pallas kernel in interpret mode (CPU checks).

Gradients (custom_vjp on the xwT op):
  dL/dx       = dy @ W_dense
  dL/dvalues  = gather of (dyᵀ x) at the packed index positions — i.e. the
                gradient of a sparse weight exists only at its non-zero
                coordinates, which is what keeps DeMM serving and sparse
                fine-tuning consistent.
  indices are non-differentiable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig, unpack
from repro.kernels import ref as kref
from repro.kernels.demm_spmm import demm_spmm_pallas, demm_xwT_pallas

BACKENDS = ("reference", "pallas", "pallas_interpret")


def _dispatch_xwT(x, values, indices, cfg, w_shape, backend):
    if backend == "reference":
        return kref.xwT_ref(x, values, indices, cfg, w_shape)
    if backend == "pallas":
        return demm_xwT_pallas(x, values, indices, cfg, interpret=False)
    if backend == "pallas_interpret":
        return demm_xwT_pallas(x, values, indices, cfg, interpret=True)
    raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def demm_matmul_xwT(x, values, indices, cfg: SparsityConfig, w_shape,
                    backend: str = "reference"):
    """y = x @ W_sparseᵀ; x (B, K), W packed (O, G, Ne) for dense (O, K)."""
    return _dispatch_xwT(x, values, indices, cfg, w_shape, backend)


def _xwT_fwd(x, values, indices, cfg, w_shape, backend):
    y = _dispatch_xwT(x, values, indices, cfg, w_shape, backend)
    return y, (x, values, indices)


def _xwT_bwd(cfg, w_shape, backend, res, dy):
    x, values, indices = res
    o, k = w_shape
    m = cfg.m
    g = k // m
    w = unpack(values, indices, cfg, (o, k))                 # (O, K)
    dx = jnp.dot(dy, w.astype(dy.dtype))                      # (B, K)
    # dW = dyᵀ @ x, needed only at the packed coordinates.
    dw = jnp.dot(dy.T.astype(jnp.float32), x.astype(jnp.float32))  # (O, K)
    dw_g = dw.reshape(o, g, m)
    dvalues = jnp.take_along_axis(dw_g, indices, axis=-1).astype(values.dtype)
    # Padded slots (value 0 at index 0) must not accumulate gradient, or they
    # would densify the pattern.
    dvalues = jnp.where(values != 0, dvalues, jnp.zeros((), values.dtype))
    return dx.astype(x.dtype), dvalues, None


demm_matmul_xwT.defvjp(_xwT_fwd, _xwT_bwd)


def demm_spmm(values, indices, b, cfg: SparsityConfig, a_shape,
              backend: str = "reference"):
    """C = A_sparse @ B (paper orientation)."""
    if backend == "reference":
        return kref.spmm_ref(values, indices, b, cfg, a_shape)
    if backend == "pallas":
        return demm_spmm_pallas(values, indices, b, cfg, interpret=False)
    if backend == "pallas_interpret":
        return demm_spmm_pallas(values, indices, b, cfg, interpret=True)
    raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
