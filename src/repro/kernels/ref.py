"""Pure-jnp oracles for the DeMM kernels.

Every Pallas kernel in this package is validated with
``np.testing.assert_allclose`` against these references across shape/dtype
sweeps (see tests/test_demm_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig, unpack


def spmm_ref(values: jax.Array, indices: jax.Array, b: jax.Array,
             cfg: SparsityConfig, a_shape) -> jax.Array:
    """C = A_sparse @ B via unpack-to-dense then dense matmul (fp32 accum)."""
    a = unpack(values, indices, cfg, tuple(a_shape))
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def xwT_ref(x: jax.Array, values: jax.Array, indices: jax.Array,
            cfg: SparsityConfig, w_shape) -> jax.Array:
    """y = x @ W_sparseᵀ via unpack-to-dense (fp32 accum)."""
    w = unpack(values, indices, cfg, tuple(w_shape))
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32).T,
                   preferred_element_type=jnp.float32)


def block_spmm_ref(active_groups, values, indices, b, cfg: SparsityConfig,
                   r: int) -> jax.Array:
    """Oracle for the two-level block-sparse format: scatter every active
    group back to dense, then matmul."""
    rb, a_max, block_r, ne = values.shape
    k, cd = b.shape
    m = cfg.m
    g = k // m
    dense = jnp.zeros((rb, block_r, g, m), values.dtype)
    iota = jnp.arange(m, dtype=jnp.int32)
    onehot = (indices[..., None] == iota).astype(values.dtype)  # (RB,A,br,Ne,M)
    per_slot = jnp.einsum("rabn,rabnm->rabm", values, onehot)    # (RB,A,br,M)
    # scatter-add each active slot into its group (duplicate ids accumulate,
    # matching the kernel's revisit-accumulate semantics)
    def per_block(dense_b, ag_b, slot_b):
        return dense_b.at[:, ag_b, :].add(jnp.swapaxes(slot_b, 0, 1))
    dense = jax.vmap(per_block)(dense, active_groups, per_slot)
    a = dense.reshape(r, k)
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
