"""Pure-jnp oracles for the DeMM kernels.

Every Pallas kernel in this package is validated with
``np.testing.assert_allclose`` against these references across shape/dtype
sweeps (see tests/test_demm_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparsity import (SparsityConfig, expand_scales, unpack,
                                 unpack_block)


def spmm_ref(values: jax.Array, indices: jax.Array, b: jax.Array,
             cfg: SparsityConfig, a_shape) -> jax.Array:
    """C = A_sparse @ B via unpack-to-dense then dense matmul (fp32 accum)."""
    a = unpack(values, indices, cfg, tuple(a_shape))
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def xwT_ref(x: jax.Array, values: jax.Array, indices: jax.Array,
            cfg: SparsityConfig, w_shape) -> jax.Array:
    """y = x @ W_sparseᵀ via unpack-to-dense (fp32 accum)."""
    w = unpack(values, indices, cfg, tuple(w_shape))
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32).T,
                   preferred_element_type=jnp.float32)


def block_spmm_ref(active_groups, values, indices, b, cfg: SparsityConfig,
                   r: int) -> jax.Array:
    """Oracle for the two-level block-sparse format: scatter every active
    group back to dense (``core.sparsity.unpack_block`` — one home for the
    revisit-accumulate scatter semantics), then matmul."""
    k = b.shape[0]
    a = unpack_block(active_groups, values, indices, cfg, (r, k))
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# int8 quantized oracles (repro.quant): dequantize, then the float path.
# ---------------------------------------------------------------------------

def xwT_q8_ref(x: jax.Array, values: jax.Array, indices: jax.Array,
               scales: jax.Array, cfg: SparsityConfig, w_shape) -> jax.Array:
    """y = x @ W_q8ᵀ with per-output-row (O,) or per-group (O, G) scales:
    dequant + float ref."""
    vals = values.astype(jnp.float32) * expand_scales(scales, values)
    return xwT_ref(x, vals, indices, cfg, w_shape)


def block_spmm_q8_ref(active_groups, values, indices, scales, b,
                      cfg: SparsityConfig, r: int) -> jax.Array:
    """Two-level block oracle with per-(row-block, group, row) scales
    (RB, A_max, block_r): dequant + float ref."""
    vals = values.astype(jnp.float32) * scales[..., None]
    return block_spmm_ref(active_groups, vals, indices, b, cfg, r)
