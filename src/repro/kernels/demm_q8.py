"""Pallas TPU kernels: int8 quantized DeMM matmuls (w8a16).

Quantized twins of ``demm_spmm.demm_xwT_pallas`` and
``demm_block_spmm.demm_block_spmm_pallas`` for weights produced by
``repro.quant.quantize_packed``: the packed ``values`` stream is int8 (a
further 2–4× cut of the already-compressed weight HBM traffic on top of the
sparsity win) and dequantization happens **in-register**, after the DMA
stage — only quantized bytes ever leave HBM.

w8a16 scheme: weights int8, activations keep their serving dtype
(bf16/f32).  Inside the kernel the int8 values are cast to the activation
dtype while building the (rows, M) scatter matrix S — int8 magnitudes
(≤127, ≤254 after duplicate-index accumulation) are exact in bf16 — and the
symmetric scales fold in as one row-wise multiply on S before the MXU
matmul, so the fused body costs one extra VPU multiply per tile:

  * xwT:   scales are per output row ``(O,)`` → S rows scale by
    ``scales[o]`` (passed as an ``(O, 1)`` operand so the BlockSpec stays
    2-D).  Per-group scales ``(O, G)`` (``repro.quant`` granularity
    ``"per_group"``) cost the same: grid step ``g`` reads column ``g`` of
    the scales operand instead of column 0.
  * block: scales are per (row-block, group, row) ``(RB, A_max, block_r)``
    → the ``(block_r, M)`` scatter tile scales row-wise per grid step, and
    the level-1 active-group prefetch (the decoupled address stream) is
    untouched.

Accumulation stays fp32, matching the float kernels' oracle tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparsity import SparsityConfig
from repro.kernels.demm_spmm import (
    _CompilerParams,
    _pad_to,
    _scatter_matrix,
    DEFAULT_BLOCK_B,
    DEFAULT_BLOCK_R,
)
from repro.kernels.demm_block_spmm import DEFAULT_BLOCK_C


# ---------------------------------------------------------------------------
# y = x @ W_q8ᵀ (serving orientation)
# ---------------------------------------------------------------------------

def _xwT_q8_kernel(x_ref, values_ref, indices_ref, scales_ref, out_ref, *,
                   m, n):
    g = pl.program_id(2)

    @pl.when(g == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # int8 → activation dtype inside the scatter expansion (in-register
    # dequant), then one row-wise multiply by the per-output-row scale.
    s = _scatter_matrix(values_ref[...], indices_ref[...], m, n,
                        x_ref.dtype)                            # (Ot, M)
    s = s * scales_ref[...].astype(x_ref.dtype)                 # (Ot, 1)
    contrib = jax.lax.dot_general(
        x_ref[...], s,
        dimension_numbers=(((1,), (1,)), ((), ())),             # contract M
        preferred_element_type=jnp.float32,
    )                                                           # (Bt, Ot)
    out_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_b", "block_o", "interpret"),
)
def demm_xwT_q8_pallas(
    x: jax.Array,           # (Bx, K) dense activations
    values: jax.Array,      # (O, G, N) int8 packed weight
    indices: jax.Array,     # (O, G, N) int32
    scales: jax.Array,      # (O,) per-row or (O, G) per-group f32 scales
    cfg: SparsityConfig,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_o: int = DEFAULT_BLOCK_R,
    interpret: bool = False,
) -> jax.Array:
    bx, k = x.shape
    o, g, n = values.shape
    m = cfg.m
    assert k == g * m, (k, g, m)
    assert n == cfg.n_effective, (n, cfg)
    assert scales.shape in ((o,), (o, g)), (scales.shape, values.shape)
    per_group = scales.ndim == 2
    block_b = min(block_b, bx)
    block_o = min(block_o, o)
    x = _pad_to(x, 0, block_b)
    values = _pad_to(values, 0, block_o)
    indices = _pad_to(indices, 0, block_o)
    # Per-row scales ride as an (O, 1) operand so the BlockSpec stays 2-D;
    # per-group scales ride as (O, G) and grid step gg picks its column —
    # the kernel body sees a (block_o, 1) tile either way.
    scales2d = _pad_to(scales if per_group else scales.reshape(o, 1), 0,
                       block_o)
    bxp, op = x.shape[0], values.shape[0]

    grid = (bxp // block_b, op // block_o, g)
    kernel = functools.partial(_xwT_q8_kernel, m=m, n=n)
    scales_map = ((lambda i, j, gg: (j, gg)) if per_group
                  else (lambda i, j, gg: (j, 0)))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i, j, gg: (i, gg)),
            pl.BlockSpec((block_o, 1, n), lambda i, j, gg: (j, gg, 0)),
            pl.BlockSpec((block_o, 1, n), lambda i, j, gg: (j, gg, 0)),
            pl.BlockSpec((block_o, 1), scales_map),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, gg: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bxp, op), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="demm_xwT_q8",
    )(x, values, indices, scales2d)
    return out[:bx, :o]


# ---------------------------------------------------------------------------
# C = A_q8_block @ B (two-level layout, scalar-prefetch address stream)
# ---------------------------------------------------------------------------

def _block_q8_kernel(ag_ref, values_ref, indices_ref, scales_ref, b_ref,
                     out_ref, *, m, n):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = values_ref[0]                                     # (1, block_r, N)
    idxs = indices_ref[0]
    s = _scatter_matrix(
        jnp.swapaxes(vals, 0, 1), jnp.swapaxes(idxs, 0, 1), m, n, b_ref.dtype
    )                                                        # (block_r, M)
    s = s * scales_ref[0, 0][:, None].astype(b_ref.dtype)
    out_ref[...] += jax.lax.dot_general(
        s, b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "r", "cd_block", "interpret"),
)
def demm_block_spmm_q8_pallas(
    active_groups: jax.Array,  # (RB, A_max) int32
    values: jax.Array,         # (RB, A_max, block_r, Ne) int8
    indices: jax.Array,        # (RB, A_max, block_r, Ne)
    scales: jax.Array,         # (RB, A_max, block_r) float32
    b: jax.Array,              # (K, Cd)
    cfg: SparsityConfig,
    *,
    r: int,
    cd_block: int = DEFAULT_BLOCK_C,
    interpret: bool = False,
) -> jax.Array:
    rb, a_max, block_r, n = values.shape
    k, cd = b.shape
    m = cfg.m
    assert rb * block_r == r
    assert n == cfg.n_effective
    assert scales.shape == (rb, a_max, block_r), (scales.shape, values.shape)
    cd_block = min(cd_block, cd)
    assert cd % cd_block == 0

    grid = (rb, cd // cd_block, a_max)
    kernel = functools.partial(_block_q8_kernel, m=m, n=n)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_r, n),
                             lambda i, c, j, ag: (i, j, 0, 0)),
                pl.BlockSpec((1, 1, block_r, n),
                             lambda i, c, j, ag: (i, j, 0, 0)),
                pl.BlockSpec((1, 1, block_r),
                             lambda i, c, j, ag: (i, j, 0)),
                # Decoupled read port (unchanged by quantization): B's DMA
                # address comes from the prefetched active-group id.
                pl.BlockSpec((m, cd_block), lambda i, c, j, ag: (ag[i, j], c)),
            ],
            out_specs=pl.BlockSpec((block_r, cd_block),
                                   lambda i, c, j, ag: (i, c)),
        ),
        out_shape=jax.ShapeDtypeStruct((r, cd), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="demm_block_spmm_q8",
    )(active_groups, values, indices, scales, b)
