"""Fault tolerance: supervised training with restart, straggler monitoring,
and elastic re-meshing.

* ``TrainingSupervisor`` — wraps the step loop: periodic (async) checkpoints,
  exception-driven restore + deterministic data skip-ahead.  Because the
  data pipeline is a pure function of the step index, a restart reproduces
  the uninterrupted trajectory bitwise (tested).
* ``StragglerMonitor`` — per-host step-time EWMA; hosts slower than
  ``threshold``× the fleet median are flagged for replacement / microbatch
  rebalancing (hook returns the suggested new grain distribution).
* ``elastic_restore`` — restore a checkpoint onto a *different* mesh (e.g.
  after losing a data-parallel slice): shardings are recomputed for the new
  mesh and ``checkpoint.restore`` reshards transparently.

Observability (``repro.obs``, DESIGN.md §12): the supervisor observes a
step-time histogram, restart/failure counters, and checkpoint save/restore
duration histograms on its :class:`~repro.obs.MetricsRegistry` (the process
default unless ``metrics=`` is given), with ``checkpoint_save`` /
``restart`` trace events; :class:`StragglerMonitor` folds its per-host EWMA
state and each :class:`StragglerReport` into the same registry (per-host
gauges + flagged count) instead of keeping the report purely bespoke.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro import obs
from repro.data.pipeline import DataConfig, global_batch
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3
    async_save: bool = False


class TrainingSupervisor:
    """Drives (params, opt_state) through ``train_step`` with restarts."""

    def __init__(self, cfg: SupervisorConfig, train_step: Callable,
                 data_cfg: DataConfig, to_batch: Optional[Callable] = None,
                 extra_state=None, metrics=None, recorder=None):
        """``extra_state`` (optional) is any object with an
        ``extra_state() -> pytree`` / ``load_extra_state(pytree)`` pair
        (e.g. ``sparsetrain.SparseTrainer``): its tree is saved under the
        checkpoint's ``extra`` key and pushed back on restore, so stateful
        schedules (pruning masks, QAT observers) survive restarts with the
        same bitwise-replay guarantee as params.

        ``recorder`` (optional :class:`~repro.obs.FlightRecorder`,
        DESIGN.md §16): the supervisor taps its trace into the recorder's
        rings, beats a ``train_step`` stall watchdog once per step, and
        dumps flight data when the run dies (restart budget exhausted or an
        unexpected exception)."""
        self.cfg = cfg
        self.train_step = train_step
        self.data_cfg = data_cfg
        self.to_batch = to_batch or (lambda b: b)
        self.extra = extra_state
        self.restarts = 0
        self.pending_save = None
        self.metrics = metrics if metrics is not None else obs.metrics()
        m = self.metrics
        self._m_step_time = m.histogram(
            "train_step_seconds", help="wall time per training step")
        self._m_steps = m.counter(
            "train_steps_total", help="completed training steps")
        self._m_restarts = m.counter(
            "train_restarts_total", help="checkpoint-restore restarts")
        self._m_failures = m.counter(
            "train_failures_total", help="step failures caught")
        self._m_ckpt_save = m.histogram(
            "train_checkpoint_save_seconds",
            help="checkpoint save duration (submission time if async)")
        self._m_ckpt_restore = m.histogram(
            "train_checkpoint_restore_seconds",
            help="checkpoint restore duration")
        self._m_ckpt_saves = m.counter(
            "train_checkpoint_saves_total", help="checkpoints written")
        self._recorder = recorder
        self._watchdog = None
        if recorder is not None:
            recorder.attach_trace(m.trace)
            self._watchdog = recorder.watchdog("train_step")

    def _save(self, state, step):
        t0 = time.perf_counter()
        tree = {"params": state[0], "opt": state[1]}
        if self.extra is not None:
            tree["extra"] = self.extra.extra_state()
        if self.cfg.async_save:
            if self.pending_save is not None:
                self.pending_save.result()
            self.pending_save = ckpt.save_async(tree, self.cfg.ckpt_dir, step)
        else:
            ckpt.save(tree, self.cfg.ckpt_dir, step)
        dt = time.perf_counter() - t0
        self._m_ckpt_save.observe(dt)
        self._m_ckpt_saves.inc()
        self.metrics.trace.event("checkpoint_save", step=step, seconds=dt,
                                 asynchronous=self.cfg.async_save)

    def _restore(self, template_state, shardings=None):
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            return template_state, 0
        t0 = time.perf_counter()
        template = {"params": template_state[0], "opt": template_state[1]}
        if self.extra is not None:
            template["extra"] = self.extra.extra_state()
        tree = ckpt.restore(template, self.cfg.ckpt_dir, step, shardings)
        if self.extra is not None:
            self.extra.load_extra_state(tree["extra"])
        dt = time.perf_counter() - t0
        self._m_ckpt_restore.observe(dt)
        self.metrics.trace.event("checkpoint_restore", step=step, seconds=dt)
        return (tree["params"], tree["opt"]), step

    def run(self, params, opt_state, num_steps: int,
            failure_injector: Optional[Callable[[int], None]] = None):
        """Run ``num_steps`` steps with checkpoint/restart.  Returns
        (params, opt_state, metrics_of_last_step, restart_count)."""
        state = (params, opt_state)
        step = 0
        metrics = None
        while step < num_steps:
            try:
                if self._watchdog is not None:
                    self._watchdog.beat()
                if failure_injector is not None:
                    failure_injector(step)
                t0 = time.perf_counter()
                batch = self.to_batch(global_batch(self.data_cfg, step))
                p, o, metrics = self.train_step(state[0], state[1], batch,
                                                step)
                state = (p, o)
                self._m_step_time.observe(time.perf_counter() - t0)
                self._m_steps.inc()
                step += 1
                if step % self.cfg.ckpt_every == 0 or step == num_steps:
                    self._save(state, step)
            except _InjectedFailure as e:
                self._m_failures.inc()
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    if self._recorder is not None:
                        self._recorder.dump("crash-restart-budget")
                    raise
                self._m_restarts.inc()
                self.metrics.trace.event("restart", step=step,
                                         reason=str(e)[:200])
                state, step = self._restore(state)
            except BaseException:
                # unexpected failure: capture the flight rings before dying
                if self._recorder is not None:
                    self._recorder.dump("crash-train")
                raise
        if self.pending_save is not None:
            self.pending_save.result()
            self.pending_save = None
        return state[0], state[1], metrics, self.restarts


class _InjectedFailure(RuntimeError):
    """Simulated node failure (tests raise this via the injector)."""


def inject_failure_once(at_step: int):
    fired = {"done": False}

    def injector(step):
        if step == at_step and not fired["done"]:
            fired["done"] = True
            raise _InjectedFailure(f"simulated node failure at step {step}")

    return injector


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerReport:
    flagged_hosts: list
    median_time: float
    suggestion: dict  # host -> microbatch grain multiplier


class StragglerMonitor:
    """EWMA per-host step times; flags hosts slower than threshold×median.

    On a real deployment the per-host times come from the coordinator's
    heartbeats; here they are fed in directly (and by the tests).

    State is folded into the metrics registry: every ``record`` updates a
    per-host ``train_host_step_seconds`` EWMA gauge, every ``report``
    updates ``train_straggler_median_step_seconds`` /
    ``train_stragglers_flagged`` — so straggler status ships in the same
    ``--metrics-out`` snapshot as everything else instead of living only in
    ad-hoc :class:`StragglerReport` objects."""

    def __init__(self, num_hosts: int, alpha: float = 0.3,
                 threshold: float = 1.5, metrics=None):
        self.ewma = np.zeros(num_hosts)
        self.seen = np.zeros(num_hosts, bool)
        self.alpha = alpha
        self.threshold = threshold
        self.metrics = metrics if metrics is not None else obs.metrics()
        self._m_hosts = [
            self.metrics.gauge("train_host_step_seconds",
                               help="per-host step-time EWMA", host=str(i))
            for i in range(num_hosts)]

    def record(self, host_times):
        host_times = np.asarray(host_times, float)
        new = ~self.seen
        self.ewma = np.where(new, host_times,
                             self.alpha * host_times +
                             (1 - self.alpha) * self.ewma)
        self.seen[:] = True
        for g, v in zip(self._m_hosts, self.ewma):
            g.set(float(v))

    def report(self) -> StragglerReport:
        med = float(np.median(self.ewma))
        flagged = [int(i) for i in np.nonzero(
            self.ewma > self.threshold * med)[0]]
        # rebalance: slow hosts get proportionally fewer microbatches
        suggestion = {
            int(i): (round(float(med / self.ewma[i]), 2) if i in flagged
                     else 1.0)
            for i in range(len(self.ewma))}
        self.metrics.gauge("train_straggler_median_step_seconds",
                           help="fleet median of the per-host EWMA").set(med)
        self.metrics.gauge("train_stragglers_flagged",
                           help="hosts slower than threshold x median").set(
            len(flagged))
        if flagged:
            self.metrics.trace.event("stragglers_flagged", hosts=flagged,
                                     median_seconds=med)
        return StragglerReport(flagged, med, suggestion)


# ---------------------------------------------------------------------------
# Elastic re-meshing
# ---------------------------------------------------------------------------

def elastic_restore(template, directory: str, step: int, new_mesh,
                    spec_tree):
    """Restore a checkpoint onto a different mesh: rebuild NamedShardings
    for ``new_mesh`` from the PartitionSpec tree and reshard on load."""
    from repro.sharding.partitioning import shardings_for

    shardings = shardings_for(new_mesh, spec_tree)
    return ckpt.restore(template, directory, step, shardings)
