"""Fault tolerance: supervised training with restart, straggler monitoring,
and elastic re-meshing.

* ``TrainingSupervisor`` — wraps the step loop: periodic (async) checkpoints,
  exception-driven restore + deterministic data skip-ahead.  Because the
  data pipeline is a pure function of the step index, a restart reproduces
  the uninterrupted trajectory bitwise (tested).
* ``StragglerMonitor`` — per-host step-time EWMA; hosts slower than
  ``threshold``× the fleet median are flagged for replacement / microbatch
  rebalancing (hook returns the suggested new grain distribution).
* ``elastic_restore`` — restore a checkpoint onto a *different* mesh (e.g.
  after losing a data-parallel slice): shardings are recomputed for the new
  mesh and ``checkpoint.restore`` reshards transparently.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.data.pipeline import DataConfig, global_batch
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3
    async_save: bool = False


class TrainingSupervisor:
    """Drives (params, opt_state) through ``train_step`` with restarts."""

    def __init__(self, cfg: SupervisorConfig, train_step: Callable,
                 data_cfg: DataConfig, to_batch: Optional[Callable] = None,
                 extra_state=None):
        """``extra_state`` (optional) is any object with an
        ``extra_state() -> pytree`` / ``load_extra_state(pytree)`` pair
        (e.g. ``sparsetrain.SparseTrainer``): its tree is saved under the
        checkpoint's ``extra`` key and pushed back on restore, so stateful
        schedules (pruning masks, QAT observers) survive restarts with the
        same bitwise-replay guarantee as params."""
        self.cfg = cfg
        self.train_step = train_step
        self.data_cfg = data_cfg
        self.to_batch = to_batch or (lambda b: b)
        self.extra = extra_state
        self.restarts = 0
        self.pending_save = None

    def _save(self, state, step):
        tree = {"params": state[0], "opt": state[1]}
        if self.extra is not None:
            tree["extra"] = self.extra.extra_state()
        if self.cfg.async_save:
            if self.pending_save is not None:
                self.pending_save.result()
            self.pending_save = ckpt.save_async(tree, self.cfg.ckpt_dir, step)
        else:
            ckpt.save(tree, self.cfg.ckpt_dir, step)

    def _restore(self, template_state, shardings=None):
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            return template_state, 0
        template = {"params": template_state[0], "opt": template_state[1]}
        if self.extra is not None:
            template["extra"] = self.extra.extra_state()
        tree = ckpt.restore(template, self.cfg.ckpt_dir, step, shardings)
        if self.extra is not None:
            self.extra.load_extra_state(tree["extra"])
        return (tree["params"], tree["opt"]), step

    def run(self, params, opt_state, num_steps: int,
            failure_injector: Optional[Callable[[int], None]] = None):
        """Run ``num_steps`` steps with checkpoint/restart.  Returns
        (params, opt_state, metrics_of_last_step, restart_count)."""
        state = (params, opt_state)
        step = 0
        metrics = None
        while step < num_steps:
            try:
                if failure_injector is not None:
                    failure_injector(step)
                batch = self.to_batch(global_batch(self.data_cfg, step))
                p, o, metrics = self.train_step(state[0], state[1], batch,
                                                step)
                state = (p, o)
                step += 1
                if step % self.cfg.ckpt_every == 0 or step == num_steps:
                    self._save(state, step)
            except _InjectedFailure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                state, step = self._restore(state)
        if self.pending_save is not None:
            self.pending_save.result()
            self.pending_save = None
        return state[0], state[1], metrics, self.restarts


class _InjectedFailure(RuntimeError):
    """Simulated node failure (tests raise this via the injector)."""


def inject_failure_once(at_step: int):
    fired = {"done": False}

    def injector(step):
        if step == at_step and not fired["done"]:
            fired["done"] = True
            raise _InjectedFailure(f"simulated node failure at step {step}")

    return injector


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerReport:
    flagged_hosts: list
    median_time: float
    suggestion: dict  # host -> microbatch grain multiplier


class StragglerMonitor:
    """EWMA per-host step times; flags hosts slower than threshold×median.

    On a real deployment the per-host times come from the coordinator's
    heartbeats; here they are fed in directly (and by the tests)."""

    def __init__(self, num_hosts: int, alpha: float = 0.3,
                 threshold: float = 1.5):
        self.ewma = np.zeros(num_hosts)
        self.seen = np.zeros(num_hosts, bool)
        self.alpha = alpha
        self.threshold = threshold

    def record(self, host_times):
        host_times = np.asarray(host_times, float)
        new = ~self.seen
        self.ewma = np.where(new, host_times,
                             self.alpha * host_times +
                             (1 - self.alpha) * self.ewma)
        self.seen[:] = True

    def report(self) -> StragglerReport:
        med = float(np.median(self.ewma))
        flagged = [int(i) for i in np.nonzero(
            self.ewma > self.threshold * med)[0]]
        # rebalance: slow hosts get proportionally fewer microbatches
        suggestion = {
            int(i): (round(float(med / self.ewma[i]), 2) if i in flagged
                     else 1.0)
            for i in range(len(self.ewma))}
        return StragglerReport(flagged, med, suggestion)


# ---------------------------------------------------------------------------
# Elastic re-meshing
# ---------------------------------------------------------------------------

def elastic_restore(template, directory: str, step: int, new_mesh,
                    spec_tree):
    """Restore a checkpoint onto a different mesh: rebuild NamedShardings
    for ``new_mesh`` from the PartitionSpec tree and reshard on load."""
    from repro.sharding.partitioning import shardings_for

    shardings = shardings_for(new_mesh, spec_tree)
    return ckpt.restore(template, directory, step, shardings)
