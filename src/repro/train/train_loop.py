"""Distributed training step: microbatch scan + remat + ZeRO-1 AdamW.

``make_train_step(model, cfg, opt_cfg, num_microbatches)`` builds a jittable
``train_step(params, opt_state, batch, step)``:

  * the global batch (already DP-sharded by ``in_shardings``) is split into
    ``num_microbatches`` chunks processed by a ``lax.scan`` that accumulates
    fp32 gradients — this bounds activation memory (the 262k-vocab logits of
    gemma3 would not fit otherwise);
  * the loss is the model's ``train_loss`` with the DeMM masked-sparse path;
  * AdamW moments carry ZeRO-1 shardings (partitioning.opt_state_specs), so
    the update computes on data-axis shards; SPMD materializes the implied
    reduce-scatter/all-gather;
  * all comms overlap is left to the XLA latency-hiding scheduler — the
    structure (per-layer scan, accumulate-in-carry) is chosen so gradient
    reductions of microbatch i can overlap compute of microbatch i+1.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.sharding import context as shctx


def _split_microbatches(batch: dict, n: int) -> dict:
    def f(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(f, batch)


def premask_params(params):
    """Apply the N:M straight-through masks ONCE per step.

    Weights are constant within a step, so recomputing the top-k mask in
    every microbatch × remat pass (up to 14×/layer) is pure waste — masking
    here and running the model in ``dense`` mode cuts those top-k ops and
    their gradient plumbing out of the hot loop while keeping identical
    semantics (straight-through gradients still reach the dense weight
    through this one masking site)."""
    from repro.core.pruning import masked_weight
    from repro.core.sparse_linear import node_sparsity

    def walk(node):
        if isinstance(node, dict):
            if "w" in node:
                cfg = node_sparsity(node)
                if cfg is not None:
                    w = node["w"]
                    # layer-stacked weights (L, ..., O, K): the N:M groups
                    # live along K, so masking is row-wise after flattening.
                    flat = w.reshape(-1, w.shape[-1])
                    return dict(node,
                                w=masked_weight(flat, cfg).reshape(w.shape))
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(params)


def make_train_step(model, opt_cfg: adamw.AdamWConfig, *,
                    num_microbatches: int = 1, policy=None, mode=None,
                    backend=None, donate: bool = True, premask: bool = True,
                    fake_quant=None, qat_granularity: str = "per_row"):
    """Build a jittable ``train_step(params, opt_state, batch, step,
    masks=None)``.

    ``masks`` (optional, a ``sparsetrain.masks.build_masks`` tree) replaces
    the per-step top-k premasking with externally scheduled masks — the
    gradual-sparsification path of ``repro.sparsetrain``: the schedule
    driver refreshes the mask tree on its own cadence and the step just
    applies it straight-through.  ``fake_quant`` (e.g. ``"int8"``) adds
    QAT: after masking, every sparse weight is fake-quantized on the int8
    grid its packed serving form will use (``sparsetrain.qat``), at
    ``qat_granularity`` (``per_row`` | ``per_group``).
    """
    from repro.core.sparse_linear import resolve_policy
    from repro.sparsetrain.qat import validate_qat

    validate_qat(fake_quant, qat_granularity)
    policy = resolve_policy(policy, mode, backend)
    mode = policy.mode
    # With premasking, the per-microbatch model runs in dense mode.
    inner_policy = (policy.replace(mode="dense")
                    if premask and mode == "masked" else policy)

    def loss_fn(params, mb):
        loss, metrics = model.train_loss(params, mb, policy=inner_policy)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step, masks=None):
        del step  # schedule uses opt_state.step
        use_premask = premask and mode == "masked"
        if masks is not None:
            if not use_premask:
                raise ValueError(
                    "scheduled masks need mode='masked' with premask=True "
                    "(the inner model must run dense so the mask is applied "
                    "exactly once)")
            from repro.sparsetrain.masks import apply_mask_tree

            # scheduled masking: same one-masking-site semantics as
            # premasking, but the mask comes from the sparsify schedule
            # instead of a per-step top-k.
            fwd_params = apply_mask_tree(params, masks)
        elif use_premask:
            # mask once per step; the straight-through vjp of the mask is
            # the identity, so gradients w.r.t. the masked params ARE the
            # straight-through gradients for the dense params — no vjp
            # plumbing needed.
            fwd_params = premask_params(params)
        else:
            fwd_params = params
        if fake_quant is not None:
            from repro.sparsetrain import qat

            fwd_params = qat.fake_quant_params(fwd_params, qat_granularity)

        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(fwd_params, batch)
        else:
            mbs = _split_microbatches(batch, num_microbatches)

            def mb_step(acc, mb):
                (loss, metrics), g = grad_fn(fwd_params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32)
                    if gi is not None and hasattr(gi, "dtype") else a,
                    acc, g)
                return acc, (loss, metrics)

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32)
                if hasattr(p, "dtype") and jnp.issubdtype(p.dtype, jnp.floating)
                else None, fwd_params)
            grads, (losses, metricses) = jax.lax.scan(mb_step, acc0, mbs)
            grads = jax.tree.map(
                lambda g: g / num_microbatches if g is not None else None,
                grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metricses)

        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model, *, policy=None, mode=None, backend=None):
    from repro.core.sparse_linear import resolve_policy

    policy = resolve_policy(policy, mode, backend)

    def eval_step(params, batch):
        loss, metrics = model.train_loss(params, batch, policy=policy)
        return dict(metrics, loss=loss)

    return eval_step
