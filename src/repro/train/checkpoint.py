"""Sharded, atomic, reshard-on-restore checkpointing.

Layout::

    <dir>/step_<N>/
        manifest.json        # pytree structure, shapes, dtypes, paths
        <leaf-path>.npy      # one file per leaf (host-gathered)
    <dir>/step_<N>.tmp/      # staging; os.rename() commits atomically

Restore takes target shardings (possibly for a DIFFERENT mesh than the one
that saved — elastic restarts) and rebuilds global arrays with
``jax.make_array_from_callback``, so each device materializes only its
shard.  ``save_async`` stages device-to-host transfers immediately and
writes on a background thread (training continues).

Typed nodes: :class:`~repro.core.sparsity.PackedWeight` nodes (values /
indices — plus active_groups for the block layout and scales for quantized
weights — leaves with static ``{cfg, dense_shape, layout, block_geom,
qdtype}`` aux) and
:class:`Static` metadata are recorded in the manifest's ``nodes`` table, and
restore patches the manifest's aux back over the template — so a packed
model round-trips save → elastic-restore with its full
:class:`SparsityConfig` (including k-reconfiguration) and quantization tag
even if the restoring process rebuilt its template with different static
metadata.
"""

from __future__ import annotations

import json
import os
import shutil
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import PackedWeight, SparsityConfig, Static
from repro.core.treeutil import key_path_str

_EXEC = ThreadPoolExecutor(max_workers=2)


def _leaf_paths(tree):
    paths = []

    def one(path, leaf):
        paths.append((key_path_str(path), leaf))

    jax.tree_util.tree_map_with_path(one, tree)
    return paths


# ---------------------------------------------------------------------------
# Typed-node manifest entries (PackedWeight aux, Static values)
# ---------------------------------------------------------------------------

def _encode_value(v):
    """JSON-encode a Static value, tagging non-JSON-native types."""
    if isinstance(v, SparsityConfig):
        return {"__type__": "SparsityConfig", "n": v.n, "m": v.m, "k": v.k}
    if isinstance(v, tuple):
        return {"__type__": "tuple", "items": [_encode_value(x) for x in v]}
    return v


def _decode_value(v):
    if isinstance(v, dict) and "__type__" in v:
        if v["__type__"] == "SparsityConfig":
            return SparsityConfig(v["n"], v["m"], v["k"])
        if v["__type__"] == "tuple":
            return tuple(_decode_value(x) for x in v["items"])
    return v


def _node_entries(tree, prefix=""):
    """Manifest entries for typed (non-array) nodes, keyed by tree path."""
    out = []
    if isinstance(tree, PackedWeight):
        entry = {"path": prefix, "kind": "packed_weight",
                 "cfg": {"n": tree.cfg.n, "m": tree.cfg.m,
                         "k": tree.cfg.k},
                 "dense_shape": list(tree.dense_shape),
                 "layout": tree.layout}
        if tree.block_geom is not None:
            entry["block_geom"] = list(tree.block_geom)
        if tree.qdtype is not None:
            entry["qdtype"] = tree.qdtype
        if tree.shards > 1:
            # Renumbered shard-stacked / shard-local provenance: a restore
            # must know the TP geometry the group ids were renumbered for.
            entry["shards"] = tree.shards
            if tree.shard_axis is not None:
                entry["shard_axis"] = tree.shard_axis
        out.append(entry)
    elif isinstance(tree, Static):
        out.append({"path": prefix, "kind": "static",
                    "value": _encode_value(tree.value)})
    elif isinstance(tree, dict):
        for k, v in tree.items():
            out.extend(_node_entries(v, f"{prefix}/{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_node_entries(v, f"{prefix}/{i}" if prefix else str(i)))
    return out


def _patch_nodes(tree, by_path, prefix=""):
    """Overlay manifest node aux onto a restored tree (manifest wins, so the
    saved SparsityConfig — k included — survives a stale template)."""
    if isinstance(tree, PackedWeight):
        e = by_path.get(prefix)
        if e is not None and e["kind"] == "packed_weight":
            cfg = SparsityConfig(**e["cfg"])
            geom = e.get("block_geom")
            qdtype = e.get("qdtype")   # absent in pre-quant manifests
            return PackedWeight(tree.values, tree.indices, cfg=cfg,
                                dense_shape=tuple(e["dense_shape"]),
                                layout=e["layout"],
                                active_groups=tree.active_groups,
                                block_geom=tuple(geom) if geom else None,
                                scales=tree.scales if qdtype else None,
                                qdtype=qdtype,
                                shard_axis=e.get("shard_axis"),
                                shards=int(e.get("shards", 1)))
        return tree
    if isinstance(tree, Static):
        e = by_path.get(prefix)
        if e is not None and e["kind"] == "static":
            return Static(_decode_value(e["value"]))
        return tree
    if isinstance(tree, dict):
        return {k: _patch_nodes(v, by_path, f"{prefix}/{k}" if prefix else k)
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        patched = [_patch_nodes(v, by_path,
                                f"{prefix}/{i}" if prefix else str(i))
                   for i, v in enumerate(tree)]
        if isinstance(tree, tuple) and hasattr(tree, "_fields"):
            return type(tree)(*patched)   # NamedTuple (e.g. optimizer state)
        return type(tree)(patched)
    return tree


def save(tree, directory: str, step: int, *, plan=None) -> str:
    """Synchronous atomic save.  Returns the committed directory.

    ``plan`` (a :class:`~repro.sharding.plan.ShardingPlan`) is serialized
    into the manifest so a restoring process knows the distribution
    geometry the checkpoint was produced under (:func:`load_plan`)."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": [], "nodes": _node_entries(tree)}
    if plan is not None:
        manifest["sharding_plan"] = plan.to_json()
    for path, leaf in _leaf_paths(tree):
        fname = path.replace("/", "__") + ".npy"
        if leaf is None:
            manifest["leaves"].append({"path": path, "kind": "none"})
            continue
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "path": path, "kind": "array", "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(tree, directory: str, step: int, *, plan=None) -> Future:
    host_tree = jax.tree.map(
        lambda x: np.asarray(jax.device_get(x)) if x is not None else x, tree)
    return _EXEC.submit(save, host_tree, directory, step, plan=plan)


def load_plan(directory: str, step: Optional[int] = None):
    """The :class:`~repro.sharding.plan.ShardingPlan` a checkpoint was saved
    with, or None (no plan recorded / pre-plan manifest).  ``step`` defaults
    to the latest committed step."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None
    final = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    blob = manifest.get("sharding_plan")
    if blob is None:
        return None
    from repro.sharding.plan import ShardingPlan
    return ShardingPlan.from_json(blob)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(template, directory: str, step: int, shardings=None):
    """Restore into ``template``'s structure.  ``shardings`` (same structure)
    places every leaf; None leaves restore to host numpy (then committed to
    the default device by jnp.asarray).  Typed nodes (PackedWeight aux,
    Static values) are patched from the manifest, so the checkpoint — not
    the restoring process's template — is authoritative for them."""
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten(template)
    shard_flat = (treedef.flatten_up_to(shardings)
                  if shardings is not None else [None] * len(flat))
    paths = [p for p, _ in _leaf_paths(template)]
    out = []
    for (path, leaf), sh in zip(zip(paths, flat), shard_flat):
        entry = by_path[path]
        if entry["kind"] == "none":
            out.append(None)
            continue
        data = np.load(os.path.join(final, entry["file"]))
        if sh is not None:
            arr = jax.make_array_from_callback(
                tuple(entry["shape"]), sh, lambda idx, d=data: d[idx])
        else:
            arr = jnp.asarray(data)
        out.append(arr)
    restored = treedef.unflatten(out)
    nodes = {e["path"]: e for e in manifest.get("nodes", [])}
    return _patch_nodes(restored, nodes) if nodes else restored
