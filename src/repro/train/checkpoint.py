"""Sharded, atomic, reshard-on-restore checkpointing.

Layout::

    <dir>/step_<N>/
        manifest.json        # pytree structure, shapes, dtypes, paths
        <leaf-path>.npy      # one file per leaf (host-gathered)
    <dir>/step_<N>.tmp/      # staging; os.rename() commits atomically

Restore takes target shardings (possibly for a DIFFERENT mesh than the one
that saved — elastic restarts) and rebuilds global arrays with
``jax.make_array_from_callback``, so each device materializes only its
shard.  ``save_async`` stages device-to-host transfers immediately and
writes on a background thread (training continues).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Static

_EXEC = ThreadPoolExecutor(max_workers=2)


def _leaf_paths(tree):
    paths = []

    def one(path, leaf):
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        paths.append(("/".join(parts), leaf))

    jax.tree_util.tree_map_with_path(one, tree)
    return paths


def save(tree, directory: str, step: int) -> str:
    """Synchronous atomic save.  Returns the committed directory."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": []}
    for path, leaf in _leaf_paths(tree):
        fname = path.replace("/", "__") + ".npy"
        if isinstance(leaf, Static):
            manifest["leaves"].append(
                {"path": path, "kind": "static", "value": leaf.value})
            continue
        if leaf is None:
            manifest["leaves"].append({"path": path, "kind": "none"})
            continue
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "path": path, "kind": "array", "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(tree, directory: str, step: int) -> Future:
    host_tree = jax.tree.map(
        lambda x: np.asarray(jax.device_get(x))
        if x is not None and not isinstance(x, Static) else x, tree)
    return _EXEC.submit(save, host_tree, directory, step)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(template, directory: str, step: int, shardings=None):
    """Restore into ``template``'s structure.  ``shardings`` (same structure)
    places every leaf; None leaves restore to host numpy (then committed to
    the default device by jnp.asarray)."""
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten(template)
    shard_flat = (treedef.flatten_up_to(shardings)
                  if shardings is not None else [None] * len(flat))
    paths = [p for p, _ in _leaf_paths(template)]
    out = []
    for (path, leaf), sh in zip(zip(paths, flat), shard_flat):
        entry = by_path[path]
        if entry["kind"] == "static":
            out.append(Static(entry["value"]))
            continue
        if entry["kind"] == "none":
            out.append(None)
            continue
        data = np.load(os.path.join(final, entry["file"]))
        if sh is not None:
            arr = jax.make_array_from_callback(
                tuple(entry["shape"]), sh, lambda idx, d=data: d[idx])
        else:
            arr = jnp.asarray(data)
        out.append(arr)
    return treedef.unflatten(out)
