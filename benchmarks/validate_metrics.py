"""Validate a ``--metrics-out`` JSON snapshot (CI metrics-smoke gate).

Checks a ``repro.obs`` metrics snapshot against
``benchmarks/metrics_schema.json`` plus content requirements — the schema
proves the *shape*, the ``--require-*`` flags prove the run actually
*observed* something:

    PYTHONPATH=src python benchmarks/validate_metrics.py serve_metrics.json \
        --schema benchmarks/metrics_schema.json \
        --require-counter serve_requests_completed_total \
        --require-counter kernel_dispatch_total \
        --require-histogram serve_decode_token_seconds

``--require-counter NAME`` demands at least one entry of that family (any
labels) with value > 0; ``--require-histogram NAME`` demands count > 0 and
internal consistency (sum(counts) == count, len(counts) == len(buckets)+1);
``--require-gauge NAME`` demands the family exists (gauges legitimately
read 0 — e.g. ``serve_queue_depth`` after a drain — so only presence is
checked); ``--require-sketch NAME`` demands a quantile-sketch family
(obs v2, DESIGN.md §16) with observations and internal consistency
(``sum(bins) + zero_count == count``).

The validator implements the JSON-Schema subset the checked-in schema uses
(type, required, properties, additionalProperties-as-schema, items,
minimum, minItems) by hand — this container has no ``jsonschema`` package
and the repo stays dependency-free.
"""

from __future__ import annotations

import argparse
import json
import sys

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "number": (int, float),
    "integer": int,
    "boolean": bool,
    "null": type(None),
}


def validate(instance, schema: dict, path: str = "$") -> list:
    """Returns a list of 'path: message' error strings (empty == valid)."""
    errors = []
    t = schema.get("type")
    if t is not None:
        py = _TYPES[t]
        ok = isinstance(instance, py)
        if ok and t in ("number", "integer") and isinstance(instance, bool):
            ok = False   # bool is an int subclass; JSON says it isn't
        if not ok:
            errors.append(f"{path}: expected {t}, got "
                          f"{type(instance).__name__}")
            return errors   # deeper checks would only cascade
    if isinstance(instance, dict):
        for key in schema.get("required", ()):
            if key not in instance:
                errors.append(f"{path}: missing required key {key!r}")
        props = schema.get("properties", {})
        for key, sub in props.items():
            if key in instance:
                errors += validate(instance[key], sub, f"{path}.{key}")
        addl = schema.get("additionalProperties")
        if isinstance(addl, dict):
            for key, val in instance.items():
                if key not in props:
                    errors += validate(val, addl, f"{path}.{key}")
    if isinstance(instance, list):
        if "minItems" in schema and len(instance) < schema["minItems"]:
            errors.append(f"{path}: has {len(instance)} items, needs >= "
                          f"{schema['minItems']}")
        items = schema.get("items")
        if isinstance(items, dict):
            for i, val in enumerate(instance):
                errors += validate(val, items, f"{path}[{i}]")
    if isinstance(instance, (int, float)) and not isinstance(instance, bool):
        if "minimum" in schema and instance < schema["minimum"]:
            errors.append(f"{path}: {instance} < minimum "
                          f"{schema['minimum']}")
    return errors


def check_counter(snap: dict, name: str) -> list:
    entries = [c for c in snap.get("counters", []) if c.get("name") == name]
    if not entries:
        return [f"required counter {name!r} is absent"]
    if not any(c.get("value", 0) > 0 for c in entries):
        return [f"required counter {name!r} never incremented "
                f"(all {len(entries)} entries are 0)"]
    return []


def check_gauge(snap: dict, name: str) -> list:
    entries = [g for g in snap.get("gauges", []) if g.get("name") == name]
    if not entries:
        return [f"required gauge {name!r} is absent"]
    return []


def check_histogram(snap: dict, name: str) -> list:
    errors = []
    entries = [h for h in snap.get("histograms", [])
               if h.get("name") == name]
    if not entries:
        return [f"required histogram {name!r} is absent"]
    for h in entries:
        label = f"{name}{h.get('labels') or ''}"
        if len(h["counts"]) != len(h["buckets"]) + 1:
            errors.append(f"{label}: len(counts)={len(h['counts'])} != "
                          f"len(buckets)+1={len(h['buckets']) + 1}")
        if sum(h["counts"]) != h["count"]:
            errors.append(f"{label}: sum(counts)={sum(h['counts'])} != "
                          f"count={h['count']}")
    if not any(h.get("count", 0) > 0 for h in entries):
        errors.append(f"required histogram {name!r} has no observations")
    return errors


def check_sketch(snap: dict, name: str) -> list:
    errors = []
    entries = [s for s in snap.get("sketches", [])
               if s.get("name") == name]
    if not entries:
        return [f"required sketch {name!r} is absent"]
    for s in entries:
        label = f"{name}{s.get('labels') or ''}"
        total = sum(s.get("bins", {}).values()) + s.get("zero_count", 0)
        if total != s.get("count"):
            errors.append(f"{label}: sum(bins)+zero_count={total} != "
                          f"count={s.get('count')}")
    if not any(s.get("count", 0) > 0 for s in entries):
        errors.append(f"required sketch {name!r} has no observations")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("snapshot", help="metrics JSON written by --metrics-out")
    ap.add_argument("--schema", default="benchmarks/metrics_schema.json")
    ap.add_argument("--require-counter", action="append", default=[],
                    metavar="NAME",
                    help="fail unless this counter family exists with a "
                         "nonzero entry (repeatable)")
    ap.add_argument("--require-gauge", action="append", default=[],
                    metavar="NAME",
                    help="fail unless this gauge family is present "
                         "(repeatable)")
    ap.add_argument("--require-histogram", action="append", default=[],
                    metavar="NAME",
                    help="fail unless this histogram family has "
                         "observations and is internally consistent "
                         "(repeatable)")
    ap.add_argument("--require-sketch", action="append", default=[],
                    metavar="NAME",
                    help="fail unless this quantile-sketch family has "
                         "observations and is internally consistent "
                         "(sum(bins)+zero_count == count; repeatable)")
    args = ap.parse_args(argv)

    with open(args.snapshot) as f:
        snap = json.load(f)
    with open(args.schema) as f:
        schema = json.load(f)

    errors = validate(snap, schema)
    for name in args.require_counter:
        errors += check_counter(snap, name)
    for name in args.require_gauge:
        errors += check_gauge(snap, name)
    for name in args.require_histogram:
        errors += check_histogram(snap, name)
    for name in args.require_sketch:
        errors += check_sketch(snap, name)

    if errors:
        print(f"{args.snapshot}: INVALID ({len(errors)} errors)")
        for e in errors:
            print(f"  {e}")
        return 1
    required = (args.require_counter + args.require_gauge
                + args.require_histogram + args.require_sketch)
    print(f"{args.snapshot}: ok ({len(snap.get('counters', []))} counters, "
          f"{len(snap.get('gauges', []))} gauges, "
          f"{len(snap.get('histograms', []))} histograms, "
          f"{len(snap.get('sketches', []))} sketches"
          + (f"; required: {', '.join(required)}" if required else "")
          + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
