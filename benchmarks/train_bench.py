"""Training-step benchmark: packed-vs-dense step time (``repro.sparsetrain``).

Measures one reduced-config model under the training execution modes the
subsystem adds, emitting ``BENCH_train.json`` (uploaded as a CI artifact by
the ``train-smoke`` job):

* ``dense``            — plain dense training step (the baseline).
* ``masked_premask``   — straight-through N:M premasking (the pre-existing
  sparse-training path).
* ``sparsify``         — scheduled masks (``sparsetrain.masks``) applied in
  the step; mask refresh cost is excluded (it amortizes over
  ``update_every`` steps and is reported separately).
* ``sparsify_qat``     — scheduled masks + int8 fake-quant (``ste.py``).
* ``packed_finetune_xwT`` / ``packed_finetune_block`` — a value-only
  fine-tuning step *directly on the packed form* (grad through
  ``ExecPolicy(mode="packed")`` via the custom_vjps of
  ``sparsetrain.vjp``), the sparse-fine-tune scenario the vjp coverage
  unlocks.  Measured on a single representative layer matmul, not the full
  model, since packed execution composes per-layer.

CPU wall-times are indicative (the CI artifact tracks relative drift, not
absolute TPU performance).

    PYTHONPATH=src python benchmarks/train_bench.py [--quick] [--out F]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import sparse_linear as sl
from repro.core.sparse_linear import ExecPolicy
from repro.core.sparsity import SparsityConfig, pack_block, random_sparse_dense
from repro.data.pipeline import DataConfig, global_batch
from repro.models.families import build_model
from repro.optim import adamw
from repro.sparsetrain import init_mask_state, parse_schedule
from repro.train.train_loop import make_train_step

DEFAULT_OUT = "BENCH_train.json"


def _time(fn, *args, warmup=2, iters=5):
    """Returns ``(first_call_ms, steady_ms)``: the first call pays jit
    compilation (tracked separately so compile-time drift never shows up as
    a step-time regression), steady state averages ``iters`` post-warmup
    calls."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    first_ms = (time.perf_counter() - t0) * 1e3
    for _ in range(max(0, warmup - 1)):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return first_ms, (time.perf_counter() - t0) / iters * 1e3   # ms


def bench_model_steps(arch: str, batch: int, seq: int, warmup: int,
                      iters: int):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=100, warmup_steps=5)
    opt = adamw.init(opt_cfg, params)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                          global_batch=batch)
    b = global_batch(data_cfg, 0)

    sched = parse_schedule("2:16", 100)
    masks = init_mask_state(params, sched, 60)["masks"]   # final phase

    cases = []

    def add(name, step_fn, *extra):
        fn = jax.jit(step_fn)
        compile_ms, ms = _time(lambda: fn(params, opt, b, 0, *extra),
                               warmup=warmup, iters=iters)
        cases.append({"name": name, "step_ms": round(ms, 3),
                      "compile_ms": round(compile_ms, 3)})
        print(f"  {name:24s} {ms:9.2f} ms/step "
              f"(compile {compile_ms:7.0f} ms)")

    add("dense", make_train_step(model, opt_cfg,
                                 policy=ExecPolicy(mode="dense")))
    add("masked_premask", make_train_step(model, opt_cfg))
    add("sparsify", make_train_step(model, opt_cfg), masks)
    add("sparsify_qat",
        make_train_step(model, opt_cfg, fake_quant="int8"), masks)

    # mask-refresh cost (amortized over schedule.update_every steps)
    from repro.sparsetrain.masks import build_masks

    t0 = time.perf_counter()
    jax.block_until_ready(jax.tree.leaves(
        build_masks(params, sched, len(sched.phases) - 1))[0])
    refresh_ms = (time.perf_counter() - t0) * 1e3
    print(f"  {'mask_refresh (1x)':24s} {refresh_ms:9.2f} ms "
          f"(every {sched.update_every} steps)")
    return cfg, cases, refresh_ms, sched.update_every


def bench_packed_finetune(warmup: int, iters: int):
    """Value-only fine-tuning grad step directly on the packed forms."""
    cfg = SparsityConfig(8, 128)
    rng = np.random.default_rng(0)
    o, k, bsz = 256, 512, 64
    w = jnp.asarray(random_sparse_dense(rng, o, k, cfg))
    x = jnp.asarray(rng.standard_normal((bsz, k)), jnp.float32)
    y_t = jnp.asarray(rng.standard_normal((bsz, o)), jnp.float32)
    pol = ExecPolicy(mode="packed")
    out = []
    for layout, pw in (("xwT", sl.pack_params({"w": w}, cfg)),
                       ("block", pack_block(w, cfg))):
        @jax.jit
        def step(values, pw=pw):
            def loss(v):
                y = sl.apply(pw.replace(values=v), x, pol)
                return jnp.mean((y - y_t) ** 2)

            g = jax.grad(loss)(values)
            return values - 1e-3 * g

        compile_ms, ms = _time(step, pw.values, warmup=warmup, iters=iters)
        out.append({"name": f"packed_finetune_{layout}",
                    "step_ms": round(ms, 3),
                    "compile_ms": round(compile_ms, 3)})
        print(f"  packed_finetune_{layout:18s} {ms:9.2f} ms/step "
              f"({o}x{k}, batch {bsz})")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer iters, smaller batch")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.quick:
        args.batch, args.seq, args.warmup, args.iters = 2, 32, 1, 3

    print(f"train-step benchmark: arch={args.arch} (reduced) "
          f"batch={args.batch} seq={args.seq}")
    cfg, cases, refresh_ms, update_every = bench_model_steps(
        args.arch, args.batch, args.seq, args.warmup, args.iters)
    cases += bench_packed_finetune(args.warmup, args.iters)

    by_name = {c["name"]: c["step_ms"] for c in cases}
    dense = by_name["dense"]
    from repro import obs

    blob = {
        # run_metadata first: the explicit keys below win on collision
        "meta": {**obs.run_metadata(),
                 "arch": cfg.name, "reduced": True, "batch": args.batch,
                 "seq": args.seq, "iters": args.iters,
                 "platform": jax.default_backend(),
                 "jax": jax.__version__,
                 "mask_refresh_ms": round(refresh_ms, 3),
                 "mask_update_every": update_every},
        "cases": cases,
        "ratios_vs_dense": {c["name"]: round(c["step_ms"] / dense, 3)
                            for c in cases},
    }
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=2)
    print(f"wrote {args.out} (sparsify/dense = "
          f"{blob['ratios_vs_dense']['sparsify']}, sparsify_qat/dense = "
          f"{blob['ratios_vs_dense']['sparsify_qat']})")


if __name__ == "__main__":
    main()
