"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows:
  * fig6_*     — Fig. 6 reproduction (relaxed 8:128 vs S2TA/VEGETA/SPOTS)
  * fig8_*     — Fig. 8 reproduction (fine-grained 1:8/1:4/1:2)
  * kernel_*   — DeMM kernel structural benchmarks (packed-byte roofline)
  * roofline_* — per-(arch×shape) roofline fractions from the dry-run JSONL
                 (requires results/dryrun.jsonl; skipped gracefully if absent)

``--autotune`` additionally drives the ``repro.tune`` autotuner over the
config-zoo matmul shapes and writes ``BENCH_kernels.json`` (tuned vs default
vs dense; see benchmarks/kernel_bench.py).
"""

from __future__ import annotations

import argparse


def main() -> None:
    from benchmarks import fig6_resnet50, fig8_finegrained, kernel_bench
    from benchmarks import roofline as roofline_mod

    ap = argparse.ArgumentParser()
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    rows = []
    print("== Fig. 6: relaxed 8:128 on ResNet50 (paper: 18/54/67%) ==")
    f6 = fig6_resnet50.run(verbose=False)
    rows += f6
    for name, val, derived in f6:
        print(f"{name},{val:.2f},{derived}")

    print("== Fig. 8: fine-grained 1:8/1:4/1:2 (ResNet50+ConvNeXt) ==")
    f8 = fig8_finegrained.run(verbose=False)
    rows += f8
    for name, val, derived in f8:
        print(f"{name},{val:.2f},{derived}")

    print("== DeMM kernel benchmarks ==")
    kb = kernel_bench.run(verbose=False)
    rows += kb
    for name, val, derived in kb:
        print(f"{name},{val:.2f},{derived}")

    print("== Roofline (from dry-run) ==")
    rl = roofline_mod.run(verbose=False)
    rows += rl
    for name, val, derived in rl:
        print(f"{name},{val:.2f},{derived}")
    if not rl:
        print("roofline_skipped,0,run results/run_dryrun.sh first")

    if args.autotune:
        print("== Autotune (repro.tune over the config zoo) ==")
        out = ("BENCH_kernels_quick.json" if args.quick
               else kernel_bench.DEFAULT_OUT)
        blob = kernel_bench.run_autotune(quick=args.quick, out_path=out,
                                         verbose=False)
        for case in blob["cases"]:
            name = f"autotune_{case['name']}_vs_default"
            rows.append((name, case["tuned_vs_default"],
                         f"tuned={case['tuned']['backend']}"))
            print(f"{name},{case['tuned_vs_default']:.2f},"
                  f"tuned={case['tuned']['backend']}{case['tuned']['params']}")

    print(f"== total: {len(rows)} benchmark rows ==")


if __name__ == "__main__":
    main()
