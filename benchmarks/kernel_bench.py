"""DeMM kernel micro-benchmarks (paper §II engine behaviour).

Two modes:

* **structural** (default; ``run()``) — CPU wall-time is meaningless for TPU
  kernels, so this reports the structural quantities that determine TPU
  latency: HBM bytes streamed per GEMM for packed vs dense weights (the
  decoupling win), MXU-aligned block shapes, and the modeled v5e roofline
  time per matmul — plus a CPU interpret-mode correctness timing so the
  harness is runnable offline.

* **autotune** (``--autotune``; ``run_autotune()``) — drives the
  ``repro.tune`` subsystem over the config zoo's matmul shapes: for every
  distinct (shape, dtype, pattern) problem it measures a dense-matmul
  baseline, the heuristic default dispatch, and the full autotuner, then
  writes ``BENCH_kernels.json`` with the tuned-vs-default-vs-dense table.
  The default config is always in the measured candidate set, so the tuned
  choice is never slower than the default on the measured host.  Tuning
  results persist in the ``repro.tune`` cache for later serving runs.

    PYTHONPATH=src python benchmarks/kernel_bench.py --autotune [--quick]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import (SparsityConfig, pack, pack_block, prune,
                                 random_sparse_dense)
from repro.kernels.demm_spmm import demm_xwT_pallas
from repro.kernels.ref import xwT_ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9

# (name, out, in, batch_tokens, pattern)
CASES = [
    ("mlp_gate_decode", 6912, 2560, 8, SparsityConfig(8, 128)),
    ("mlp_down_decode", 2560, 6912, 8, SparsityConfig(8, 128)),
    ("attn_qkv_decode", 4096, 4096, 8, SparsityConfig(8, 128)),
    ("mlp_gate_prefill", 6912, 2560, 2048, SparsityConfig(8, 128)),
    ("finegrained_1:4", 4096, 4096, 8, SparsityConfig(1, 4)),
]

DEFAULT_OUT = "BENCH_kernels.json"

# Two-level block-layout (xwT_block) cases: (name, out, in, batch, pattern).
# Shapes are kept under the interpret-mode FLOP limit so the Pallas block
# kernel is a measurable candidate on CPU hosts too.
BLOCK_CASES = [
    ("block_mlp_decode", 256, 512, 64, SparsityConfig(8, 128)),
    ("block_attn_decode", 256, 256, 128, SparsityConfig(8, 128)),
]

# int8-quantized cases (repro.quant, w8a16 kernels): one per quantized op so
# the CI smoke gates int8 tuned-vs-dense ratios on every jax matrix leg.
Q8_CASES = [
    ("q8_mlp_decode", 256, 512, 8, SparsityConfig(8, 128)),
]
Q8_BLOCK_CASES = [
    ("q8_block_mlp_decode", 256, 512, 64, SparsityConfig(8, 128)),
]


def roofline_time(flops, bytes_):
    return max(flops / PEAK_FLOPS, bytes_ / HBM_BW)


def run(verbose: bool = True):
    rows = []
    for name, o, k, bt, sp in CASES:
        dense_w_bytes = o * k * 2                        # bf16
        g = k // sp.m
        packed_bytes = o * g * sp.n_effective * (2 + 1)  # bf16 + int8 idx
        act_bytes = bt * (k + o) * 2
        flops = 2 * bt * o * k                           # dense-equiv MXU
        t_dense = roofline_time(flops, dense_w_bytes + act_bytes)
        t_packed = roofline_time(flops, packed_bytes + act_bytes)
        speedup = t_dense / t_packed
        rows.append((f"kernel_{name}_v5e_speedup", speedup,
                     f"w_bytes {dense_w_bytes} -> {packed_bytes}"))
        if verbose:
            print(f"{name:22s} weights {dense_w_bytes/1e6:7.2f}MB -> "
                  f"{packed_bytes/1e6:6.2f}MB packed | modeled v5e "
                  f"{t_dense*1e6:8.2f}us -> {t_packed*1e6:8.2f}us "
                  f"({speedup:4.1f}x)")

    # correctness + interpret-mode wall time for one case
    rng = np.random.default_rng(0)
    sp = SparsityConfig(8, 128)
    w = random_sparse_dense(rng, 512, 1024, sp)
    x = rng.standard_normal((128, 1024)).astype(np.float32)
    p = pack(jnp.asarray(w, jnp.float32), sp)
    t0 = time.time()
    got = demm_xwT_pallas(jnp.asarray(x), p.values, p.indices, sp,
                          interpret=True)
    got.block_until_ready()
    dt = time.time() - t0
    want = xwT_ref(jnp.asarray(x), p.values, p.indices, sp, (512, 1024))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)
    rows.append(("kernel_interpret_roundtrip", dt * 1e6, "allclose=True"))
    if verbose:
        print(f"interpret-mode validation (512x1024 @ 8:128): "
              f"{dt*1e3:.0f}ms, allclose vs oracle [ok]")
    return rows


# ---------------------------------------------------------------------------
# Autotune mode
# ---------------------------------------------------------------------------

def _zoo_cases(quick: bool):
    """Distinct xwT problems from the config zoo (reduced shapes: the full
    decode/prefill shapes are covered by CASES and tile-tuned on TPU)."""
    from repro.configs.base import ARCH_IDS, get_arch

    arch_ids = ARCH_IDS[:3] if quick else ARCH_IDS
    cases = []
    for aid in arch_ids:
        cfg = get_arch(aid).reduced()
        if cfg.sparsity is None:
            continue
        sp = cfg.sparsity
        d, f = cfg.d_model, cfg.d_ff or cfg.d_model
        cases.append((f"{aid}_mlp_up_decode", f, d, 8, sp))
        cases.append((f"{aid}_mlp_down_decode", d, f, 8, sp))
        if cfg.moe:
            cases.append((f"{aid}_expert_up_decode",
                          cfg.moe.d_ff_expert, d, 8, sp))
    if not quick:
        # production decode shapes; batch capped so the CPU dense baseline
        # stays measurable (TPU hosts see the same tile spaces regardless)
        cases += [(f"zoo_{n}", o, k, min(bt, 128), sp)
                  for n, o, k, bt, sp in CASES]
    return cases


def _measure_thunk(thunk, warmup, iters):
    """Returns ``(first_call_s, steady_s)``: the first call pays jit
    compilation, steady state is the min over ``iters`` fenced calls
    (``repro.tune.measure``'s estimator) — reported separately so compile
    time never pollutes the tuned-vs-dense steady-state ratios."""
    t0 = time.perf_counter()
    jax.block_until_ready(thunk())
    first = time.perf_counter() - t0
    for _ in range(max(0, warmup - 1)):
        jax.block_until_ready(thunk())
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        best = min(best, time.perf_counter() - t0)
    return first, best


def _case_entry(name, key, shape, t_dense, default, t_default, res,
                verbose, t_dense_compile=None, t_default_compile=None):
    """Shared tuned-vs-default-vs-dense record (one schema for every op —
    benchmarks/compare_bench.py parses these)."""
    # the default was measured twice (eagerly above and inside the tuner);
    # keep the tuned<=default invariant against the tuner's own measurement.
    tuner_default_us = min(
        (c.measured_s * 1e6 for c in res.candidates
         if c.backend == default.backend and c.params == default.params
         and c.measured_s is not None), default=t_default * 1e6)
    entry = {
        "name": name,
        "problem": key,
        "shape": shape,
        "dense_us": t_dense * 1e6,
        "dense_compile_us": (None if t_dense_compile is None
                             else t_dense_compile * 1e6),
        "default": {"backend": default.backend,
                    "params": default.params,
                    "us": t_default * 1e6,
                    "compile_us": (None if t_default_compile is None
                                   else t_default_compile * 1e6)},
        "tuned": {"backend": res.best.backend,
                  "params": res.best.params,
                  "us": res.best.measured_us},
        "tuned_vs_default": tuner_default_us / res.best.measured_us,
        "dense_vs_tuned": t_dense * 1e6 / res.best.measured_us,
        "candidates": res.table(),
    }
    if verbose:
        print(f"{name:28s} dense {t_dense*1e6:9.1f}us | default "
              f"{default.backend:18s} {t_default*1e6:9.1f}us | tuned "
              f"{res.best.backend}{res.best.params} "
              f"{res.best.measured_us:9.1f}us "
              f"({entry['tuned_vs_default']:.2f}x vs default)")
    return entry


def run_autotune(quick: bool = False, out_path: str = DEFAULT_OUT,
                 verbose: bool = True, warmup: "int | None" = None,
                 iters: "int | None" = None):
    from repro import tune

    default_w, default_i = (1, 2) if quick else (2, 5)
    warmup = default_w if warmup is None else warmup
    iters = default_i if iters is None else iters
    max_measure = 4 if quick else 8
    rng = np.random.default_rng(0)
    seen = set()
    results = []
    for name, o, k, bt, sp in _zoo_cases(quick):
        problem = tune.Problem.for_xwT((bt, k), (o, k), sp, jnp.float32)
        key = tune.problem_key(problem)
        if key in seen:
            continue
        seen.add(key)

        w_dense = jnp.asarray(prune(jnp.asarray(
            rng.standard_normal((o, k)).astype(np.float32)), sp))
        p = pack(w_dense, sp)
        x = jnp.asarray(rng.standard_normal((bt, k)).astype(np.float32))

        # 1. dense baseline (what serving pays without the paper's format)
        dense_mm = jax.jit(lambda xx, ww: xx @ ww.T)
        t_dense_c, t_dense = _measure_thunk(
            lambda: dense_mm(x, w_dense), warmup, iters)

        # 2. heuristic default dispatch (the pre-tuning hardcoded choice),
        #    jitted like the tuner measures and like serving dispatches
        default = tune.heuristic_default(problem)
        dvar = tune.get_variant("xwT", default.backend)
        default_jf = jax.jit(lambda xx, vv, ii: dvar.call(
            xx, vv, ii, sp, (o, k), **default.params))
        t_default_c, t_default = _measure_thunk(
            lambda: default_jf(x, p.values, p.indices), warmup, iters)

        # 3. full autotune (defaults are always in the measured set, so
        #    tuned <= default on this host by construction)
        res = tune.autotune_xwT(x, p.values, p.indices, sp, (o, k),
                                max_measure=max_measure, warmup=warmup,
                                iters=iters, persist=True)
        results.append(_case_entry(
            name, key, {"out": o, "k": k, "batch": bt,
                        "pattern": sp.pattern_name()},
            t_dense, default, t_default, res, verbose,
            t_dense_compile=t_dense_c, t_default_compile=t_default_c))

    # --- two-level block layout (xwT_block dispatch) ----------------------
    for name, o, k, bt, sp in BLOCK_CASES[:1 if quick else None]:
        w_dense = jnp.asarray(prune(jnp.asarray(
            rng.standard_normal((o, k)).astype(np.float32)), sp))
        pw = pack_block(w_dense, sp)
        x = jnp.asarray(rng.standard_normal((bt, k)).astype(np.float32))
        problem = tune.Problem.for_xwT_block(x.shape, pw, jnp.float32)
        key = tune.problem_key(problem)
        if key in seen:
            continue
        seen.add(key)

        dense_mm = jax.jit(lambda xx, ww: xx @ ww.T)
        t_dense_c, t_dense = _measure_thunk(
            lambda: dense_mm(x, w_dense), warmup, iters)

        default = tune.heuristic_default(problem)
        dvar = tune.get_variant("xwT_block", default.backend)
        default_jf = jax.jit(lambda xx, vv, ii, ag: dvar.call(
            xx, vv, ii, ag, sp, (o, k), **default.params))
        t_default_c, t_default = _measure_thunk(
            lambda: default_jf(x, pw.values, pw.indices, pw.active_groups),
            warmup, iters)

        res = tune.autotune_xwT_block(x, pw, max_measure=max_measure,
                                      warmup=warmup, iters=iters,
                                      persist=True)
        results.append(_case_entry(
            name, key, {"out": o, "k": k, "batch": bt,
                        "pattern": sp.pattern_name(),
                        "block_geom": list(pw.block_geom)},
            t_dense, default, t_default, res, verbose,
            t_dense_compile=t_dense_c, t_default_compile=t_default_c))

    # --- int8 quantized packed weights (repro.quant, w8a16 dispatch) ------
    from repro.quant import quantize_packed

    for name, o, k, bt, sp in Q8_CASES:
        w_dense = jnp.asarray(prune(jnp.asarray(
            rng.standard_normal((o, k)).astype(np.float32)), sp))
        p = pack(w_dense, sp)
        from repro.core.sparsity import PackedWeight
        q = quantize_packed(PackedWeight(p.values, p.indices, cfg=sp,
                                         dense_shape=(o, k)))
        x = jnp.asarray(rng.standard_normal((bt, k)).astype(np.float32))
        problem = tune.Problem.for_xwT((bt, k), (o, k), sp, jnp.float32,
                                       quantized=True)
        key = tune.problem_key(problem)
        if key in seen:
            continue
        seen.add(key)

        dense_mm = jax.jit(lambda xx, ww: xx @ ww.T)
        t_dense_c, t_dense = _measure_thunk(
            lambda: dense_mm(x, w_dense), warmup, iters)

        default = tune.heuristic_default(problem)
        dvar = tune.get_variant("xwT_q8", default.backend)
        default_jf = jax.jit(lambda xx, vv, ii, ss: dvar.call(
            xx, vv, ii, ss, sp, (o, k), **default.params))
        t_default_c, t_default = _measure_thunk(
            lambda: default_jf(x, q.values, q.indices, q.scales),
            warmup, iters)

        res = tune.autotune_xwT_q8(x, q.values, q.indices, q.scales, sp,
                                   (o, k), max_measure=max_measure,
                                   warmup=warmup, iters=iters, persist=True)
        results.append(_case_entry(
            name, key, {"out": o, "k": k, "batch": bt,
                        "pattern": sp.pattern_name(), "qdtype": "int8"},
            t_dense, default, t_default, res, verbose,
            t_dense_compile=t_dense_c, t_default_compile=t_default_c))

    for name, o, k, bt, sp in Q8_BLOCK_CASES:
        w_dense = jnp.asarray(prune(jnp.asarray(
            rng.standard_normal((o, k)).astype(np.float32)), sp))
        q = quantize_packed(pack_block(w_dense, sp))
        x = jnp.asarray(rng.standard_normal((bt, k)).astype(np.float32))
        problem = tune.Problem.for_xwT_block(x.shape, q, jnp.float32)
        key = tune.problem_key(problem)
        if key in seen:
            continue
        seen.add(key)

        dense_mm = jax.jit(lambda xx, ww: xx @ ww.T)
        t_dense_c, t_dense = _measure_thunk(
            lambda: dense_mm(x, w_dense), warmup, iters)

        default = tune.heuristic_default(problem)
        dvar = tune.get_variant("xwT_block_q8", default.backend)
        default_jf = jax.jit(lambda xx, vv, ii, ag, ss: dvar.call(
            xx, vv, ii, ag, ss, sp, (o, k), **default.params))
        t_default_c, t_default = _measure_thunk(
            lambda: default_jf(x, q.values, q.indices, q.active_groups,
                               q.scales), warmup, iters)

        res = tune.autotune_xwT_block(x, q, max_measure=max_measure,
                                      warmup=warmup, iters=iters,
                                      persist=True)
        results.append(_case_entry(
            name, key, {"out": o, "k": k, "batch": bt,
                        "pattern": sp.pattern_name(),
                        "block_geom": list(q.block_geom), "qdtype": "int8"},
            t_dense, default, t_default, res, verbose,
            t_dense_compile=t_dense_c, t_default_compile=t_default_c))

    from repro import obs

    blob = {
        "platform": tune.current_platform(),
        "jax": jax.__version__,
        "generated_by": "benchmarks/kernel_bench.py --autotune"
                        + (" --quick" if quick else ""),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        # host/python/jax provenance so two BENCH files are comparable
        # (or visibly not) before comparing their numbers
        "meta": obs.run_metadata(),
        "cases": results,
    }
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=2)
    if verbose:
        print(f"wrote {out_path} ({len(results)} cases, platform="
              f"{blob['platform']})")
    return blob


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--autotune", action="store_true",
                    help="measure tuned vs default vs dense across the "
                         "config zoo and write BENCH_kernels.json")
    ap.add_argument("--quick", action="store_true",
                    help="reduced case set / iterations (CI smoke)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="output JSON path for --autotune")
    ap.add_argument("--warmup", type=int, default=None,
                    help="override warmup iterations (CI regression runs "
                         "want more than the quick default of 1)")
    ap.add_argument("--iters", type=int, default=None,
                    help="override timed iterations per candidate")
    args = ap.parse_args()
    if args.autotune or args.quick:
        out = args.out
        if args.quick and out == DEFAULT_OUT:
            # quick runs (reduced cases/iters) must never clobber the
            # committed full benchmark trajectory.  They default to
            # BENCH_kernels_quick.json — the *committed CI regression
            # baseline* — so running `--quick` without `--out` IS the
            # rebaseline flow (the diff shows up in git); CI itself passes
            # `--out BENCH_kernels_quick_ci.json` and compares against the
            # committed file (benchmarks/compare_bench.py).
            out = "BENCH_kernels_quick.json"
        run_autotune(quick=args.quick, out_path=out, warmup=args.warmup,
                     iters=args.iters)
    if not args.autotune:
        run()


if __name__ == "__main__":
    main()
