"""DeMM kernel micro-benchmarks (paper §II engine behaviour).

CPU wall-time is meaningless for TPU kernels, so this benchmark reports the
structural quantities that determine TPU latency: HBM bytes streamed per
GEMM for packed vs dense weights (the decoupling win), MXU-aligned block
shapes, and the modeled v5e roofline time per matmul — plus a CPU
interpret-mode correctness timing so the harness is runnable offline.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import SparsityConfig, pack, random_sparse_dense
from repro.kernels.demm_spmm import demm_xwT_pallas
from repro.kernels.ref import xwT_ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9

# (name, out, in, batch_tokens, pattern)
CASES = [
    ("mlp_gate_decode", 6912, 2560, 8, SparsityConfig(8, 128)),
    ("mlp_down_decode", 2560, 6912, 8, SparsityConfig(8, 128)),
    ("attn_qkv_decode", 4096, 4096, 8, SparsityConfig(8, 128)),
    ("mlp_gate_prefill", 6912, 2560, 2048, SparsityConfig(8, 128)),
    ("finegrained_1:4", 4096, 4096, 8, SparsityConfig(1, 4)),
]


def roofline_time(flops, bytes_):
    return max(flops / PEAK_FLOPS, bytes_ / HBM_BW)


def run(verbose: bool = True):
    rows = []
    for name, o, k, bt, sp in CASES:
        dense_w_bytes = o * k * 2                        # bf16
        g = k // sp.m
        packed_bytes = o * g * sp.n_effective * (2 + 1)  # bf16 + int8 idx
        act_bytes = bt * (k + o) * 2
        flops = 2 * bt * o * k                           # dense-equiv MXU
        t_dense = roofline_time(flops, dense_w_bytes + act_bytes)
        t_packed = roofline_time(flops, packed_bytes + act_bytes)
        speedup = t_dense / t_packed
        rows.append((f"kernel_{name}_v5e_speedup", speedup,
                     f"w_bytes {dense_w_bytes} -> {packed_bytes}"))
        if verbose:
            print(f"{name:22s} weights {dense_w_bytes/1e6:7.2f}MB -> "
                  f"{packed_bytes/1e6:6.2f}MB packed | modeled v5e "
                  f"{t_dense*1e6:8.2f}us -> {t_packed*1e6:8.2f}us "
                  f"({speedup:4.1f}x)")

    # correctness + interpret-mode wall time for one case
    rng = np.random.default_rng(0)
    sp = SparsityConfig(8, 128)
    w = random_sparse_dense(rng, 512, 1024, sp)
    x = rng.standard_normal((128, 1024)).astype(np.float32)
    p = pack(jnp.asarray(w, jnp.float32), sp)
    t0 = time.time()
    got = demm_xwT_pallas(jnp.asarray(x), p.values, p.indices, sp,
                          interpret=True)
    got.block_until_ready()
    dt = time.time() - t0
    want = xwT_ref(jnp.asarray(x), p.values, p.indices, sp, (512, 1024))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)
    rows.append(("kernel_interpret_roundtrip", dt * 1e6, "allclose=True"))
    if verbose:
        print(f"interpret-mode validation (512x1024 @ 8:128): "
              f"{dt*1e3:.0f}ms, allclose vs oracle [ok]")
    return rows


if __name__ == "__main__":
    run()
