"""Roofline table aggregation: read the dry-run JSONL and emit the
per-(arch × shape) three-term roofline table.

Usage: PYTHONPATH=src python -m benchmarks.roofline [results/dryrun.jsonl]
"""

from __future__ import annotations

import json
import os
import sys

RESULTS = "results/dryrun.jsonl"


def load(path=RESULTS):
    recs = {}
    if not os.path.exists(path):
        return recs
    for line in open(path):
        r = json.loads(line)
        key = (r.get("arch"), r.get("shape"), r.get("mesh"))
        recs[key] = r  # last record wins (reruns)
    return recs


def table(recs, mesh="pod16x16"):
    rows = []
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or "error" in r:
            continue
        rl = r["roofline"]
        rows.append({
            "arch": arch, "shape": shape,
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "useful_ratio": r.get("useful_flops_ratio", 0.0),
            "peak_gb": r["memory_analysis"]["peak_bytes"] / 1e9,
            "roofline_fraction": rl["compute_s"] / max(
                rl["compute_s"], rl["memory_s"], rl["collective_s"]),
        })
    return rows


def run(verbose=True, path=RESULTS):
    recs = load(path)
    rows = table(recs)
    out = []
    if verbose:
        print(f"{'arch':24s}{'shape':13s}{'compute':>9s}{'memory':>9s}"
              f"{'collect':>9s}  {'dominant':12s}{'useful':>7s}{'frac':>6s}"
              f"{'mem/dev':>9s}")
        for r in rows:
            print(f"{r['arch']:24s}{r['shape']:13s}{r['compute_s']:9.3f}"
                  f"{r['memory_s']:9.3f}{r['collective_s']:9.3f}  "
                  f"{r['dominant']:12s}{r['useful_ratio']:7.2f}"
                  f"{r['roofline_fraction']:6.2f}{r['peak_gb']:8.1f}G")
    for r in rows:
        out.append((f"roofline_{r['arch']}_{r['shape']}",
                    r["roofline_fraction"] * 100,
                    f"dominant={r['dominant']}"))
    errors = [(k, v["error"][:80]) for k, v in recs.items() if "error" in v]
    if verbose and errors:
        print(f"\n{len(errors)} cells with errors:")
        for k, e in errors:
            print(" ", k, e)
    return out


if __name__ == "__main__":
    run(path=sys.argv[1] if len(sys.argv) > 1 else RESULTS)
