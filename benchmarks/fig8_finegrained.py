"""Fig. 8 reproduction: overall latency on ResNet50 + ConvNeXt at
fine-grained 1:8 / 1:4 / 1:2, DeMM(8,128,64,8) (k-reconfigured) vs S2TA and
VEGETA configured natively at each pattern (their optimal conditions).
SPOTS is omitted, as in the paper (no contiguous zero groups to skip).

Paper claims (average DeMM improvement, ResNet50+ConvNeXt):
  1:8 -> 29% vs S2TA, 39% vs VEGETA
  1:4 -> 19% vs S2TA, 12% vs VEGETA
  1:2 -> 14% vs S2TA,  5% vs VEGETA

Reproduction note (DESIGN.md §7): the DeMM
paper does not specify S2TA's DBB internals; our S2TA model is an idealized
output-stationary tensor array that saturates its 512 MACs at exact N:M
patterns, i.e. it is *stronger* than the silicon S2TA.  The DeMM-vs-S2TA
numbers below are therefore conservative lower bounds; the VEGETA comparison
reproduces the paper's density trend.
"""

from __future__ import annotations

import numpy as np

from repro.core.perfmodel import (
    FINEGRAINED_ENGINES,
    convnext_t_gemms,
    improvement,
    nm_mask,
    resnet50_gemms,
    run_network,
)

PAPER_CLAIMS = {(1, 8): (29, 39), (1, 4): (19, 12), (1, 2): (14, 5)}


def run(verbose: bool = True):
    rows = []
    for (n, m), (claim_s2ta, claim_veg) in PAPER_CLAIMS.items():
        imps_s, imps_v = [], []
        for net_name, gemms in (("resnet50", resnet50_gemms()),
                                ("convnext", convnext_t_gemms())):
            engines = FINEGRAINED_ENGINES(n, m)
            res = run_network(engines, gemms,
                              lambda rng, s: nm_mask(rng, s.r, s.k, n, m),
                              seed=1)
            names = [e.name for e in engines]
            imps_s.append(improvement(res, names[0], names[1]))
            imps_v.append(improvement(res, names[0], names[2]))
        s, v = float(np.mean(imps_s)) * 100, float(np.mean(imps_v)) * 100
        rows.append((f"fig8_1:{m}_vs_S2TA", s, f"paper_claim={claim_s2ta}%"))
        rows.append((f"fig8_1:{m}_vs_VEGETA", v, f"paper_claim={claim_veg}%"))
        if verbose:
            print(f"1:{m}: DeMM vs S2TA {s:+.1f}% (paper {claim_s2ta}%), "
                  f"vs VEGETA {v:+.1f}% (paper {claim_veg}%)")
    return rows


if __name__ == "__main__":
    run()
