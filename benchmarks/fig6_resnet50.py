"""Fig. 6 reproduction: per-layer ResNet50 latency @ relaxed 8:128 (95%
RigL-style unstructured masks), DeMM(8,128,64,8) vs S2TA vs VEGETA vs SPOTS,
all at 512 MACs / 500 MHz.

Paper claims (overall latency improvement of DeMM): 18% vs S2TA, 54% vs
VEGETA, 67% vs SPOTS.
"""

from __future__ import annotations

from repro.core.perfmodel import (
    CLOCK_HZ,
    PAPER_ENGINES_RELAXED,
    improvement,
    resnet50_gemms,
    run_network,
    unstructured_mask,
)

PAPER_CLAIMS = {"S2TA": 0.18, "VEGETA": 0.54, "SPOTS": 0.67}


def run(verbose: bool = True):
    gemms = resnet50_gemms()
    engines = PAPER_ENGINES_RELAXED()
    results = run_network(
        engines, gemms,
        lambda rng, s: unstructured_mask(rng, s.r, s.k, 0.95), seed=0)
    names = [e.name for e in engines]
    rows = []
    if verbose:
        print(f"{'layer':<16}" + "".join(f"{n:>22}" for n in names))
        for s in gemms:
            print(f"{s.name:<16}" + "".join(
                f"{results[n][s.name]:>22,}" for n in names))
    totals = {n: sum(results[n].values()) for n in names}
    out = {}
    for n in names:
        us = totals[n] / CLOCK_HZ * 1e6
        rows.append((f"fig6_total_{n}", us, f"cycles={totals[n]}"))
    for other, claim in zip(names[1:], ("18%", "54%", "67%")):
        imp = improvement(results, names[0], other)
        key = other.split("(")[0].replace("-S", "")
        rows.append((f"fig6_improvement_vs_{key}", imp * 100,
                     f"paper_claim={claim}"))
        if verbose:
            print(f"DeMM improvement vs {other}: {imp*100:.1f}% "
                  f"(paper: {claim})")
    return rows


if __name__ == "__main__":
    run()
