"""Benchmark-regression gate: compare two kernel_bench autotune JSONs.

    python benchmarks/compare_bench.py BASELINE.json NEW.json \
        [--threshold 1.25] [--absolute] [--min-us 0]

For every case present in *both* files the tuned-path cost is compared and
the script exits 1 if any case regressed beyond ``--threshold`` (default
1.25 = 25% slower, the CI gate).

By default the compared metric is ``best_us / dense_us`` — the fastest
measured candidate normalized by the dense matmul measured *in the same run
on the same host*.  CI runners and dev machines differ wildly in absolute
speed, so raw microseconds would gate on machine lottery; the dense-relative
ratio keeps the check about the *kernels* (a dispatch-layer or kernel
regression moves tuned relative to dense on any host).  ``best_us`` is the
min over the case's measured candidate table (not just the selected winner):
with few timing iterations the winner can flip between near-tied variants,
and the min over the shared candidate set is stable against those flips
while still catching a real regression (which slows every variant of the
affected kernel).  ``--absolute`` switches the numerator comparison to raw
microseconds for same-host trend tracking.

Cases only in one file (new benchmarks, renamed cases) are reported and
skipped; ``--min-us`` skips cases whose tuned time is below the floor in
both files (sub-noise microbenchmarks).
"""

from __future__ import annotations

import argparse
import json
import sys


def load_cases(path: str):
    with open(path) as f:
        blob = json.load(f)
    return {c["name"]: c for c in blob.get("cases", [])}, blob


def best_us(case: dict) -> float:
    """Fastest measured candidate (falls back to the selected winner)."""
    measured = [c["measured_us"] for c in case.get("candidates", [])
                if c.get("measured_us") is not None]
    best = min(measured, default=None)
    return case["tuned"]["us"] if best is None else min(best,
                                                        case["tuned"]["us"])


def metric(case: dict, absolute: bool) -> float:
    us = best_us(case)
    if absolute:
        return us
    dense = case.get("dense_us") or 0.0
    return us / dense if dense > 0 else float("inf")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if any tuned benchmark case regressed vs baseline")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("new", help="freshly generated JSON to check")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="max allowed new/baseline metric ratio "
                         "(default 1.25 = 25%% regression)")
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw tuned_us instead of tuned/dense ratios")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="skip cases with tuned_us below this floor in both "
                         "files (sub-noise microbenchmarks)")
    args = ap.parse_args(argv)

    base_cases, base_blob = load_cases(args.baseline)
    new_cases, new_blob = load_cases(args.new)
    unit = "tuned_us" if args.absolute else "tuned/dense"
    print(f"baseline: {args.baseline} (platform={base_blob.get('platform')}, "
          f"jax={base_blob.get('jax')})")
    print(f"new     : {args.new} (platform={new_blob.get('platform')}, "
          f"jax={new_blob.get('jax')})")
    print(f"metric  : {unit}, threshold {args.threshold:.2f}x\n")

    shared = sorted(set(base_cases) & set(new_cases))
    for only, names in (("baseline-only", set(base_cases) - set(new_cases)),
                        ("new-only", set(new_cases) - set(base_cases))):
        if names:
            print(f"[skip] {only} cases: {', '.join(sorted(names))}")
    if not shared:
        print("no shared cases to compare — failing closed")
        return 1

    regressions = []
    w = max(len(n) for n in shared)
    for name in shared:
        b, n = base_cases[name], new_cases[name]
        if (args.min_us and b["tuned"]["us"] < args.min_us
                and n["tuned"]["us"] < args.min_us):
            print(f"{name:{w}s}  skipped (< {args.min_us}us)")
            continue
        mb, mn = metric(b, args.absolute), metric(n, args.absolute)
        ratio = mn / mb if mb > 0 else float("inf")
        flag = "REGRESSED" if ratio > args.threshold else "ok"
        print(f"{name:{w}s}  base {mb:10.3f}  new {mn:10.3f}  "
              f"({ratio:5.2f}x)  {flag}")
        if ratio > args.threshold:
            regressions.append((name, ratio, b["tuned"], n["tuned"]))

    if regressions:
        print(f"\n{len(regressions)} case(s) regressed > "
              f"{(args.threshold - 1) * 100:.0f}%:")
        for name, ratio, bt, nt in regressions:
            print(f"  {name}: {ratio:.2f}x  "
                  f"(baseline {bt['backend']}{bt['params']} "
                  f"{bt['us']:.1f}us -> new {nt['backend']}{nt['params']} "
                  f"{nt['us']:.1f}us)")
        return 1
    print("\nno tuned-path regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
