"""Traffic-replay serving benchmark: paged engine vs legacy dense-cache loop.

    PYTHONPATH=src python benchmarks/serve_bench.py \
        --trace benchmarks/traces/tiny_trace.jsonl --out BENCH_serve.json \
        [--compare BENCH_serve_quick.json --threshold 1.25 \
         --min-prefill-speedup 3.0]

Replays traffic (a committed JSONL trace, or Poisson arrivals synthesized
from ``--seed``/``--rate``) through BOTH serving engines on the same model
and prompts:

* the legacy :class:`~repro.serve.serve_loop.ServeEngine` (dense
  ``num_slots × max_len`` cache, token-by-token prefill through the decode
  step), and
* the :class:`~repro.paged.PagedServeEngine` (shared paged KV arena,
  chunked prefill as a second compiled program, scheduled admission +
  preemption).

Arrivals are **logical engine ticks** (``arrival_tick``), not wall-clock —
so admission order, preemption count, and every token of output are
deterministic across hosts and jax versions; only the latencies differ.
The emitted ``BENCH_serve.json`` carries p50/p99 TTFT + end-to-end latency,
decode and prefill tokens/sec, and peak arena occupancy for both engines,
plus the cross-engine checks the CI gate consumes:

* ``token_identical`` — paged and dense decode emitted identical tokens for
  every request (hard failure if not);
* ``prefill_speedup`` — chunked-prefill tokens/sec over the token-by-token
  baseline, measured by a prefill-only drain (``max_new=1``) on each engine
  (``--min-prefill-speedup`` turns it into a gate);
* ``rel`` — same-host paged/legacy ratios (lower = better), the unit
  ``--compare`` gates with the kernel-bench 25%-regression idiom: absolute
  latencies gate on machine lottery, the *ratio* between two engines
  measured in the same process is stable.

``--spec N:M`` adds a third leg: self-speculative decoding (repro.spec,
DESIGN.md §15) on packed weights — a packed non-spec engine and a packed
spec engine replay the same trace, and the leg reports acceptance rate,
committed window columns per full-tier dispatch, and the spec/non-spec
tokens/sec ratio, gating token identity and ``--min-acceptance``.  Pair it
with ``--sparsity`` (e.g. ``--sparsity 8:16 --spec 6:16``) so the packed
pattern has a tier the draft can narrow.  ``--min-spec-speedup`` turns the
throughput ratio into a gate too — meaningful only on memory-bandwidth-
bound accelerators: on the CPU reference backend a draft step densifies
the same weights as a full step, so drafting costs compute it cannot save
and the dispatch-normalized ``tokens_per_dispatch`` is the portable
signal.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

DEFAULT_TRACE = os.path.join(os.path.dirname(__file__), "traces",
                             "tiny_trace.jsonl")
_WARM_UID = 10 ** 9


def load_trace(path: str):
    reqs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            reqs.append(json.loads(line))
    for i, r in enumerate(reqs):
        for key in ("uid", "arrival_tick", "prompt_len", "max_new"):
            if key not in r:
                raise ValueError(f"{path}:{i}: missing {key!r}")
        r.setdefault("priority", 1)
    return sorted(reqs, key=lambda r: (r["arrival_tick"], r["uid"]))


def poisson_trace(n: int, rate: float, seed: int, max_prompt: int,
                  max_new: int):
    """Synthesize ``n`` arrivals with exponential inter-arrival ticks."""
    rng = np.random.default_rng(seed)
    tick, reqs = 0, []
    for uid in range(n):
        tick += int(rng.exponential(1.0 / max(rate, 1e-6)))
        reqs.append({"uid": uid, "arrival_tick": tick,
                     "prompt_len": int(rng.integers(4, max_prompt + 1)),
                     "max_new": max_new,
                     "priority": int(rng.integers(0, 3))})
    return reqs


def make_prompt(seed: int, uid: int, length: int, vocab: int) -> np.ndarray:
    """Per-request deterministic prompt: replayable from (seed, uid)."""
    rng = np.random.default_rng((seed, uid))
    return rng.integers(0, vocab, length, dtype=np.int32)


def _requests(trace, seed, vocab, uid_offset=0, max_new=None):
    from repro.serve.serve_loop import Request

    return [(r["arrival_tick"],
             Request(uid=r["uid"] + uid_offset,
                     prompt=make_prompt(seed, r["uid"], r["prompt_len"],
                                        vocab),
                     max_new_tokens=max_new or r["max_new"],
                     priority=r["priority"]))
            for r in trace]


def replay(engine, pairs, max_ticks=100000):
    """Tick-driven replay: submit at each request's arrival tick, step until
    drained.  Returns (wall_seconds, ticks, peak_occupancy)."""
    pending = sorted(pairs, key=lambda p: p[0])
    peak_occ, ticks, i = 0.0, 0, 0
    t0 = time.perf_counter()
    while i < len(pending) or _busy(engine):
        while i < len(pending) and pending[i][0] <= ticks:
            engine.submit(pending[i][1])
            i += 1
        engine.step()
        ticks += 1
        if hasattr(engine, "kv"):
            peak_occ = max(peak_occ, engine.kv.occupancy())
        if ticks >= max_ticks:
            raise RuntimeError(f"replay did not drain in {max_ticks} ticks")
    return time.perf_counter() - t0, ticks, peak_occ


def _busy(engine) -> bool:
    if any(r is not None for r in engine.active):
        return True
    queue = getattr(engine, "queue", None)
    return len(queue if queue is not None else engine.sched) > 0


def _warmup(engine, vocab, uid):
    from repro.serve.serve_loop import Request

    engine.submit(Request(uid=uid, prompt=make_prompt(0, uid, 4, vocab),
                          max_new_tokens=2))
    engine.run_until_drained()


def percentiles(xs):
    if not xs:
        return {"p50_s": None, "p99_s": None}
    return {"p50_s": float(np.percentile(xs, 50)),
            "p99_s": float(np.percentile(xs, 99))}


def lat_stats(reqs):
    ttft = [r.first_token_ts - r.submit_ts for r in reqs
            if r.first_token_ts is not None]
    e2e = [r.complete_ts - r.submit_ts for r in reqs
           if r.complete_ts is not None]
    return ({f"ttft_{k}": v for k, v in percentiles(ttft).items()} |
            {f"e2e_{k}": v for k, v in percentiles(e2e).items()})


def main(argv=None) -> int:
    from repro.configs.base import ARCH_IDS, get_arch
    from repro.models.families import build_model
    from repro.obs.metrics import MetricsRegistry, run_metadata
    from repro.obs.slo import SLOConfig, slo_report
    from repro.paged import PagedServeConfig, PagedServeEngine, SchedConfig
    from repro.serve.serve_loop import ServeConfig, ServeEngine

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm_3b",
                    help="must be a full-attention arch (paged cache)")
    ap.add_argument("--trace", default=None, metavar="JSONL",
                    help=f"replay this trace (default: Poisson unless "
                         f"{DEFAULT_TRACE} is given); lines of "
                         "{uid, arrival_tick, prompt_len, max_new, priority}")
    ap.add_argument("--requests", type=int, default=12,
                    help="Poisson mode: number of synthesized arrivals")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson mode: mean arrivals per tick")
    ap.add_argument("--seed", type=int, default=0,
                    help="deterministic request sampling (prompt tokens and "
                         "Poisson arrivals); recorded in the output meta so "
                         "traffic runs are replayable")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=8,
                    help="Poisson mode: tokens generated per request")
    ap.add_argument("--max-prompt", type=int, default=40,
                    help="Poisson mode: max synthesized prompt length")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-pages", type=int, default=None,
                    help="arena pages incl. the null page (default: fully "
                         "provisioned — undersize it to exercise preemption)")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--scheduler", choices=("fcfs", "priority"),
                    default="fcfs")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="gate the rel metrics against this committed "
                         "baseline JSON (new/base <= --threshold)")
    ap.add_argument("--threshold", type=float, default=1.25)
    ap.add_argument("--min-prefill-speedup", type=float, default=None,
                    help="fail unless chunked prefill beats token-by-token "
                         "ingest by this factor (tokens/sec)")
    ap.add_argument("--sparsity", default=None, metavar="N:M",
                    help="override the arch sparsity pattern on every "
                         "sparse linear (pair with --spec so the draft "
                         "tier can narrow the packed weights)")
    ap.add_argument("--spec", default=None, metavar="N:M",
                    help="run the speculative leg with this draft tier "
                         "(packed weights, repro.spec)")
    ap.add_argument("--spec-gamma", type=int, default=4,
                    help="tokens drafted per speculation window")
    ap.add_argument("--min-acceptance", type=float, default=0.5,
                    help="spec leg: fail if the measured draft acceptance "
                         "rate is at or below this")
    ap.add_argument("--min-spec-speedup", type=float, default=None,
                    help="spec leg: fail unless spec tokens/sec >= this "
                         "factor of the packed non-spec baseline (leave "
                         "unset on compute-bound CPU hosts — see module "
                         "docstring)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="judge each leg's completed requests against this "
                         "time-to-first-token deadline (repro.obs.slo; the "
                         "per-leg report is embedded either way)")
    ap.add_argument("--slo-e2e-ms", type=float, default=None,
                    help="end-to-end latency deadline in ms for the per-leg "
                         "SLO report")
    args = ap.parse_args(argv)

    trace_path = args.trace
    if trace_path:
        trace = load_trace(trace_path)
    else:
        trace = poisson_trace(args.requests, args.rate, args.seed,
                              args.max_prompt, args.max_new)

    # float32 compute: the token-identity check compares argmax across two
    # differently-compiled programs; bf16 puts random-init logits on a 1/256
    # grid where exact top-1/top-2 ties are common and a 1-ulp reduction-
    # order difference flips them.  At f32 resolution ties don't collide.
    cfg = dataclasses.replace(get_arch(args.arch).reduced(),
                              compute_dtype="float32")
    if args.sparsity:
        from repro.core.sparsity import SparsityConfig
        from repro.spec import parse_tier
        n, m = parse_tier(args.sparsity)
        cfg = dataclasses.replace(cfg, sparsity=SparsityConfig(n, m, 1))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    vocab = cfg.vocab_size
    prompt_tokens = sum(r["prompt_len"] for r in trace)

    # -- paged engine -------------------------------------------------------
    reg = MetricsRegistry()
    paged = PagedServeEngine(
        model, params,
        PagedServeConfig(num_slots=args.slots, max_len=args.max_len,
                         page_size=args.page_size, num_pages=args.max_pages,
                         prefill_chunk=args.prefill_chunk,
                         sched=SchedConfig(policy=args.scheduler)),
        metrics=reg)
    _warmup(paged, vocab, _WARM_UID)
    pairs = _requests(trace, args.seed, vocab)
    p_dt, p_ticks, p_occ = replay(paged, pairs)
    p_reqs = [r for r in paged.completed if r.uid < _WARM_UID]
    p_tokens = sum(len(r.output) for r in p_reqs)
    # prefill-only drain: chunked ingest throughput (max_new=1 ends each
    # request on its final prefill chunk — no decode steps in the window)
    pf_pairs = _requests(trace, args.seed, vocab, uid_offset=2 * _WARM_UID,
                         max_new=1)
    pf_dt, _, _ = replay(paged, [(0, r) for _, r in pf_pairs])
    slo_cfg = SLOConfig(ttft_ms=args.slo_ttft_ms, e2e_ms=args.slo_e2e_ms)
    paged_stats = {
        **lat_stats(p_reqs),
        # sketch-backed per-phase percentiles + goodput (+ pass/fail when
        # --slo-* deadlines are set); extra keys the compare gate ignores
        "slo_report": slo_report(p_reqs, slo_cfg),
        "tokens_per_sec": p_tokens / p_dt,
        "prefill_tokens_per_sec": prompt_tokens / pf_dt,
        "ticks": p_ticks,
        "preempts": int(reg.counter("serve_preempt_total").value),
        "peak_occupancy": p_occ,
        "fragmentation": paged.kv.fragmentation(),
        "prefill_dispatches": paged.prefill.dispatches,
    }

    # -- legacy engine ------------------------------------------------------
    legacy = ServeEngine(model, params,
                         ServeConfig(num_slots=args.slots,
                                     max_len=args.max_len),
                         metrics=MetricsRegistry())
    _warmup(legacy, vocab, _WARM_UID)
    pairs = _requests(trace, args.seed, vocab)
    l_dt, l_ticks, _ = replay(legacy, pairs)
    l_reqs = [r for r in legacy.completed if r.uid < _WARM_UID]
    l_tokens = sum(len(r.output) for r in l_reqs)
    pf_pairs = _requests(trace, args.seed, vocab, uid_offset=2 * _WARM_UID,
                         max_new=1)
    lf_dt, _, _ = replay(legacy, [(0, r) for _, r in pf_pairs])
    legacy_stats = {
        **lat_stats(l_reqs),
        "slo_report": slo_report(l_reqs, slo_cfg),
        "tokens_per_sec": l_tokens / l_dt,
        "prefill_tokens_per_sec": prompt_tokens / lf_dt,
        "ticks": l_ticks,
    }

    # -- cross-engine checks ------------------------------------------------
    p_out = {r.uid: list(r.output) for r in p_reqs}
    l_out = {r.uid: list(r.output) for r in l_reqs}
    token_identical = p_out == l_out
    speedup = (paged_stats["prefill_tokens_per_sec"]
               / legacy_stats["prefill_tokens_per_sec"])
    rel = {  # same-host cross-engine ratios, all lower-is-better
        "ttft_p99": paged_stats["ttft_p99_s"] / legacy_stats["ttft_p99_s"],
        "e2e_p99": paged_stats["e2e_p99_s"] / legacy_stats["e2e_p99_s"],
        "tps": legacy_stats["tokens_per_sec"] / paged_stats["tokens_per_sec"],
        "prefill": 1.0 / speedup,
    }

    # -- speculative leg (packed weights, draft tier = --spec) --------------
    spec_stats = None
    if args.spec:
        from repro.core.sparse_linear import ExecPolicy
        from repro.launch.pack_tree import pack_tree
        from repro.spec import SpecConfig, tier_sort_tree

        packed = tier_sort_tree(pack_tree(params))
        pol = ExecPolicy(mode="packed", backend="reference")
        serve_cfg = ServeConfig(num_slots=args.slots, max_len=args.max_len)

        base_eng = ServeEngine(model, packed, serve_cfg, policy=pol,
                               metrics=MetricsRegistry())
        _warmup(base_eng, vocab, _WARM_UID)
        b_dt, _, _ = replay(base_eng, _requests(trace, args.seed, vocab))
        b_reqs = [r for r in base_eng.completed if r.uid < _WARM_UID]
        b_tokens = sum(len(r.output) for r in b_reqs)

        spec_eng = ServeEngine(model, packed, serve_cfg, policy=pol,
                               metrics=MetricsRegistry(),
                               spec=SpecConfig(draft=args.spec,
                                               gamma=args.spec_gamma))
        _warmup(spec_eng, vocab, _WARM_UID)
        s_dt, _, _ = replay(spec_eng, _requests(trace, args.seed, vocab))
        s_reqs = [r for r in spec_eng.completed if r.uid < _WARM_UID]
        s_tokens = sum(len(r.output) for r in s_reqs)

        sm = spec_eng._spec_metrics
        spec_stats = {
            **lat_stats(s_reqs),
            "slo_report": slo_report(s_reqs, slo_cfg),
            "draft": args.spec,
            "gamma": args.spec_gamma,
            "tokens_per_sec": s_tokens / s_dt,
            "baseline_tokens_per_sec": b_tokens / b_dt,
            "speedup": (s_tokens / s_dt) / (b_tokens / b_dt),
            "drafted": int(sm.drafted.value),
            "accepted": int(sm.accepted.value),
            "acceptance_rate": sm.accepted.value / max(sm.drafted.value, 1),
            "tokens_per_dispatch": (sm._committed_total
                                    / max(sm._verify_dispatches, 1)),
            "verify_dispatches": sm._verify_dispatches,
            "token_identical": ({r.uid: list(r.output) for r in s_reqs}
                                == {r.uid: list(r.output) for r in b_reqs}),
        }

    blob = {
        "meta": {**run_metadata(), "arch": cfg.name,
                 "compute_dtype": cfg.compute_dtype, "seed": args.seed,
                 "trace": trace_path or "poisson",
                 "requests": len(trace), "prompt_tokens": prompt_tokens,
                 "slots": args.slots, "max_len": args.max_len,
                 "page_size": args.page_size, "max_pages": args.max_pages,
                 "prefill_chunk": args.prefill_chunk,
                 "scheduler": args.scheduler,
                 "sparsity": args.sparsity},
        "paged": paged_stats,
        "legacy": legacy_stats,
        "rel": rel,
        "token_identical": token_identical,
        "prefill_speedup": speedup,
        "spec": spec_stats,
    }
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=2)

    print(f"replayed {len(trace)} requests ({prompt_tokens} prompt tokens) "
          f"on {cfg.name} [{args.scheduler}]")
    for name, s in (("paged", paged_stats), ("legacy", legacy_stats)):
        print(f"  {name:6s} ttft p50/p99 {s['ttft_p50_s'] * 1e3:7.1f}/"
              f"{s['ttft_p99_s'] * 1e3:7.1f} ms   e2e p99 "
              f"{s['e2e_p99_s'] * 1e3:7.1f} ms   {s['tokens_per_sec']:7.1f} "
              f"tok/s   prefill {s['prefill_tokens_per_sec']:8.1f} tok/s")
    print(f"  paged: {paged_stats['preempts']} preempts, peak occupancy "
          f"{paged_stats['peak_occupancy']:.2f}, "
          f"{paged_stats['prefill_dispatches']} prefill dispatches")
    for name, s in (("paged", paged_stats), ("legacy", legacy_stats),
                    *((("spec", spec_stats),) if spec_stats else ())):
        rep = s["slo_report"]
        wt = rep["goodput"]["wasted_tokens"]
        line = (f"  {name:6s} goodput "
                + (f"{rep['goodput']['ratio']:.3f}"
                   if rep["goodput"]["ratio"] is not None else "n/a")
                + f" (wasted: preempt {wt['preempt']}, spec_reject "
                  f"{wt['spec_reject']})")
        if "slo" in rep:
            line += (f"   slo attainment "
                     f"{rep['slo']['attainment']:.3f} "
                     f"({rep['slo']['pass']}/{rep['completed']})")
        print(line)
    print(f"  prefill speedup {speedup:.2f}x, token_identical="
          f"{token_identical}")
    if spec_stats:
        print(f"  spec   draft {spec_stats['draft']} gamma "
              f"{spec_stats['gamma']}: acceptance "
              f"{spec_stats['acceptance_rate']:.3f} "
              f"({spec_stats['accepted']}/{spec_stats['drafted']}), "
              f"{spec_stats['tokens_per_dispatch']:.2f} tokens/dispatch, "
              f"{spec_stats['tokens_per_sec']:7.1f} tok/s "
              f"({spec_stats['speedup']:.2f}x packed non-spec), "
              f"token_identical={spec_stats['token_identical']}")
    print(f"wrote {args.out}")

    failures = []
    if not token_identical:
        diff = sorted(u for u in p_out if p_out[u] != l_out.get(u))
        failures.append(f"paged vs dense decode outputs differ (uids {diff})")
    if args.min_prefill_speedup and speedup < args.min_prefill_speedup:
        failures.append(f"prefill speedup {speedup:.2f}x < required "
                        f"{args.min_prefill_speedup}x")
    if spec_stats:
        if not spec_stats["token_identical"]:
            failures.append("speculative decode diverged from the packed "
                            "non-spec stream")
        if spec_stats["acceptance_rate"] <= args.min_acceptance:
            failures.append(
                f"spec acceptance {spec_stats['acceptance_rate']:.3f} <= "
                f"required {args.min_acceptance}")
        if spec_stats["tokens_per_dispatch"] <= 1.0:
            failures.append(
                f"spec tokens/dispatch {spec_stats['tokens_per_dispatch']:.2f}"
                " <= 1 (speculation commits no extra tokens per full-tier "
                "dispatch)")
        if (args.min_spec_speedup
                and spec_stats["speedup"] < args.min_spec_speedup):
            failures.append(f"spec speedup {spec_stats['speedup']:.2f}x < "
                            f"required {args.min_spec_speedup}x")
    if args.compare:
        with open(args.compare) as f:
            base = json.load(f)
        print(f"\ncompare vs {args.compare} "
              f"(platform={base['meta'].get('platform')}, "
              f"jax={base['meta'].get('jax')}), threshold "
              f"{args.threshold:.2f}x")
        for key, new_v in rel.items():
            base_v = base.get("rel", {}).get(key)
            if base_v is None or base_v <= 0:
                print(f"  {key:10s} [skip] no baseline value")
                continue
            ratio = new_v / base_v
            flag = "REGRESSED" if ratio > args.threshold else "ok"
            print(f"  {key:10s} base {base_v:7.3f}  new {new_v:7.3f}  "
                  f"({ratio:5.2f}x)  {flag}")
            if ratio > args.threshold:
                failures.append(f"rel.{key} regressed {ratio:.2f}x vs "
                                f"{args.compare}")

    if failures:
        print("\nFAIL:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
