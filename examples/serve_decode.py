"""Serve a small model with batched requests, comparing every supported
serving path — dense-masked, packed xwT, two-level block, and int8-quantized
block (sparsity × quantization, the S2TA-style multiplicative win) — then
the paged serving engine (shared KV arena + chunked prefill + preemption)
against the legacy dense-cache loop.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.sparse_linear import ExecPolicy
from repro.core.sparsity import SparsityConfig
from repro.launch.pack_tree import pack_tree
from repro.models.families import build_model
from repro.obs.metrics import MetricsRegistry
from repro.paged import PagedServeConfig, PagedServeEngine
from repro.serve.serve_loop import Request, ServeConfig, ServeEngine


def run_engine(model, params, cfg, mode, requests):
    eng = ServeEngine(model, params, ServeConfig(num_slots=4, max_len=64),
                      policy=ExecPolicy(mode=mode))
    for r in requests:
        eng.submit(Request(uid=r.uid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens))
    t0 = time.time()
    eng.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in eng.completed)
    return eng.completed, toks / dt, dt


def main():
    cfg = get_arch("gemma3_1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    requests = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 8,
                                            dtype=np.int32),
                        max_new_tokens=12)
                for i in range(8)]

    done_m, tps_m, dt_m = run_engine(model, params, cfg, "masked", requests)
    packed = pack_tree(params)
    done_p, tps_p, dt_p = run_engine(model, packed, cfg, "packed", requests)
    # the two-level block layout: active-group lists gate the kernel's DMAs
    # (scan-stacked weights share one a_max via pack_block_stacked)
    blocked = pack_tree(params, layout="block")
    done_b, tps_b, dt_b = run_engine(model, blocked, cfg, "packed", requests)
    # sparsity × quantization: the same block layout with int8 values +
    # traced scales, dequantized in-register by the w8a16 kernels
    quant = pack_tree(params, layout="block", quantize="int8")
    done_q, tps_q, dt_q = run_engine(model, quant, cfg, "packed", requests)

    sp = cfg.sparsity
    print(f"arch {cfg.name} (reduced), sparsity {sp.pattern_name()}, "
          f"weight compression {sp.compression_ratio(2, 1):.1f}x "
          f"(int8: {sp.compression_ratio(2, 1) * 1.5:.1f}x)")
    print(f"masked-dense serving: {len(done_m)} reqs, {tps_m:.1f} tok/s")
    print(f"packed-DeMM  serving: {len(done_p)} reqs, {tps_p:.1f} tok/s "
          f"(CPU interpret — on TPU the packed path cuts weight HBM reads "
          f"~{sp.compression_ratio(2, 1):.0f}x; see DESIGN.md §6)")
    print(f"block-DeMM   serving: {len(done_b)} reqs, {tps_b:.1f} tok/s "
          f"(layout='block': two-level packing, DESIGN.md §9)")
    print(f"block+int8   serving: {len(done_q)} reqs, {tps_q:.1f} tok/s "
          f"(quantize='int8': w8a16 kernels, DESIGN.md §10)")

    # generations agree modulo fp-tie argmax flips (the packed path
    # accumulates in fp32, the masked path in bf16) and int8 rounding
    by_uid_m = {r.uid: r.output for r in done_m}
    by_uid_p = {r.uid: r.output for r in done_p}
    by_uid_b = {r.uid: r.output for r in done_b}
    by_uid_q = {r.uid: r.output for r in done_q}
    agree = np.mean([
        np.mean(np.asarray(by_uid_m[u]) == np.asarray(by_uid_p[u]))
        for u in by_uid_m])
    agree_b = np.mean([
        np.mean(np.asarray(by_uid_p[u]) == np.asarray(by_uid_b[u]))
        for u in by_uid_p])
    agree_q = np.mean([
        np.mean(np.asarray(by_uid_b[u]) == np.asarray(by_uid_q[u]))
        for u in by_uid_b])
    print(f"greedy top-1 agreement across paths: {agree:.1%} "
          f"(fp32 vs bf16 accumulation), xwT vs block: {agree_b:.1%}, "
          f"block vs block+int8: {agree_q:.1%}")
    assert agree > 0.7, "packed and masked paths diverged beyond fp noise"
    assert agree_b > 0.95, "block and xwT packed paths diverged"
    assert agree_q > 0.6, "int8 path diverged beyond quantization noise"
    for uid in sorted(by_uid_m)[:3]:
        print(f"  req {uid}: masked {by_uid_m[uid]}")
        print(f"          packed {by_uid_p[uid]}")

    paged_section()


def paged_section():
    """Paged serving (repro.paged, DESIGN.md §13) vs the legacy dense-cache
    engine: mixed prompt lengths, an arena deliberately too small for all
    four sequences (forcing at least one preemption-by-page-eviction), and
    exact token-level agreement — greedy preempt/resume is deterministic.

    Uses a full-attention arch (the paged cache targets full-attention KV;
    ring buffers are already O(window)) at float32 compute, where greedy
    argmax agreement across the two engines' differently-compiled programs
    is exact."""
    cfg = dataclasses.replace(get_arch("stablelm_3b").reduced(),
                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (5, 23, 11, 37)]          # mixed prompt lengths

    def submit_all(eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
        t0 = time.time()
        eng.run_until_drained()
        return {r.uid: list(r.output) for r in eng.completed}, \
            time.time() - t0

    legacy = ServeEngine(model, params,
                         ServeConfig(num_slots=4, max_len=96),
                         metrics=MetricsRegistry())
    out_legacy, dt_l = submit_all(legacy)

    reg = MetricsRegistry()
    paged = PagedServeEngine(
        model, params,
        PagedServeConfig(num_slots=4, max_len=96, page_size=8,
                         num_pages=13,     # too small: forces eviction
                         prefill_chunk=16),
        metrics=reg)
    out_paged, dt_p = submit_all(paged)

    preempts = int(reg.counter("serve_preempt_total").value)
    chunks = sum(-(-len(p) // 16) for p in prompts)
    print(f"\npaged serving ({cfg.name}, fp32): arena of "
          f"{paged.layout.usable_pages} x {paged.layout.page_size}-token "
          f"pages shared by {len(prompts)} requests")
    print(f"  chunked prefill: {paged.prefill.dispatches} dispatches for "
          f"{sum(len(p) for p in prompts)} prompt tokens "
          f"(sum ceil(T/16) = {chunks}, plus re-prefill after preemption; "
          f"legacy feeds token-by-token)")
    print(f"  preemptions: {preempts} (page eviction -> requeue -> "
          f"re-prefill of prompt + generated-so-far)")
    print(f"  legacy {dt_l:.2f}s vs paged {dt_p:.2f}s to drain")
    assert preempts >= 1, "undersized arena should have preempted"
    assert out_paged == out_legacy, "paged serving must be token-identical"
    print("  token-identical with the legacy dense engine: "
          f"{len(out_paged)}/{len(prompts)} requests "
          "(greedy preempt/resume is deterministic)")
    for uid in sorted(out_paged)[:2]:
        print(f"  req {uid}: {out_paged[uid]}")


if __name__ == "__main__":
    main()
