"""End-to-end driver: gradually sparsify a ~100M-param LM to relaxed 8:128
DeMM sparsity (``repro.sparsetrain``), then serve it packed.

This is the deliverable-(b) end-to-end example, now on the full train-side
pipeline: a real (non-reduced) small config of the xlstm family trained on
the synthetic pipeline with the full supervisor stack (checkpoints +
deterministic resume + schedule state riding every checkpoint), a gradual
dense → 8:256 → 8:128 magnitude-pruning schedule instead of a fixed mask,
and — after baking the final masks — packed **block-layout** serving
through ``launch/serve.py``'s engine, asserting the trained model actually
generates.

Run:  PYTHONPATH=src python examples/train_sparse_lm.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.sparsity import SparsityConfig
from repro.data.pipeline import DataConfig
from repro.launch.serve import run_serve
from repro.launch.train import verify_final_masks
from repro.models.families import build_model
from repro.optim import adamw
from repro.sparsetrain import SparseTrainRecipe, SparseTrainer
from repro.sparsetrain.masks import anneal_schedule
from repro.train.fault_tolerance import (
    SupervisorConfig,
    TrainingSupervisor,
    inject_failure_once,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_sparse_lm")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    # ~100M-class config: the xlstm-125m arch, narrowed for CPU wall-time,
    # with the paper's relaxed sparsity on every projection.
    cfg = dataclasses.replace(
        get_arch("xlstm_125m"),
        num_layers=4, d_model=256, num_heads=4, vocab_size=8192,
        sparsity=SparsityConfig(8, 128, 1),
    )
    cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=32))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params) if hasattr(x, "size"))
    print(f"model: {cfg.name}-style, {n/1e6:.1f}M params, "
          f"sparsity {cfg.sparsity.pattern_name()}")

    opt_cfg = adamw.AdamWConfig(lr=3e-4, total_steps=args.steps,
                                warmup_steps=args.steps // 20)
    opt = adamw.init(opt_cfg, params)

    # Gradual sparsification: dense warmup → coarse 8:256 → serving 8:128,
    # mask refreshed every 25 steps and frozen for the last 10%.
    schedule = anneal_schedule(cfg.sparsity, args.steps)
    print(f"sparsify schedule: {schedule.spec()}")
    trainer = SparseTrainer(model, opt_cfg,
                            SparseTrainRecipe(schedule=schedule))
    trainer.init_state(params)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)

    # keyed by step so supervisor restarts replaying steps overwrite
    # instead of duplicating entries (same rule as launch/train.py)
    loss_by_step = {}
    t0 = time.time()

    def logging_step(p, o, b, s):
        p, o, m = trainer.train_step(p, o, b, s)
        loss_by_step[s] = float(m["loss"])
        if s % 25 == 0:
            print(f"step {s:4d}  loss {loss_by_step[s]:.4f}  "
                  f"({time.time()-t0:.0f}s)")
        return p, o, m

    sup = TrainingSupervisor(
        SupervisorConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50),
        logging_step, data_cfg, extra_state=trainer)
    injector = (inject_failure_once(args.inject_failure)
                if args.inject_failure else None)
    params, opt, _, restarts = sup.run(params, opt, args.steps,
                                       failure_injector=injector)
    first, last = loss_by_step[0], loss_by_step[max(loss_by_step)]
    print(f"\nfinal loss {last:.4f} (started {first:.4f}), "
          f"restarts={restarts}")
    # pruning phases cause transient spikes: require learning vs init OR
    # recovery within the final (serving-pattern) phase — same rule as
    # launch/train.py
    t_final = min(schedule.phases[-1].start, max(loss_by_step))
    assert last < first or last < loss_by_step[t_final], \
        "loss must decrease (vs step 0 or vs the final phase's start)"

    # Bake the final masks and serve the trained model through the
    # launch/serve.py engine on the two-level block layout.
    params = trainer.finalize(params)
    n_sparse = verify_final_masks(params)
    print(f"final masks satisfy 8:128 exactly on {n_sparse} sparse linears")
    engine = run_serve(model, params, cfg.vocab_size, packed=True,
                       layout="block", backend="reference", requests=4,
                       slots=2, max_new=6, max_len=64)
    assert len(engine.completed) == 4, "block-packed serving must drain"
    assert all(len(r.output) == 6 for r in engine.completed)
    print(f"served {len(engine.completed)} requests on the block-packed "
          f"trained model, e.g. {engine.completed[0].output}")


if __name__ == "__main__":
    main()
