"""End-to-end driver: train a ~100M-param LM with relaxed 8:128 DeMM
sparsity for a few hundred steps, with checkpointing and restart.

This is the deliverable-(b) end-to-end example: a real (non-reduced) small
config of the xlstm family trained on the synthetic pipeline with the full
supervisor stack (checkpoints + deterministic resume).

Run:  PYTHONPATH=src python examples/train_sparse_lm.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.sparsity import SparsityConfig
from repro.data.pipeline import DataConfig
from repro.models.families import build_model
from repro.optim import adamw
from repro.train.fault_tolerance import (
    SupervisorConfig,
    TrainingSupervisor,
    inject_failure_once,
)
from repro.train.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_sparse_lm")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    # ~100M-class config: the xlstm-125m arch, narrowed for CPU wall-time,
    # with the paper's relaxed sparsity on every projection.
    cfg = dataclasses.replace(
        get_arch("xlstm_125m"),
        num_layers=4, d_model=256, num_heads=4, vocab_size=8192,
        sparsity=SparsityConfig(8, 128, 1),
    )
    cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=32))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params) if hasattr(x, "size"))
    print(f"model: {cfg.name}-style, {n/1e6:.1f}M params, "
          f"sparsity {cfg.sparsity.pattern_name()}")

    opt_cfg = adamw.AdamWConfig(lr=3e-4, total_steps=args.steps,
                                warmup_steps=args.steps // 20)
    opt = adamw.init(opt_cfg, params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)

    losses = []
    t0 = time.time()

    def logging_step(p, o, b, s):
        p, o, m = step_fn(p, o, b, s)
        losses.append(float(m["loss"]))
        if s % 25 == 0:
            print(f"step {s:4d}  loss {losses[-1]:.4f}  "
                  f"({time.time()-t0:.0f}s)")
        return p, o, m

    sup = TrainingSupervisor(
        SupervisorConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50),
        logging_step, data_cfg)
    injector = (inject_failure_once(args.inject_failure)
                if args.inject_failure else None)
    params, opt, _, restarts = sup.run(params, opt, args.steps,
                                       failure_injector=injector)
    print(f"\nfinal loss {losses[-1]:.4f} (started {losses[0]:.4f}), "
          f"restarts={restarts}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
