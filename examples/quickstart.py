"""Quickstart: the paper's technique end to end in five minutes.

1. Build a relaxed 8:128-sparse matrix, pack it, and run the DeMM engine.
2. Validate the Pallas TPU kernel (interpret mode) against the jnp oracle.
3. Train a tiny sparse LM for a few steps and serve it with packed weights.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.demm import DeMMConfig, demm_spmm
from repro.core.sparsity import (
    SparsityConfig,
    pack,
    prune,
    random_sparse_dense,
    satisfies_pattern,
)
from repro.kernels.demm_spmm import demm_spmm_pallas
from repro.kernels.ref import spmm_ref

print("=" * 70)
print("1. Relaxed structured sparsity + the decoupled engine")
print("=" * 70)
cfg = SparsityConfig(n=8, m=128)
rng = np.random.default_rng(0)
a = random_sparse_dense(rng, rows=256, cols=512, cfg=cfg)
b = rng.standard_normal((512, 128)).astype(np.float32)
print(f"pattern {cfg.pattern_name()}: density {cfg.density:.3%}, "
      f"packed compression {cfg.compression_ratio(2, 1):.1f}x (bf16+int8)")
assert satisfies_pattern(jnp.asarray(a), cfg)

packed = pack(jnp.asarray(a), cfg)
print(f"packed: values {packed.values.shape}, indices {packed.indices.shape}")
out = demm_spmm(packed, jnp.asarray(b))          # row-wise product-first
np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-4, atol=1e-4)
print("DeMM row-wise product-first == dense matmul  [ok]")

engine = DeMMConfig(n=8, m=128, c=64, k=8)       # the paper's DeMM(8,128,64,8)
print(f"engine DeMM(8,128,64,8): {engine.multipliers} MACs, supports 8:128 "
      f"through {engine.k * engine.n}:128 (k-reconfiguration)")

print()
print("=" * 70)
print("2. Pallas TPU kernel vs oracle (interpret mode on CPU)")
print("=" * 70)
t0 = time.time()
got = demm_spmm_pallas(packed.values, packed.indices, jnp.asarray(b), cfg,
                       block_r=128, block_c=128, interpret=True)
want = spmm_ref(packed.values, packed.indices, jnp.asarray(b), cfg,
                (256, 512))
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                           atol=1e-4)
print(f"fused decompress->MXU kernel == oracle  [ok]  ({time.time()-t0:.1f}s)")

print()
print("=" * 70)
print("3. Sparse LM: train (masked) -> pack -> serve (DeMM)")
print("=" * 70)
from repro.configs.base import get_arch
from repro.core.sparse_linear import ExecPolicy
from repro.core.sparsity import PackedWeight
from repro.launch.pack_tree import pack_tree
from repro.models.families import build_model
from repro.optim import adamw
from repro.serve.serve_loop import Request, ServeConfig, ServeEngine
from repro.train.train_loop import make_train_step

arch = get_arch("stablelm_3b").reduced()
model = build_model(arch)
params = model.init(jax.random.PRNGKey(0))
opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=20, warmup_steps=2)
opt = adamw.init(opt_cfg, params)
step = jax.jit(make_train_step(model, opt_cfg))
batch = {
    "tokens": jnp.asarray(rng.integers(0, arch.vocab_size, (4, 32))),
    "targets": jnp.asarray(rng.integers(0, arch.vocab_size, (4, 32))),
}
losses = []
for i in range(8):
    params, opt, m = step(params, opt, batch, i)
    losses.append(float(m["loss"]))
print(f"masked-sparse training: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

packed_params = pack_tree(params)   # sparse linears -> PackedWeight pytrees
pws = [l for l in jax.tree_util.tree_leaves(
    packed_params, is_leaf=lambda n: isinstance(n, PackedWeight))
    if isinstance(l, PackedWeight)]
print(f"packed weights are first-class pytrees ({len(pws)} nodes), e.g. "
      f"{pws[0]}")
eng = ServeEngine(model, packed_params, ServeConfig(num_slots=2, max_len=48),
                  policy=ExecPolicy(mode="packed", backend="reference"))
eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3], np.int32),
                   max_new_tokens=8))
eng.run_until_drained()
print(f"packed-DeMM serving: generated {eng.completed[0].output}")
print("\nquickstart complete.")
