"""Prune a dense model to relaxed N:M, fine-tune with RigL mask updates,
and pack for DeMM serving — the full model-compression workflow the paper's
engine targets.

Run:  PYTHONPATH=src python examples/prune_and_pack.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.pruning import PruneSchedule, init_mask, maybe_update_mask
from repro.core.sparsity import (PackedWeight, SparsityConfig, pack, prune,
                                 satisfies_pattern)
from repro.launch.pack_tree import pack_tree
from repro.models.families import build_model
from repro.optim import adamw
from repro.train.train_loop import make_train_step


def main():
    # Stage 1: dense-ish baseline (the reduced config inits pre-pruned;
    # densify one layer to show the pruning step explicitly).
    cfg = get_arch("h2o_danube_1_8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sp = SparsityConfig(2, 16)

    w = jax.random.normal(jax.random.PRNGKey(7), (64, 128))
    print(f"dense w: {float(jnp.mean(w != 0)):.2%} non-zero")

    # Stage 2: magnitude-prune to the relaxed pattern
    wp = prune(w, sp)
    assert satisfies_pattern(wp, sp)
    print(f"pruned to {sp.pattern_name()}: {float(jnp.mean(wp != 0)):.2%} "
          f"non-zero, pattern valid")

    # Stage 3: RigL-style mask evolution during (simulated) training
    sched = PruneSchedule(cfg=sp, update_every=2, regrow_fraction=0.3)
    mask = init_mask(w, sp)
    for step in range(6):
        fake_grad = jax.random.normal(jax.random.PRNGKey(step), w.shape)
        mask = maybe_update_mask(jnp.asarray(step), w, mask, fake_grad, sched)
        dens = float(jnp.mean(mask))
        assert satisfies_pattern(jnp.where(mask, w, 0.0), sp)
    print(f"RigL mask updates keep the pattern exact (density {dens:.2%})")

    # Stage 4: brief sparse fine-tune of the full model + pack for serving
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    opt = adamw.init(opt_cfg, params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32))),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)))}
    for i in range(4):
        params, opt, m = step_fn(params, opt, batch, i)
    print(f"fine-tuned 4 steps (loss {float(m['loss']):.3f})")

    packed = pack_tree(params)
    pws = list(_walk_packed(packed))
    total_dense, total_packed = 0, 0
    for pw in pws:
        o, k = pw.dense_shape
        stack = 1
        for s in pw.stack_dims:
            stack *= s
        total_dense += stack * o * k * 2
        total_packed += pw.values.size * 3  # bf16 value + int8 index
    print(f"packed {len(pws)} sparse layers (pattern "
          f"{pws[0].cfg.pattern_name()}): {total_dense/1e6:.1f}MB dense "
          f"-> {total_packed/1e6:.1f}MB packed "
          f"({total_dense/total_packed:.1f}x smaller weight stream)")


def _walk_packed(tree):
    """Yield every PackedWeight node (isinstance, no key-sniffing)."""
    if isinstance(tree, PackedWeight):
        yield tree
    elif isinstance(tree, dict):
        for v in tree.values():
            yield from _walk_packed(v)


if __name__ == "__main__":
    main()
