import os
import sys

# make tests/helpers.py importable regardless of invocation directory
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
