"""MoE dispatch invariants: token conservation, capacity drops, routing
determinism (property-based)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env: deterministic example replay
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod


def _setup(e=8, k=2, d=32, f=16, seed=0):
    cfg = MoEConfig(num_experts=e, experts_per_token=k, d_ff_expert=f)
    params = moe_mod.init_moe(jax.random.PRNGKey(seed), d, cfg, sparse=None)
    return cfg, params


def test_identity_experts_preserve_token_mix():
    """With identity-like experts (w_down @ w_up ≈ scaled identity is hard;
    instead zero experts), the output is exactly zero — no token leaks."""
    cfg, params = _setup()
    params = dict(params, w_down=jnp.zeros_like(params["w_down"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y, aux = moe_mod.apply_moe(params, x, cfg, capacity=64)
    np.testing.assert_allclose(np.asarray(y), 0.0)


def test_capacity_drops_are_passthrough_zero():
    """capacity=1 forces drops; dropped tokens contribute zero output (the
    residual connection outside the MoE carries them)."""
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32))
    y_full, _ = moe_mod.apply_moe(params, x, cfg, capacity=64)
    y_tight, _ = moe_mod.apply_moe(params, x, cfg, capacity=1)
    # tight capacity must zero *some* token outputs
    z_full = np.mean(np.all(np.asarray(y_full) == 0, axis=-1))
    z_tight = np.mean(np.all(np.asarray(y_tight) == 0, axis=-1))
    assert z_tight > z_full


def test_top1_routing_selects_argmax_expert():
    cfg, params = _setup(e=4, k=1)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 32))
    logits = x.reshape(-1, 32) @ params["router"]["w"].T
    top = np.argmax(np.asarray(logits), -1)
    # perturb one expert's weights to NaN; tokens routed there go NaN
    bad = int(top[0])
    wg = params["w_gate"].at[bad].set(jnp.nan)
    y, _ = moe_mod.apply_moe(dict(params, w_gate=wg), x, cfg, capacity=8)
    yn = np.isnan(np.asarray(y)).any(-1)[0]
    assert yn[0]  # token 0 hit the poisoned expert
    for t in range(1, 4):
        assert yn[t] == (top[t] == bad)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
def test_property_moe_finite_and_deterministic(seed, e, k):
    cfg, params = _setup(e=e, k=k, seed=seed % 1000)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, 32))
    y1, a1 = moe_mod.apply_moe(params, x, cfg, capacity=32)
    y2, a2 = moe_mod.apply_moe(params, x, cfg, capacity=32)
    assert np.all(np.isfinite(np.asarray(y1)))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert float(a1) == float(a2) >= 0.0


def test_aux_loss_penalizes_imbalance():
    cfg, params = _setup(e=4, k=1)
    # router forced to send everything to expert 0
    w = jnp.zeros_like(params["router"]["w"]).at[0].set(10.0)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 32))
    _, aux_skew = moe_mod.apply_moe(
        dict(params, router={"w": w}), x, cfg, capacity=64)
    _, aux_uniform = moe_mod.apply_moe(
        dict(params, router={"w": jnp.zeros_like(w)}), x, cfg, capacity=64)
    assert float(aux_skew) > float(aux_uniform)
