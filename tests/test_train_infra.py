"""Training infrastructure: optimizer, train loop (premask equivalence),
data pipeline, checkpointing, fault tolerance, straggler monitor."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.data.pipeline import DataConfig, DataIterator, global_batch, host_batch
from repro.models.families import build_model
from repro.optim import adamw
from repro.optim.compression import int8_roundtrip, topk_with_error_feedback
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (
    StragglerMonitor,
    SupervisorConfig,
    TrainingSupervisor,
    inject_failure_once,
)
from repro.train.train_loop import make_train_step, premask_params


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("stablelm_3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _batch(cfg, b=4, t=16, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t))),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t))),
    }


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_loss(small_model):
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=30, warmup_steps=2,
                                weight_decay=0.0)
    opt = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(model, opt_cfg))
    batch = _batch(cfg)
    losses = []
    for i in range(12):
        params, opt, m = step(params, opt, batch, i)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.98


def test_adamw_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_premask_equivalence(small_model):
    """premask=True and premask=False produce identical updates."""
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    batch = _batch(cfg)
    outs = {}
    for pm in (True, False):
        opt = adamw.init(opt_cfg, params)
        step = jax.jit(make_train_step(model, opt_cfg, premask=pm,
                                       num_microbatches=2))
        p2, _, m = step(params, opt, batch, 0)
        outs[pm] = (p2, float(m["loss"]))
    assert outs[True][1] == pytest.approx(outs[False][1], rel=1e-5)
    flat_t = jax.tree.leaves(outs[True][0])
    flat_f = jax.tree.leaves(outs[False][0])
    for a, b in zip(flat_t, flat_f):
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-4, atol=1e-5)


def test_premask_straight_through_reaches_masked_weights(small_model):
    cfg, model, params = small_model
    # densify one sparse weight, then confirm premask re-applies the pattern
    dense_w = jnp.ones_like(params["layers"]["mlp"]["gate"]["w"])
    params2 = jax.tree.map(lambda x: x, params)
    params2["layers"]["mlp"]["gate"]["w"] = dense_w
    mp = premask_params(params2)
    wm = mp["layers"]["mlp"]["gate"]["w"]
    assert float(jnp.mean((wm == 0).astype(jnp.float32))) > 0.5


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_topk_error_feedback_conserves_mass():
    g = {"a": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                          jnp.float32)}
    r = {"a": jnp.zeros((64, 64))}
    sent, resid = topk_with_error_feedback(g, r, fraction=0.1)
    # sent + residual == original (+ previous residual)
    np.testing.assert_allclose(np.asarray(sent["a"] + resid["a"]),
                               np.asarray(g["a"]), rtol=1e-6)
    density = float(jnp.mean((sent["a"] != 0).astype(jnp.float32)))
    assert density == pytest.approx(0.1, abs=0.02)


def test_int8_roundtrip_accuracy():
    g = {"a": jnp.asarray(np.random.default_rng(1).standard_normal((128,)),
                          jnp.float32)}
    out = int8_roundtrip(g)
    err = float(jnp.max(jnp.abs(out["a"] - g["a"])))
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127
    assert err <= scale * 0.51


def test_compressed_training_converges(small_model):
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=20, warmup_steps=1,
                                weight_decay=0.0, compression="topk",
                                topk_fraction=0.2)
    opt = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(model, opt_cfg))
    batch = _batch(cfg)
    losses = []
    for i in range(10):
        params, opt, m = step(params, opt, batch, i)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_skip_ahead():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4)
    b1 = global_batch(cfg, 7)
    b2 = global_batch(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = DataIterator(cfg)
    it.seek(7)
    b3 = next(it)
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])
    # targets are next-token shifted view of the same stream
    full = global_batch(cfg, 0)
    assert full["tokens"].shape == (4, 8)
    assert full["targets"].shape == (4, 8)


def test_host_batch_slicing():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    full = global_batch(cfg, 3)
    h0 = host_batch(cfg, 3, 0, 4)
    h3 = host_batch(cfg, 3, 3, 4)
    np.testing.assert_array_equal(h0["tokens"], full["tokens"][:2])
    np.testing.assert_array_equal(h3["tokens"], full["tokens"][6:])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, small_model):
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig()
    opt = adamw.init(opt_cfg, params)
    tree = {"params": params, "opt": opt}
    ckpt.save(tree, str(tmp_path), 5)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored = ckpt.restore(tree, str(tmp_path), 5)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        if hasattr(a, "dtype"):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_commit(tmp_path, small_model):
    cfg, model, params = small_model
    ckpt.save({"p": params}, str(tmp_path), 1)
    # no .tmp directories remain after a successful save
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_checkpoint_async(tmp_path, small_model):
    cfg, model, params = small_model
    fut = ckpt.save_async({"p": params}, str(tmp_path), 2)
    fut.result(timeout=60)
    assert ckpt.latest_step(str(tmp_path)) == 2


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_restart_resumes_bitwise(tmp_path, small_model):
    """Injected failure + restore reproduces the uninterrupted trajectory."""
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=20, warmup_steps=1)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4)
    step = jax.jit(make_train_step(model, opt_cfg))

    def run(ckpt_dir, injector):
        opt = adamw.init(opt_cfg, params)
        sup = TrainingSupervisor(
            SupervisorConfig(ckpt_dir=ckpt_dir, ckpt_every=4), step, data_cfg)
        return sup.run(params, opt, 12, failure_injector=injector)

    p_ok, _, _, r_ok = run(str(tmp_path / "a"), None)
    p_f, _, _, r_f = run(str(tmp_path / "b"), inject_failure_once(9))
    assert r_ok == 0 and r_f == 1
    for a, b in zip(jax.tree.leaves(p_ok), jax.tree.leaves(p_f)):
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_flags_and_rebalances():
    mon = StragglerMonitor(num_hosts=8, threshold=1.4)
    for _ in range(5):
        times = np.full(8, 1.0)
        times[3] = 2.5  # host 3 is slow
        mon.record(times)
    rep = mon.report()
    assert rep.flagged_hosts == [3]
    assert rep.suggestion[3] < 0.5   # give it ~40% of the work
    assert rep.suggestion[0] == 1.0
