"""Tests for the cycle-accurate engine models + paper-claim validation."""

import numpy as np
import pytest

from repro.core.perfmodel import (
    DeMMEngine,
    GemmShape,
    PAPER_ENGINES_RELAXED,
    S2TAEngine,
    SpotsEngine,
    VegetaEngine,
    improvement,
    nm_mask,
    resnet50_gemms,
    convnext_t_gemms,
    run_network,
    unstructured_mask,
)


def test_resnet50_gemm_inventory():
    gemms = resnet50_gemms()
    # 53 convs + fc in ResNet50
    assert sum(g.count for g in gemms) == 54
    conv1 = gemms[0]
    assert (conv1.r, conv1.k, conv1.p) == (64, 147, 12544)
    assert not conv1.sparse


def test_convnext_gemm_inventory():
    gemms = convnext_t_gemms()
    assert any("dw7x7" in g.name for g in gemms)
    assert sum(g.count for g in gemms) > 50


def test_masks():
    rng = np.random.default_rng(0)
    m = unstructured_mask(rng, 100, 1000, 0.95)
    assert 0.03 < m.mean() < 0.07
    nm = nm_mask(rng, 64, 128, 1, 4)
    grp = nm.reshape(64, 32, 4)
    assert np.all(grp.sum(-1) == 1)


def test_demm_denser_patterns_cost_more_cycles():
    """k-reconfiguration semantics: latency scales with ceil(z/N)."""
    eng = DeMMEngine(8, 128, 64, 8)
    shape = GemmShape("x", 128, 1152, 784)
    rng = np.random.default_rng(0)
    lat = [eng.gemm_cycles(shape, nm_mask(rng, 128, 1152, 1, m))
           for m in (8, 4, 2)]
    assert lat[0] < lat[1] < lat[2]
    # 1:2 (64 nnz/group, 8 cycles/row) ≈ 4x the 1:8 (16 nnz, 2 cycles/row),
    # minus preload amortization
    assert 2.5 < lat[2] / lat[0] < 5.0


def test_demm_skips_empty_rows_and_groups():
    eng = DeMMEngine(2, 16, 16, 1)
    shape = GemmShape("x", 32, 64, 64)
    empty = np.zeros((32, 64), bool)
    one = empty.copy()
    one[0, 0] = True
    assert eng.gemm_cycles(shape, one) > 0
    # empty mask costs only preload+pipe, far less than a dense one
    dense = np.ones((32, 64), bool)
    assert eng.gemm_cycles(shape, empty) < eng.gemm_cycles(shape, dense) / 3


def test_vegeta_violation_passes():
    eng = VegetaEngine(1, 16)
    shape = GemmShape("x", 16, 512, 64)
    rng = np.random.default_rng(0)
    ok = nm_mask(rng, 16, 512, 1, 16)          # exactly native
    bad = ok.copy()
    bad[:, :4] = True                          # clustered violations
    assert eng.gemm_cycles(shape, bad) > eng.gemm_cycles(shape, ok)


def test_spots_cannot_skip_finegrained():
    """Paper: SPOTS degenerates on fine-grained N:M (no contiguous zeros)."""
    eng = SpotsEngine()
    shape = GemmShape("x", 16, 512, 256)
    rng = np.random.default_rng(0)
    fine = nm_mask(rng, 16, 512, 1, 4)         # 1 nz in every 4-group
    coarse = unstructured_mask(rng, 16, 512, 0.75)
    assert eng.gemm_cycles(shape, fine) >= eng.gemm_cycles(shape, coarse)


def test_all_engines_resource_equalized():
    for e in PAPER_ENGINES_RELAXED():
        assert e.macs == 512


# ---- paper-claim validation (the reproduction gate) ----

def test_fig6_claims_within_tolerance():
    """Overall-latency improvements vs the paper's 18/54/67 claims.
    Analytical third-party engine models: accept ±6 points."""
    gemms = resnet50_gemms()
    engines = PAPER_ENGINES_RELAXED()
    res = run_network(engines, gemms,
                      lambda rng, s: unstructured_mask(rng, s.r, s.k, 0.95),
                      seed=0)
    names = [e.name for e in engines]
    claims = [0.18, 0.54, 0.67]
    for other, claim in zip(names[1:], claims):
        imp = improvement(res, names[0], other)
        assert abs(imp - claim) < 0.06, (other, imp, claim)


def test_fig8_vegeta_density_trend():
    """Paper Fig. 8 trend: DeMM's advantage over VEGETA is largest at 1:8
    and shrinks with density (39 -> 12 -> 5)."""
    imps = []
    for n, m in [(1, 8), (1, 4), (1, 2)]:
        from repro.core.perfmodel import FINEGRAINED_ENGINES
        engines = FINEGRAINED_ENGINES(n, m)
        res = run_network(engines, resnet50_gemms(),
                          lambda rng, s: nm_mask(rng, s.r, s.k, n, m), seed=1)
        names = [e.name for e in engines]
        imps.append(improvement(res, names[0], names[2]))
    assert imps[0] > imps[1] >= imps[2] - 0.02
    assert imps[0] > 0.15  # DeMM clearly ahead at 1:8
