"""Attention correctness: flash vs naive, window semantics, banded scan,
ring-buffer decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env: deterministic example replay
    from _hypothesis_compat import given, settings, strategies as st

from repro.models.attention import decode_attention, flash_attention


def naive_attention(q, k, v, *, causal=True, window=-1):
    b, t, hq, dh = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, t, hkv, g, dh).astype(np.float32)
    logits = np.einsum("bthgd,bshd->bhgts", qg,
                       np.asarray(k, np.float32)) * dh ** -0.5
    logits = logits.reshape(b, hq, t, s)
    qpos = np.arange(t)[:, None]
    kpos = np.arange(s)[None, :]
    mask = np.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    logits = np.where(mask[None, None], logits, -1e30)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    pg = p.reshape(b, hkv, g, t, s)
    out = np.einsum("bhgts,bshd->bthgd", pg, np.asarray(v, np.float32))
    return out.reshape(b, t, hq, dh)


def _qkv(b=2, t=64, hq=4, hkv=2, dh=16, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, t, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, hkv, dh)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("q_chunk,kv_chunk", [(16, 16), (32, 16), (64, 64),
                                              (16, 32)])
def test_flash_matches_naive_causal(q_chunk, kv_chunk):
    q, k, v = _qkv()
    got = flash_attention(q, k, v, causal=True, q_chunk=q_chunk,
                          kv_chunk=kv_chunk)
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("window", [1, 7, 16, 33, 64])
def test_flash_window_mask(window):
    q, k, v = _qkv(seed=1)
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_chunk=16, kv_chunk=16)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("window", [8, 16, 24, 40])
@pytest.mark.parametrize("q_chunk,kv_chunk", [(16, 16), (8, 16), (16, 8)])
def test_banded_static_window_matches_full_scan(window, q_chunk, kv_chunk):
    """The banded inner scan (static_window) must equal the full-scan
    masked computation — the §Perf iteration-3 optimization is exact."""
    q, k, v = _qkv(seed=2, t=128)
    full = flash_attention(q, k, v, causal=True, window=window,
                           q_chunk=q_chunk, kv_chunk=kv_chunk)
    banded = flash_attention(q, k, v, causal=True, static_window=window,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_non_causal_cross_attention():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 24, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 40, 4, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 40, 4, 8)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, q_chunk=8, kv_chunk=16)
    b, t, hq, dh = q.shape
    logits = np.einsum("bthd,bshd->bhts", np.asarray(q, np.float32),
                       np.asarray(k, np.float32)) * dh ** -0.5
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhts,bshd->bthd", p, np.asarray(v, np.float32))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([17, 31, 64, 100]),
       seed=st.integers(0, 2**31 - 1))
def test_property_flash_ragged_lengths(t, seed):
    """Non-chunk-multiple sequence lengths are padded correctly."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, t, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, t, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, t, 2, 8)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_decode_attention_matches_full():
    """Decode against a cache == last-row of full attention."""
    q, k, v = _qkv(seed=4, t=32)
    full = naive_attention(q, k, v, causal=True)
    got = decode_attention(q[:, -1:], k, v,
                           cache_len=jnp.full((2,), 32, jnp.int32))
    np.testing.assert_allclose(np.asarray(got)[:, 0], full[:, -1], rtol=1e-4,
                               atol=1e-4)

# ---------------------------------------------------------------------------
# Ring-buffer (sliding-window) cache wraparound
# ---------------------------------------------------------------------------

def _windowed_model(arch, **overrides):
    import dataclasses

    from repro.configs.base import get_arch
    from repro.models.families import build_model

    # float32 compute so the decode-vs-sequence comparison is tight
    cfg = dataclasses.replace(get_arch(arch).reduced(),
                              compute_dtype="float32", **overrides)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def test_ring_cache_wraparound_matches_sequence_prefill():
    """Decoding far past ``window`` must keep matching the sequence-level
    windowed path: each ring slot is overwritten (pos % W) exactly when its
    old position leaves the window, and ``slot_pos`` masks the rest."""
    cfg, model, params = _windowed_model("h2o_danube_1_8b", window=8)
    T = 21                                          # 2.6 windows deep
    tokens = ((np.arange(T) * 7 + 3) % cfg.vocab_size).astype(np.int32)
    step = jax.jit(lambda p, s, t: model.decode_step(p, s, t))
    state = model.init_decode_state(1, 32, dtype=jnp.float32)
    for t in range(T):
        logits, state = step(params, state,
                             jnp.asarray([[tokens[t]]], jnp.int32))
        if t in (6, 11, 20):                        # pre-, mid-, post-wrap
            want, _ = model.prefill(
                params, {"tokens": jnp.asarray(tokens[None, :t + 1])})
            np.testing.assert_allclose(
                np.asarray(logits[0, 0], np.float32),
                np.asarray(want[0, 0], np.float32), rtol=2e-4, atol=2e-4)


def test_ring_cache_slot_pos_eviction_bookkeeping():
    """After T decode steps with window W, slot s must hold the *latest*
    absolute position p < T with p % W == s — older positions are evicted
    by overwrite, never masked back in."""
    cfg, model, params = _windowed_model("h2o_danube_1_8b", window=8)
    T, W = 21, 8
    step = jax.jit(lambda p, s, t: model.decode_step(p, s, t))
    state = model.init_decode_state(1, 32, dtype=jnp.float32)
    for t in range(T):
        _, state = step(params, state, jnp.asarray([[t % cfg.vocab_size]],
                                                   jnp.int32))
    slot_pos = np.asarray(state["caches"]["ring"]["slot_pos"])  # (L, B, W)
    want = np.array([max(p for p in range(T) if p % W == s)
                     for s in range(W)])
    assert np.all(slot_pos == want[None, None, :])
    assert int(state["pos"][0]) == T


def test_local_global_rings_wrap_past_local_window():
    """local_global archs mix windowed (local) and full (tail) layers; the
    local rings must survive wraparound too."""
    cfg, model, params = _windowed_model("gemma3_1b", local_window=8)
    T = 19
    tokens = ((np.arange(T) * 5 + 1) % cfg.vocab_size).astype(np.int32)
    step = jax.jit(lambda p, s, t: model.decode_step(p, s, t))
    state = model.init_decode_state(1, 32, dtype=jnp.float32)
    logits = None
    for t in range(T):
        logits, state = step(params, state,
                             jnp.asarray([[tokens[t]]], jnp.int32))
    want, _ = model.prefill(params, {"tokens": jnp.asarray(tokens[None])})
    np.testing.assert_allclose(np.asarray(logits[0, 0], np.float32),
                               np.asarray(want[0, 0], np.float32),
                               rtol=2e-4, atol=2e-4)
