"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train step on CPU, asserting output shapes + finiteness (the brief's
deliverable (f)).  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.models.families import build_model


def _batch_for(cfg, b=2, t=32, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t))),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t))),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.d_model)),
            jnp.float32)
        batch["targets"] = batch["targets"]  # text-position targets only
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, t // cfg.encoder_seq_divisor,
                                 cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_train_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    loss, metrics = jax.jit(
        lambda p, b: model.train_loss(p, b, mode="masked"))(params, batch)
    assert np.isfinite(float(loss)), f"{arch_id}: loss not finite"
    assert float(loss) > 0

    # one gradient step exists and is finite on every leaf
    grads = jax.jit(jax.grad(
        lambda p, b: model.train_loss(p, b, mode="masked")[0]))(params, batch)
    finite = jax.tree.map(
        lambda g: bool(jnp.all(jnp.isfinite(g))) if g.dtype.kind == "f" else True,
        grads)
    assert all(jax.tree.leaves(finite)), f"{arch_id}: non-finite grads"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_decode_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, max_len = 2, 16
    state = model.init_decode_state(b, max_len)
    if cfg.family == "audio":
        state["enc_out"] = jnp.zeros((b, 8, cfg.d_model), jnp.bfloat16)
    tokens = jnp.zeros((b, 1), jnp.int32)
    step = jax.jit(lambda p, s, t: model.decode_step(p, s, t))
    logits, state = step(params, state, tokens)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    logits2, state = step(params, state, tokens)
    assert int(state["pos"][0]) == 2
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch_id", ["stablelm_3b", "h2o_danube_1_8b",
                                     "gemma3_1b", "xlstm_125m", "zamba2_7b"])
def test_decode_matches_full_forward(arch_id):
    """Strong invariant: token-by-token decode logits == full-sequence
    forward logits at every position (same params, same inputs)."""
    cfg = get_arch(arch_id).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, t = 1, 8
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)))

    batch = {"tokens": tokens, "targets": tokens}
    # full-sequence logits via train-path backbone
    if cfg.family in ("hybrid", "ssm"):
        full_logits, _ = model.prefill(params, batch)
    else:
        from repro.models.layers import apply_unembedding, apply_rmsnorm
        dtype = jnp.bfloat16
        x = model._embed_inputs(params, batch, jnp.float32)
        x, _ = model._backbone_seq(params, x, positions=jnp.arange(t),
                                   policy=None)
        from repro.models.layers import apply_unembedding
        full = apply_unembedding(params["unembed"], x)

    state = model.init_decode_state(b, t + 1, dtype=jnp.float32)
    step = jax.jit(lambda p, s, tok: model.decode_step(p, s, tok))
    dec = []
    for i in range(t):
        logits, state = step(params, state, tokens[:, i:i + 1])
        dec.append(np.asarray(logits[:, 0], np.float32))
    dec = np.stack(dec, axis=1)  # (B, T, V)

    if cfg.family in ("hybrid", "ssm"):
        # compare the final-position logits (prefill returns last only)
        np.testing.assert_allclose(
            dec[:, -1], np.asarray(full_logits[:, 0], np.float32),
            rtol=2e-2, atol=2e-2)
    else:
        np.testing.assert_allclose(dec, np.asarray(full, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_vlm_prepends_patches():
    cfg = get_arch("internvl2_1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, _ = model.train_loss(params, batch)
    assert np.isfinite(float(loss))


def test_moe_aux_loss_nonzero():
    cfg = get_arch("olmoe_1b_7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, metrics = model.train_loss(params, batch)
    assert float(metrics["aux"]) > 0
