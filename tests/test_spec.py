"""repro.spec tests: draft-tier views over one packed tree, replay-safe
coupled sampling, and speculative-decode token identity on both engines
(DESIGN.md §15)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.sparse_linear import ExecPolicy
from repro.core.sparsity import PackedWeight, SparsityConfig
from repro.launch.pack_tree import pack_tree
from repro.models.families import build_model
from repro.obs.metrics import MetricsRegistry
from repro.serve import Request, ServeConfig, make_engine
from repro.spec import (ReplaySafeSampler, SpecConfig, derive_draft_tier,
                        parse_tier, position_noise, tier_sort_tree)
from repro.spec.decode import guard_cache_kinds

from helpers import run_with_devices

# 8:16 pattern on every node -> a 4:16 draft tier narrows the k-reconfigured
# weights (the arch default's per-node auto-clamp would leave most nodes
# un-narrowable).
DRAFT = "4:16"
POLICY = ExecPolicy(mode="packed", backend="reference")


@pytest.fixture(scope="module")
def spec_setup():
    cfg = dataclasses.replace(get_arch("stablelm_3b").reduced(),
                              sparsity=SparsityConfig(8, 16, 1))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    packed = tier_sort_tree(pack_tree(params))
    return cfg, model, packed


def _submit(engine, vocab, n=4, max_new=8, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(n):
        prompt = rng.integers(0, vocab, 5 + i % 3, dtype=np.int32)
        engine.submit(Request(uid=i, prompt=prompt, max_new_tokens=max_new,
                              priority=i % 2))
    engine.run_until_drained()
    return {r.uid: r.output for r in engine.completed}


def _pws(tree):
    return [x for x in jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: isinstance(x, PackedWeight))[0]
        if isinstance(x, PackedWeight)]


# ---------------------------------------------------------------------------
# Tier derivation
# ---------------------------------------------------------------------------

def test_parse_tier():
    assert parse_tier("8:128") == (8, 128)
    for bad in ("8", "0:16", "16:8", "a:b"):
        with pytest.raises(ValueError):
            parse_tier(bad)


def test_draft_tier_aliases_full_buffers(spec_setup):
    """ISSUE acceptance: the draft tier is a *view* — `draft.values is
    full.values` — not a copy."""
    _, _, packed = spec_setup
    draft, report = derive_draft_tier(packed, DRAFT)
    assert report.narrowed >= 1
    narrowed = 0
    for f, d in zip(_pws(packed), _pws(draft)):
        assert d.values is f.values
        assert d.indices is f.indices
        if d.tier_ne is not None:
            narrowed += 1
            assert d.tier_ne == 4 and f.tier_ne is None
            assert d.cfg == f.cfg  # retag happens at narrow time, not here
    assert narrowed == report.narrowed


def test_draft_tier_nothing_to_narrow_raises(spec_setup):
    _, _, packed = spec_setup
    with pytest.raises(ValueError, match="narrows no"):
        derive_draft_tier(packed, "8:16")  # not sparser than the pack


# ---------------------------------------------------------------------------
# Replay-safe sampling
# ---------------------------------------------------------------------------

def test_position_noise_is_counter_keyed():
    a = position_noise(seed=7, rid=3, pos=11, n=64)
    b = position_noise(seed=7, rid=3, pos=11, n=64)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, position_noise(seed=7, rid=3, pos=12, n=64))
    assert not np.array_equal(a, position_noise(seed=7, rid=4, pos=11, n=64))
    assert not np.array_equal(a, position_noise(seed=8, rid=3, pos=11, n=64))


def test_sampler_greedy_is_argmax():
    s = ReplaySafeSampler(temperature=0.0, top_k=0, seed=0)
    logits = np.random.default_rng(0).standard_normal(50).astype(np.float32)
    assert s.sample(logits, rid=1, pos=2) == int(np.argmax(logits))


def test_sampler_replays_and_respects_top_k():
    s = ReplaySafeSampler(temperature=0.9, top_k=4, seed=1)
    logits = np.random.default_rng(1).standard_normal(50).astype(np.float32)
    allowed = set(np.argsort(-logits)[:4].tolist())
    seen = set()
    for pos in range(40):
        tok = s.sample(logits, rid=5, pos=pos)
        assert tok == s.sample(logits, rid=5, pos=pos)  # replay-exact
        assert tok in allowed
        seen.add(tok)
    assert len(seen) > 1  # actually stochastic across positions


# ---------------------------------------------------------------------------
# Cache-kind guard
# ---------------------------------------------------------------------------

def test_guard_rejects_non_rollbackable_state():
    cfg = get_arch("xlstm_125m").reduced()
    model = build_model(cfg)
    state = model.init_decode_state(batch=1, max_len=16)
    with pytest.raises(NotImplementedError, match="roll back"):
        guard_cache_kinds(state)


# ---------------------------------------------------------------------------
# Token identity: speculative == non-speculative, both engines
# ---------------------------------------------------------------------------

def _engines(model, packed, paged, temperature=0.0, top_k=0, seed=0,
             spec=None, num_pages=None, max_len=64):
    if paged:
        from repro.paged import PagedServeConfig
        cfg = PagedServeConfig(num_slots=2, max_len=max_len, page_size=4,
                               num_pages=num_pages, temperature=temperature,
                               top_k=top_k, seed=seed)
    else:
        cfg = ServeConfig(num_slots=2, max_len=max_len,
                          temperature=temperature, top_k=top_k, seed=seed)
    # fresh registry per engine: the default is process-global, and these
    # tests read preempt/spec counters
    return make_engine(model, packed, cfg, policy=POLICY, spec=spec,
                       metrics=MetricsRegistry())


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_spec_greedy_token_identity(spec_setup, paged):
    cfg, model, packed = spec_setup
    ref = _submit(_engines(model, packed, paged), cfg.vocab_size)
    eng = _engines(model, packed, paged, spec=SpecConfig(draft=DRAFT, gamma=3))
    got = _submit(eng, cfg.vocab_size)
    assert ref == got
    sm = eng._spec_metrics
    assert sm._verify_dispatches > 0
    assert sm._committed_total / sm._verify_dispatches > 1.0


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_spec_sampled_token_identity(spec_setup, paged):
    """Gumbel-max coupling: the committed stream matches non-spec at
    temperature > 0 too, not just greedy."""
    cfg, model, packed = spec_setup
    kw = dict(temperature=0.8, top_k=8, seed=3)
    ref = _submit(_engines(model, packed, paged, **kw), cfg.vocab_size)
    got = _submit(_engines(model, packed, paged, spec=SpecConfig(
        draft=DRAFT, gamma=3), **kw), cfg.vocab_size)
    assert ref == got


def test_spec_identity_across_engines(spec_setup):
    """Dense non-spec, dense spec, paged spec: one token stream."""
    cfg, model, packed = spec_setup
    ref = _submit(_engines(model, packed, paged=False), cfg.vocab_size)
    spec = SpecConfig(draft=DRAFT, gamma=4)
    dense = _submit(_engines(model, packed, paged=False, spec=spec),
                    cfg.vocab_size)
    paged = _submit(_engines(model, packed, paged=True, spec=spec),
                    cfg.vocab_size)
    assert ref == dense == paged


def test_spec_gamma_clamp_near_max_len(spec_setup):
    """Windows shrink (and fall back to plain steps) as lanes approach
    max_len; the stream must survive the clamp path."""
    cfg, model, packed = spec_setup
    ref = _submit(_engines(model, packed, paged=False, max_len=20),
                  cfg.vocab_size, max_new=16)
    got = _submit(_engines(model, packed, paged=False, max_len=20,
                           spec=SpecConfig(draft=DRAFT, gamma=4)),
                  cfg.vocab_size, max_new=16)
    assert ref == got


# ---------------------------------------------------------------------------
# Preempt -> re-prefill -> resume replay (satellite: RNG replay)
# ---------------------------------------------------------------------------

def _preempts(engine):
    rows = [c for c in engine.metrics.snapshot(meta=False)["counters"]
            if c["name"] == "serve_preempt_total"]
    return rows[0]["value"] if rows else 0


@pytest.mark.parametrize("spec", [None, SpecConfig(draft=DRAFT, gamma=3)],
                         ids=["plain", "spec"])
def test_sampled_stream_survives_preemption(spec_setup, spec):
    """A temperature>0 request preempted mid-generation under page pressure
    resumes bit-identically: the Philox(seed, rid, pos) counter stream does
    not depend on scheduling history."""
    cfg, model, packed = spec_setup
    kw = dict(paged=True, temperature=0.8, top_k=8, seed=5, max_len=48)
    roomy = _engines(model, packed, num_pages=64, spec=spec, **kw)
    ref = _submit(roomy, cfg.vocab_size, n=5, max_new=10, seed=5)

    assert _preempts(roomy) == 0

    tight = _engines(model, packed, num_pages=8, spec=spec, **kw)
    got = _submit(tight, cfg.vocab_size, n=5, max_new=10, seed=5)
    assert _preempts(tight) > 0, "arena never preempted; test is vacuous"
    assert ref == got


# ---------------------------------------------------------------------------
# TP=2: draft tier shards with the full tier's plan (forced host devices)
# ---------------------------------------------------------------------------

_TP_SPEC = r"""
import dataclasses, numpy as np, jax
from repro.configs.base import get_arch
from repro.core.sparsity import PackedWeight, SparsityConfig
from repro.models.families import build_model
from repro.launch.serve import run_serve
from repro.sharding.plan import ShardingPlan

cfg = dataclasses.replace(get_arch("stablelm_3b").reduced(),
                          sparsity=SparsityConfig(8, 16, 1))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
kw = dict(packed=True, requests=3, max_new=6, seed=0,
          plan=ShardingPlan(tp=2))
base = run_serve(model, params, cfg.vocab_size, **kw)
ref = {r.uid: r.output for r in base.completed}
sp = run_serve(model, params, cfg.vocab_size, spec_draft="4:16",
               spec_gamma=3, **kw)
got = {r.uid: r.output for r in sp.completed}
assert ref == got, (ref, got)
assert sp._spec_metrics.drafted.value > 0

def pws(tree):
    return [x for x in jax.tree_util.tree_flatten(
        tree, is_leaf=lambda y: isinstance(y, PackedWeight))[0]
        if isinstance(x, PackedWeight)]

sharded_narrowed = 0
for f, d in zip(pws(sp.params), pws(sp._draft_params)):
    assert d.values is f.values, "draft tier copied a sharded buffer"
    if d.tier_ne is not None and f.shard_axis is not None:
        sharded_narrowed += 1
        per = [s.data.nbytes for s in d.values.addressable_shards]
        assert len(per) == 2 and all(b < d.values.nbytes for b in per), per
assert sharded_narrowed, "no narrowed node is TP-sharded; test is vacuous"
print("TP_SPEC_OK", sharded_narrowed)
"""


def test_tp2_spec_token_identity_and_sharded_draft():
    out = run_with_devices(_TP_SPEC, n_devices=2)
    assert "TP_SPEC_OK" in out
