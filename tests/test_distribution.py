"""Multi-device distribution tests (subprocess with 8 host devices):
sharding correctness, MoE expert parallelism, pipeline parallelism,
elastic checkpoint restore, compressed psum, and a mini dry-run."""

import pytest

from helpers import run_with_devices


def test_tp_dp_train_step_matches_single_device():
    """A distributed train step on a 2x4 mesh must match the single-device
    result numerically (same params, same batch)."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_arch
from repro.models.families import build_model
from repro.optim import adamw
from repro.train.train_loop import make_train_step
from repro.sharding.partitioning import opt_state_specs, shardings_for
from repro.sharding.plan import ShardingPlan
from repro.sharding import context as shctx

cfg = get_arch("stablelm_3b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
opt = adamw.init(opt_cfg, params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16))),
         "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)))}

# single device reference
step = make_train_step(model, opt_cfg, num_microbatches=2)
p_ref, _, m_ref = jax.jit(step)(params, opt, batch, 0)

# distributed
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = shctx.make_context(mesh, num_kv_heads=cfg.num_kv_heads)
pspecs = ShardingPlan().param_specs(params)
pshard = shardings_for(mesh, pspecs)
zspecs = opt_state_specs(pspecs, params, mesh.shape["data"])
ospecs = adamw.AdamWState(step=P(), m=zspecs, v=zspecs, compression=None)
oshard = shardings_for(mesh, ospecs)
bshard = jax.tree.map(lambda x: NamedSharding(mesh, P(("data",), None)), batch)
params_d = jax.device_put(params, pshard)
opt_d = jax.device_put(opt, oshard)
batch_d = jax.device_put(batch, bshard)
with shctx.use_mesh(ctx):
    p_dist, _, m_dist = jax.jit(
        step, in_shardings=(pshard, oshard, bshard, None),
        out_shardings=(pshard, oshard, None))(params_d, opt_d, batch_d, 0)

assert abs(float(m_ref["loss"]) - float(m_dist["loss"])) < 1e-3, \
    (float(m_ref["loss"]), float(m_dist["loss"]))
for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_dist)):
    if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)
print("TP/DP train step matches single-device")
""")


def test_moe_expert_parallel_matches_local():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.sharding import context as shctx

cfg = MoEConfig(num_experts=8, experts_per_token=2, d_ff_expert=32)
params = moe_mod.init_moe(jax.random.PRNGKey(0), 64, cfg, sparse=None)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64))

y_ref, aux_ref = moe_mod._apply_moe_local(params, x, cfg, capacity=64)

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = shctx.make_context(mesh, num_kv_heads=16)
# drop-free capacities on both paths -> results must agree exactly
with shctx.use_mesh(ctx):
    y_ep, aux_ep = jax.jit(
        lambda p, x: moe_mod.apply_moe(p, x, cfg, capacity=64))(params, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=2e-2, atol=2e-2)
print("MoE EP matches local dispatch")
""")


def test_pipeline_parallel_matches_sequential():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.sharding.pipeline import pipeline_apply

n_stages, num_mb, mb, d = 8, 4, 2, 16
keys = jax.random.split(jax.random.PRNGKey(0), n_stages)
stage_params = {"w": jnp.stack([
    jax.random.normal(k, (d, d)) * 0.3 for k in keys])}

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])

x = jax.random.normal(jax.random.PRNGKey(1), (num_mb, mb, d))
# sequential reference
y_ref = x
for i in range(n_stages):
    y_ref = jax.vmap(lambda xx: stage_fn({"w": stage_params["w"][i]}, xx))(y_ref)

mesh = jax.make_mesh((8,), ("pipe",))
y_pipe = jax.jit(lambda p, x: pipeline_apply(stage_fn, p, x, mesh))(
    stage_params, x)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                           rtol=1e-5, atol=1e-5)

# differentiability
g = jax.grad(lambda p: pipeline_apply(stage_fn, p, x, mesh).sum())(
    stage_params)
assert np.all(np.isfinite(np.asarray(g["w"])))
print("pipeline == sequential, grads finite")
""")


def test_elastic_restore_to_smaller_mesh(tmp_path):
    run_with_devices(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import elastic_restore

mesh8 = jax.make_mesh((4, 2), ("data", "model"))
mesh4 = jax.make_mesh((2, 2), ("data", "model"))
spec = {{"w": P("model", None)}}
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
tree = {{"w": jax.device_put(w, NamedSharding(mesh8, spec["w"]))}}
ckpt.save(tree, r"{tmp_path}", 1)
restored = elastic_restore({{"w": w}}, r"{tmp_path}", 1, mesh4, spec)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
assert restored["w"].sharding.mesh.shape["data"] == 2
print("elastic restore ok")
""")


def test_compressed_psum_int8():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim.compression import compressed_psum_int8

mesh = jax.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

out = shard_map(lambda v: compressed_psum_int8(v[0], "data")[None],
                mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                check_rep=False)(x)
want = x.sum(0)
got = np.asarray(out[0])
scale = float(jnp.max(jnp.abs(x))) / 127
assert np.max(np.abs(got - np.asarray(want))) < scale * 8
print("compressed psum ok")
""")


def test_mini_dryrun_lower_compile():
    """The dry-run machinery on a small mesh: reduced config lower+compile
    with memory/cost/collective extraction end to end."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_arch
from repro.models.families import build_model
from repro.optim import adamw
from repro.train.train_loop import make_train_step
from repro.sharding.partitioning import opt_state_specs, shardings_for
from repro.sharding.plan import ShardingPlan
from repro.sharding import context as shctx
from repro.launch import hlo_analysis

cfg = get_arch("olmoe_1b_7b").reduced()
model = build_model(cfg)
pshapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = shctx.make_context(mesh, num_kv_heads=cfg.num_kv_heads)
pspecs = ShardingPlan().param_specs(pshapes)
pshard = shardings_for(mesh, pspecs)
opt_cfg = adamw.AdamWConfig()
ostate = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), pshapes)
zspecs = opt_state_specs(pspecs, pshapes, mesh.shape["data"])
ospecs = adamw.AdamWState(step=P(), m=zspecs, v=zspecs, compression=None)
oshard = shardings_for(mesh, ospecs)
sds = jax.ShapeDtypeStruct
batch = {"tokens": sds((8, 32), jnp.int32), "targets": sds((8, 32), jnp.int32)}
bshard = jax.tree.map(lambda s: NamedSharding(mesh, P(("data",), None)), batch)
step = make_train_step(model, opt_cfg, num_microbatches=2)
with shctx.use_mesh(ctx):
    lowered = jax.jit(step, in_shardings=(pshard, oshard, bshard, None),
                      out_shardings=(pshard, oshard, None)).lower(
        pshapes, ostate, batch, jnp.zeros((), jnp.int32))
    compiled = lowered.compile()
mem = compiled.memory_analysis()
a = hlo_analysis.analyze(compiled.as_text())
assert a.flops > 0 and a.bytes_accessed > 0
assert a.unknown_trip_loops == 0
print("mini dryrun ok: flops=%.2e coll=%.2e" % (a.flops, a.collective_bytes))
""")
