"""repro.tune subsystem: registry dispatch, autotuner pruning, cache."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core.sparsity import SparsityConfig, pack, random_sparse_dense
from repro.kernels import ref as kref
from repro.kernels.ops import demm_matmul_xwT, demm_spmm

SP = SparsityConfig(2, 16)


def _xwT_problem(rows=8, o=32, k=64):
    return tune.Problem.for_xwT((rows, k), (o, k), SP, jnp.float32)


def _packed(rng, o=32, k=64):
    w = random_sparse_dense(rng, o, k, SP)
    return w, pack(jnp.asarray(w), SP)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_has_builtin_variants():
    assert set(tune.backend_names("xwT")) >= {
        "reference", "pallas", "pallas_interpret"}
    assert set(tune.backend_names("spmm")) >= {
        "reference", "pallas", "pallas_interpret", "block_spmm"}


def test_registry_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        tune.get_variant("xwT", "nope")
    with pytest.raises(ValueError, match="unknown op"):
        tune.Problem(op="nope", rows=1, out=1, k=16, dtype="float32",
                     sparsity=(2, 16, 1))


def test_registry_platform_filtering():
    p = _xwT_problem()
    names = {v.name for v in tune.variants_for("xwT", p)}
    # this suite runs on CPU: the real-hardware kernel must be filtered out
    if tune.current_platform() != "tpu":
        assert "pallas" not in names
    assert "reference" in names


def test_registry_dispatch_equivalence_xwT():
    """Every dispatchable registered variant agrees with the oracle."""
    rng = np.random.default_rng(0)
    w, p = _packed(rng)
    x = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
    want = kref.xwT_ref(x, p.values, p.indices, SP, (32, 64))
    prob = _xwT_problem()
    for v in tune.variants_for("xwT", prob):
        got = v.call(x, p.values, p.indices, SP, (32, 64),
                     **v.default_params(prob))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4, err_msg=v.name)


def test_registry_dispatch_equivalence_spmm():
    rng = np.random.default_rng(1)
    a = random_sparse_dense(rng, 32, 64, SP)
    pa = pack(jnp.asarray(a), SP)
    b = jnp.asarray(rng.standard_normal((64, 48)).astype(np.float32))
    want = kref.spmm_ref(pa.values, pa.indices, b, SP, (32, 64))
    prob = tune.Problem.for_spmm((32, 64), (64, 48), SP, jnp.float32)
    for v in tune.variants_for("spmm", prob, include_measure_only=True):
        got = v.call(pa.values, pa.indices, b, SP, (32, 64),
                     **v.default_params(prob))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4, err_msg=v.name)


def test_custom_variant_registration_and_dispatch():
    def doubled_ref(x, values, indices, cfg, w_shape, **_):
        return kref.xwT_ref(x, values, indices, cfg, w_shape)

    v = tune.KernelVariant(
        op="xwT", name="_test_variant", call=doubled_ref,
        param_space=lambda p: {}, default_params=lambda p: {},
        supported=lambda p: True)
    tune.register_variant(v)
    try:
        with pytest.raises(ValueError, match="already registered"):
            tune.register_variant(v)
        rng = np.random.default_rng(2)
        w, p = _packed(rng)
        x = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
        got = demm_matmul_xwT(x, p.values, p.indices, SP, (32, 64),
                              backend="_test_variant")
        want = kref.xwT_ref(x, p.values, p.indices, SP, (32, 64))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5)
    finally:
        from repro.tune.registry import _REGISTRY
        _REGISTRY.pop(("xwT", "_test_variant"), None)


# ---------------------------------------------------------------------------
# VMEM-budget pruning / candidate enumeration
# ---------------------------------------------------------------------------

def test_vmem_bytes_scales_with_tiles():
    p = _xwT_problem(rows=1024, o=1024, k=1024)
    small = tune.vmem_bytes(p, "pallas", {"block_b": 8, "block_o": 8})
    big = tune.vmem_bytes(p, "pallas", {"block_b": 512, "block_o": 512})
    assert 0 < small < big
    assert tune.vmem_bytes(p, "reference", {}) == 0


def test_prune_rejects_oversize_tiles():
    p = _xwT_problem(rows=512, o=512, k=64)
    cands = tune.enumerate_candidates(p)
    tiled = [c for c in cands if c.params]
    assert tiled, "expected tile candidates to enumerate"
    # a budget below every tiled candidate's working set rejects them all
    floor = min(tune.vmem_bytes(p, c.backend, c.params) for c in tiled)
    kept = tune.prune_candidates(p, cands, vmem_budget=floor - 1)
    assert all(not c.params for c in kept)
    assert all(c.status == "pruned_vmem" for c in tiled
               if c not in kept)


def test_prune_keeps_defaults_and_ranks_by_perfmodel():
    p = _xwT_problem(rows=64, o=64, k=64)
    cands = tune.enumerate_candidates(p)
    kept = tune.prune_candidates(p, cands, max_measure=3)
    names = {(c.backend, tuple(sorted(c.params.items()))) for c in kept}
    for v in tune.variants_for("xwT", p, include_measure_only=True):
        assert (v.name, tuple(sorted(v.default_params(p).items()))) in names
    assert all(c.est_cycles is not None for c in kept if c.params)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def test_cache_roundtrip_and_invalidate(tmp_path):
    path = str(tmp_path / "tune_cache.json")
    cache = tune.TuneCache(path)
    p = _xwT_problem()
    cfg = tune.TunedConfig("reference", {}, measured_us=12.5, source="tuned")
    cache.put(p, cfg, persist=True)

    fresh = tune.TuneCache(path)
    assert fresh.load() == 1
    got = fresh.get(p)
    assert got == cfg

    # a different problem key misses
    assert fresh.get(_xwT_problem(rows=16)) is None

    fresh.invalidate(p)
    assert fresh.get(p) is None

    # schema-version bump invalidates stale files
    blob = json.load(open(path))
    blob["version"] = -1
    json.dump(blob, open(path, "w"))
    stale = tune.TuneCache(path)
    assert stale.load() == 0


def test_cache_resolve_falls_back_to_heuristic(tmp_path):
    cache = tune.TuneCache(str(tmp_path / "c.json"))
    p = _xwT_problem()
    got = cache.resolve(p)
    assert got.source == "heuristic"
    if tune.current_platform() != "tpu":
        assert got.backend == "reference"


def test_heuristic_prefers_pallas_on_tpu():
    p = tune.Problem(op="xwT", rows=256, out=256, k=256, dtype="bfloat16",
                     sparsity=(8, 128, 1), platform="tpu")
    got = tune.heuristic_default(p)
    assert got.backend == "pallas"
    assert got.params == {"block_b": 128, "block_o": 128}


# ---------------------------------------------------------------------------
# Autotune end-to-end + auto backend
# ---------------------------------------------------------------------------

def test_autotune_and_auto_backend_match_reference(tmp_path):
    cache = tune.TuneCache(str(tmp_path / "c.json"))
    tune.set_default_cache(cache)
    try:
        rng = np.random.default_rng(3)
        w, p = _packed(rng)
        x = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
        res = tune.autotune_xwT(x, p.values, p.indices, SP, (32, 64),
                                max_measure=3, warmup=1, iters=2,
                                cache=cache, persist=True)
        assert res.best.measured_us > 0
        assert res.best.source == "tuned"
        # the tuned choice is never slower than any measured default
        defaults = [c for c in res.candidates if c.status == "measured"]
        assert all(res.best.measured_us <= c.measured_s * 1e6 + 1e-9
                   for c in defaults if c.measured_s)

        # dispatch through backend="auto" resolves the tuned entry and
        # matches the oracle (inside jit: resolution is trace-safe)
        got = jax.jit(
            lambda xx, vv, ii: demm_matmul_xwT(
                xx, vv, ii, SP, (32, 64), backend="auto")
        )(x, p.values, p.indices)
        want = kref.xwT_ref(x, p.values, p.indices, SP, (32, 64))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
    finally:
        tune.set_default_cache(None)


def test_auto_backend_spmm_matches_reference():
    rng = np.random.default_rng(4)
    a = random_sparse_dense(rng, 16, 32, SP)
    pa = pack(jnp.asarray(a), SP)
    b = jnp.asarray(rng.standard_normal((32, 24)).astype(np.float32))
    got = demm_spmm(pa.values, pa.indices, b, SP, (16, 32), backend="auto")
    want = kref.spmm_ref(pa.values, pa.indices, b, SP, (16, 32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_xwT_grads_unaffected_by_auto_backend():
    rng = np.random.default_rng(5)
    w, p = _packed(rng, o=16, k=32)
    x = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))

    def loss(xx, vv, backend):
        y = demm_matmul_xwT(xx, vv, p.indices, SP, (16, 32), backend=backend)
        return jnp.sum(y ** 2)

    gx_auto, gv_auto = jax.grad(loss, argnums=(0, 1))(x, p.values, "auto")
    gx_ref, gv_ref = jax.grad(loss, argnums=(0, 1))(x, p.values, "reference")
    np.testing.assert_allclose(np.asarray(gx_auto), np.asarray(gx_ref),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gv_auto), np.asarray(gv_ref),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# Ragged (non-divisible) shapes through the padded Pallas kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bx,o", [(10, 24), (1, 32), (17, 31)])
def test_xwT_pallas_ragged_shapes(bx, o):
    from repro.kernels.demm_spmm import demm_xwT_pallas

    rng = np.random.default_rng(6)
    w = random_sparse_dense(rng, o, 48, SP)
    pw = pack(jnp.asarray(w), SP)
    x = jnp.asarray(rng.standard_normal((bx, 48)).astype(np.float32))
    got = demm_xwT_pallas(x, pw.values, pw.indices, SP, block_b=16,
                          block_o=16, interpret=True)
    want = kref.xwT_ref(x, pw.values, pw.indices, SP, (o, 48))
    assert got.shape == (bx, o)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("r,cd", [(21, 37), (8, 100), (33, 16)])
def test_spmm_pallas_ragged_shapes(r, cd):
    from repro.kernels.demm_spmm import demm_spmm_pallas

    rng = np.random.default_rng(7)
    a = random_sparse_dense(rng, r, 32, SP)
    pa = pack(jnp.asarray(a), SP)
    b = jnp.asarray(rng.standard_normal((32, cd)).astype(np.float32))
    got = demm_spmm_pallas(pa.values, pa.indices, b, SP, block_r=16,
                           block_c=16, interpret=True)
    want = kref.spmm_ref(pa.values, pa.indices, b, SP, (r, 32))
    assert got.shape == (r, cd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
