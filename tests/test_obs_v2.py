"""Observability v2 tests (DESIGN.md §16): quantile-sketch math and exact
merge, request-scoped trace context, drop accounting, SLO/goodput reports,
the flight recorder + stall watchdog, Perfetto export / trace propagation
on a real paged run, and router-merged sketches under speculative decoding
across DP replicas."""

import dataclasses
import json
import math
import pathlib
import time
from types import SimpleNamespace

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env: deterministic example replay
    from _hypothesis_compat import given, settings, strategies as st

from repro import obs
from repro.configs.base import get_arch
from repro.core.sparse_linear import ExecPolicy
from repro.core.sparsity import SparsityConfig
from repro.launch.pack_tree import pack_tree
from repro.models.families import build_model
from repro.obs import MetricsRegistry
from repro.obs.context import TraceContext, use
from repro.obs.export import (check_propagation, load_events, span_trees,
                              to_chrome_trace)
from repro.obs.recorder import FlightRecorder, Watchdog, subsystem_of
from repro.obs.sketch import DEFAULT_ALPHA, MIN_VALUE, QuantileSketch
from repro.obs.slo import (SLOConfig, phase_sketches, request_phases,
                           request_tokens, slo_report)
from repro.obs.trace import EventTrace
from repro.serve import Request, ServeConfig, make_engine
from repro.spec import SpecConfig, tier_sort_tree

# 8:16 pattern on every node -> a 4:16 draft tier narrows the
# k-reconfigured weights (same idiom as tests/test_spec.py)
DRAFT = "4:16"
POLICY = ExecPolicy(mode="packed", backend="reference")


@pytest.fixture(scope="module")
def spec_setup():
    cfg = dataclasses.replace(get_arch("stablelm_3b").reduced(),
                              sparsity=SparsityConfig(8, 16, 1))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    packed = tier_sort_tree(pack_tree(params))
    return cfg, model, packed


@pytest.fixture
def fresh_default_registry():
    """Isolate the process-wide registry (kernel dispatch / tune counters
    land there) and restore the previous one afterwards."""
    prev = obs.default_registry()
    reg = MetricsRegistry()
    obs.set_default_registry(reg)
    yield reg
    obs.set_default_registry(prev)


def _values(seed, n):
    """Positive latency-like values spanning µs..hours, none in the zero
    bucket (the shim only draws integers, so floats derive from a seed)."""
    rng = np.random.default_rng(seed)
    return 10.0 ** rng.uniform(-6.0, 3.5, size=n)


# ---------------------------------------------------------------------------
# sketch: relative-error bound, exact merge, serialization
# ---------------------------------------------------------------------------

@settings(max_examples=20)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 400))
def test_sketch_relative_error_bound(seed, n):
    """Every quantile estimate is within alpha (relative) of the true
    nearest-rank value, across 9+ orders of magnitude."""
    vals = _values(seed, n)
    sk = QuantileSketch(alpha=DEFAULT_ALPHA)
    for v in vals:
        sk.observe(v)
    ordered = np.sort(vals)
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        est = sk.quantile(q)
        true = ordered[int(math.floor(q * (n - 1)))]
        assert abs(est - true) <= DEFAULT_ALPHA * true * (1 + 1e-9), \
            f"q={q}: |{est} - {true}| > alpha*true"


@settings(max_examples=20)
@given(seed=st.integers(0, 2**31 - 1), cut_a=st.integers(0, 120),
       cut_b=st.integers(0, 120))
def test_sketch_merge_is_exact_and_order_free(seed, cut_a, cut_b):
    """Bucket-wise merge: any split/grouping/order of the observations
    yields identical bucket state, hence identical quantiles."""
    vals = _values(seed, 120)
    a, b = sorted((cut_a, cut_b))
    parts = [vals[:a], vals[a:b], vals[b:]]

    def sketch_of(chunk):
        sk = QuantileSketch(alpha=DEFAULT_ALPHA)
        for v in chunk:
            sk.observe(v)
        return sk

    whole = sketch_of(vals)
    # ((p0 + p1) + p2) and (p0 + (p2 + p1)): grouping and order both vary
    left = sketch_of(parts[0]).merge(sketch_of(parts[1])) \
        .merge(sketch_of(parts[2]))
    right = sketch_of(parts[0]).merge(
        sketch_of(parts[2]).merge(sketch_of(parts[1])))
    for merged in (left, right):
        assert merged.bins == whole.bins
        assert merged.zero_count == whole.zero_count
        assert merged.count == whole.count
        for q in (0.0, 0.5, 0.9, 0.99, 1.0):
            assert merged.quantile(q) == whole.quantile(q)


def test_sketch_empty_is_merge_identity():
    vals = _values(7, 50)
    sk = QuantileSketch()
    for v in vals:
        sk.observe(v)
    before = sk.to_entry()
    sk.merge(QuantileSketch())              # right identity
    assert sk.to_entry() == before
    other = QuantileSketch().merge(sk)      # left identity
    assert other.bins == sk.bins and other.count == sk.count
    assert QuantileSketch().quantile(0.5) is None
    assert len(QuantileSketch()) == 0


def test_sketch_alpha_mismatch_and_domain_errors():
    a = QuantileSketch(alpha=0.01)
    b = QuantileSketch(alpha=0.02)
    with pytest.raises(ValueError, match="different alpha"):
        a.merge(b)
    with pytest.raises(ValueError):
        QuantileSketch(alpha=1.5)
    with pytest.raises(ValueError):
        a.observe(-0.1)
    a.observe(1.0)
    with pytest.raises(ValueError):
        a.quantile(1.5)


def test_sketch_zero_bucket():
    sk = QuantileSketch()
    sk.observe(0.0)
    sk.observe(MIN_VALUE / 2)
    sk.observe(1.0)
    assert sk.zero_count == 2 and sk.count == 3
    assert sk.quantile(0.0) == 0.0
    assert sk.quantile(1.0) == pytest.approx(1.0, rel=DEFAULT_ALPHA)


def test_sketch_entry_roundtrip_survives_json():
    sk = QuantileSketch()
    for v in _values(3, 80):
        sk.observe(v)
    entry = json.loads(json.dumps(sk.to_entry()))   # snapshot wire format
    back = QuantileSketch.from_entry(entry)
    assert back.bins == sk.bins and back.count == sk.count
    for q in (0.1, 0.5, 0.99):
        assert back.quantile(q) == sk.quantile(q)
    assert sk.copy().quantile(0.5) == sk.quantile(0.5)


# ---------------------------------------------------------------------------
# registry: sketch as the fourth family kind
# ---------------------------------------------------------------------------

def test_registry_sketch_family_snapshot_and_prometheus():
    reg = MetricsRegistry()
    sk = reg.sketch("lat_sketch", help="latency", alpha=0.02, phase="decode")
    assert sk.alpha == 0.02
    # later registrations reuse the family alpha (mergeability)
    assert reg.sketch("lat_sketch", alpha=0.5, phase="prefill").alpha == 0.02
    for v in (0.001, 0.01, 0.01, 0.1):
        sk.observe(v)
    snap = reg.snapshot(meta=False)
    entries = [e for e in snap["sketches"] if e["name"] == "lat_sketch"]
    assert len(entries) == 2
    (e,) = [e for e in entries if e["labels"] == {"phase": "decode"}]
    assert e["alpha"] == 0.02 and e["count"] == 4
    assert sum(e["bins"].values()) + e["zero_count"] == e["count"]
    text = reg.to_prometheus()
    assert "# TYPE lat_sketch summary" in text
    assert 'lat_sketch{phase="decode",quantile="0.5"}' in text
    assert 'lat_sketch_count{phase="decode"} 4' in text
    # kind conflicts are rejected like any other family
    reg.counter("c").inc()
    with pytest.raises(ValueError):
        reg.sketch("c")


def test_registry_sketch_snapshot_passes_validator(tmp_path):
    import importlib.util
    root = pathlib.Path(__file__).resolve().parents[1]
    spec = importlib.util.spec_from_file_location(
        "validate_metrics", root / "benchmarks" / "validate_metrics.py")
    vm = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(vm)

    reg = MetricsRegistry()
    reg.counter("serve_requests_completed_total").inc(2)
    reg.sketch("serve_ttft_seconds_sketch").observe(0.05)
    path = tmp_path / "m.json"
    reg.write(str(path))
    assert vm.main([str(path),
                    "--schema", str(root / "benchmarks" /
                                    "metrics_schema.json"),
                    "--require-sketch", "serve_ttft_seconds_sketch"]) == 0
    # an absent sketch family fails the gate
    assert vm.main([str(path),
                    "--schema", str(root / "benchmarks" /
                                    "metrics_schema.json"),
                    "--require-sketch", "nope_sketch"]) == 1


# ---------------------------------------------------------------------------
# trace context: contextvar splice, explicit wins, drop accounting
# ---------------------------------------------------------------------------

def test_event_splices_ambient_context():
    trace = EventTrace()
    ctx = TraceContext.root(replica=0, tp_shard=1)
    with use(ctx):
        rec = trace.event("kernel_dispatch", op="xwT")
    assert rec["trace_id"] == ctx.trace_id
    assert rec["span_id"] == ctx.span_id
    assert rec["replica"] == "0" and rec["tp_shard"] == "1"
    # outside the block: no splice
    assert "trace_id" not in trace.event("kernel_dispatch", op="xwT")


def test_explicit_trace_id_wins_over_ambient():
    trace = EventTrace()
    with use(TraceContext.root()):
        rec = trace.event("spec_commit", trace_id="t-explicit", uid=3)
    assert rec["trace_id"] == "t-explicit"
    assert "span_id" not in rec      # ambient context contributed nothing


def test_context_nesting_and_children():
    outer = TraceContext.root(replica=0)
    with use(outer):
        child = outer.child(chunk=2)
        assert child.trace_id == outer.trace_id
        assert child.parent_id == outer.span_id
        assert dict(child.labels)["chunk"] == "2"
        with use(child):
            from repro.obs.context import current
            assert current() is child
        assert_current_is(outer)
    from repro.obs.context import current
    assert current() is None


def assert_current_is(ctx):
    from repro.obs.context import current
    assert current() is ctx


def test_span_inherits_context():
    trace = EventTrace()
    ctx = TraceContext.root()
    with use(ctx):
        with trace.span("request", uid=1):
            pass
    (rec,) = trace.named("request")
    assert rec["ph"] == "span" and rec["trace_id"] == ctx.trace_id


def test_trace_drop_accounting_and_header(tmp_path):
    reg = MetricsRegistry(trace=EventTrace(max_events=4))
    for i in range(7):
        reg.trace.event("request_step", i=i)
    assert reg.trace.dropped == 3
    (c,) = [e for e in reg.snapshot(meta=False)["counters"]
            if e["name"] == "trace_events_dropped_total"]
    assert c["value"] == 3
    path = tmp_path / "t.jsonl"
    assert reg.trace.write(str(path)) == 4
    header, events = load_events(str(path))
    assert header is not None and header["dropped"] == 3
    assert len(events) == 4
    assert [e["i"] for e in events] == [3, 4, 5, 6]   # oldest-first suffix
    # an un-overflowed trace writes no header line
    reg2 = MetricsRegistry()
    reg2.trace.event("x")
    reg2.trace.write(str(tmp_path / "t2.jsonl"))
    header2, _ = load_events(str(tmp_path / "t2.jsonl"))
    assert header2 is None


# ---------------------------------------------------------------------------
# slo: phase attribution, goodput, deadlines
# ---------------------------------------------------------------------------

def _req(sub=0.0, claim=0.1, first=0.4, done=1.0, prompt=8, out=4,
         wasted=0, rejected=0, overhead=0.0, preempts=0):
    return SimpleNamespace(
        submit_ts=sub, claim_ts=claim, first_token_ts=first,
        complete_ts=done, prompt=list(range(prompt)),
        output=list(range(out)), wasted_prefill_tokens=wasted,
        rejected_draft_tokens=rejected, preempt_overhead_s=overhead,
        preempts=preempts)


def test_request_phases_and_tokens():
    ph = request_phases(_req(overhead=0.2))
    assert ph["queue_wait"] == pytest.approx(0.1)
    assert ph["prefill"] == pytest.approx(0.3)
    assert ph["decode"] == pytest.approx(0.6)
    assert ph["preempt_reprefill"] == pytest.approx(0.2)   # overlay
    assert ph["ttft"] == pytest.approx(0.4)
    assert ph["e2e"] == pytest.approx(1.0)
    # incomplete request: missing boundaries are omitted, not zeroed
    ph = request_phases(_req(first=None, done=None))
    assert set(ph) == {"queue_wait"}
    toks = request_tokens(_req(wasted=5, rejected=3))
    assert toks == {"useful": 12, "wasted_preempt": 5,
                    "wasted_spec_reject": 3}


def test_slo_report_goodput_and_attainment():
    reqs = [
        _req(done=0.5),                               # fast: passes both
        _req(first=0.9, done=2.5, wasted=12, preempts=1, overhead=0.3),
        _req(first=None, done=None),                  # still in flight
        _req(done=1.2, rejected=6),
    ]
    reg = MetricsRegistry()
    rep = slo_report(reqs, SLOConfig(ttft_ms=500.0, e2e_ms=2000.0),
                     metrics=reg)
    assert rep["requests"] == 4 and rep["completed"] == 3
    assert rep["preempted_requests"] == 1
    g = rep["goodput"]
    assert g["useful_tokens"] == 4 * 12
    assert g["wasted_tokens"] == {"preempt": 12, "spec_reject": 6}
    assert g["ratio"] == pytest.approx(48 / 66)
    # req 2 misses both deadlines (ttft 900ms, e2e 2500ms)
    slo = rep["slo"]
    assert slo["pass"] == 2 and slo["fail"] == 1
    assert slo["fail_ttft"] == 1 and slo["fail_e2e"] == 1
    assert slo["attainment"] == pytest.approx(2 / 3)
    assert rep["phases"]["decode"]["count"] == 3
    assert rep["phases"]["preempt_reprefill"]["count"] == 1
    # verdicts published on the registry
    snap = reg.snapshot(meta=False)
    names = {(e["name"], tuple(sorted(e["labels"].items()))): e["value"]
             for e in snap["counters"]}
    assert names[("serve_slo_pass_total", ())] == 2
    assert names[("serve_slo_fail_total", (("slo", "ttft"),))] == 1
    (gr,) = [e for e in snap["gauges"]
             if e["name"] == "serve_goodput_ratio"]
    assert gr["value"] == pytest.approx(48 / 66)


def test_slo_report_without_deadlines_has_no_slo_block():
    rep = slo_report([_req()], SLOConfig())
    assert "slo" not in rep and rep["goodput"]["ratio"] == 1.0


def test_phase_sketches_merge_matches_single():
    """The property serve_bench relies on: per-run phase sketches merged
    across runs equal one sketch over the concatenated requests."""
    runs = [[_req(done=0.5 + 0.1 * i) for i in range(4)],
            [_req(first=0.8, done=3.0 + i) for i in range(3)]]
    merged = phase_sketches(runs[0])
    for phase, sk in phase_sketches(runs[1]).items():
        if phase in merged:
            merged[phase].merge(sk)
        else:
            merged[phase] = sk
    combined = phase_sketches(runs[0] + runs[1])
    for phase in combined:
        assert merged[phase].bins == combined[phase].bins
        assert merged[phase].quantile(0.9) == combined[phase].quantile(0.9)


# ---------------------------------------------------------------------------
# flight recorder + watchdog
# ---------------------------------------------------------------------------

def test_subsystem_routing():
    assert subsystem_of("kernel_dispatch") == "kernels"
    assert subsystem_of("autotune_search") == "tune"
    assert subsystem_of("tune_cache_resolve") == "tune"
    assert subsystem_of("train_step") == "train"
    assert subsystem_of("checkpoint_save") == "train"
    assert subsystem_of("request_submit") == "serve"
    assert subsystem_of("request") == "serve"
    assert subsystem_of("spec_commit") == "serve"
    assert subsystem_of("prefill_chunk") == "serve"
    assert subsystem_of("logger_line") == "misc"


def test_watchdog_arms_only_after_second_beat():
    wd = Watchdog("t", on_stall=lambda w: None, threshold=2.0,
                  min_stall_s=0.5, poll_s=30.0)   # poll far away: we drive
    try:
        now = time.monotonic()
        assert not wd.check(now + 1e9)        # no beats: never a stall
        wd.beat()
        assert not wd.check(time.monotonic() + 1e9)   # one beat: jit grace
        wd.beat()                             # ewma exists -> armed
        assert not wd.check(time.monotonic() + 0.01)
        assert wd.check(time.monotonic() + 10.0)
        assert wd.stalls == 1
        # one dump per episode until the loop beats again
        assert not wd.check(time.monotonic() + 20.0)
        wd.beat()
        assert wd.check(time.monotonic() + 10.0)
        assert wd.stalls == 2
        assert wd.state()["beats"] == 3
    finally:
        wd.stop()


def test_watchdog_threshold_scales_with_ewma():
    wd = Watchdog("t", on_stall=lambda w: None, threshold=4.0,
                  min_stall_s=0.001, poll_s=30.0)
    try:
        wd.beat()
        time.sleep(0.05)
        wd.beat()
        # ewma ~= 0.05 -> stall threshold ~= 0.2, floored well below
        assert 0.1 < wd.stall_after() < 1.0
        assert not wd.check(time.monotonic() + 0.01)
        assert wd.check(time.monotonic() + 5.0)
    finally:
        wd.stop()


def test_recorder_rings_and_dump(tmp_path):
    reg = MetricsRegistry()
    rec = FlightRecorder(str(tmp_path), metrics=reg, ring_size=3)
    rec.attach_trace(reg.trace)
    for i in range(5):
        reg.trace.event("request_step", i=i)
    reg.trace.event("kernel_dispatch", op="xwT")
    out = rec.dump("unit-test")
    assert out in rec.dumps
    rings = json.loads((tmp_path / "flight-0001-unit-test" /
                        "rings.json").read_text())
    # serve ring is bounded: only the 3 most recent request_step events
    assert [e["i"] for e in rings["serve"]] == [2, 3, 4]
    assert rings["kernels"][0]["name"] == "kernel_dispatch"
    meta = json.loads((tmp_path / "flight-0001-unit-test" /
                       "meta.json").read_text())
    assert meta["reason"] == "unit-test"
    assert meta["ring_sizes"] == {"serve": 3, "kernels": 1}
    metrics = json.loads((tmp_path / "flight-0001-unit-test" /
                          "metrics.json").read_text())
    assert "counters" in metrics
    (c,) = [e for e in reg.snapshot(meta=False)["counters"]
            if e["name"] == "flight_dumps_total"]
    assert c["value"] == 1
    rec.close()


def test_recorder_guard_dumps_on_crash(tmp_path):
    rec = FlightRecorder(str(tmp_path), metrics=MetricsRegistry())
    with pytest.raises(RuntimeError):
        with rec.guard():
            raise RuntimeError("boom")
    assert len(rec.dumps) == 1 and "crash-RuntimeError" in rec.dumps[0]
    rec.close()


def test_recorder_watchdog_stall_produces_one_dump(tmp_path):
    reg = MetricsRegistry()
    rec = FlightRecorder(str(tmp_path), metrics=reg)
    rec.attach_trace(reg.trace)
    wd = rec.watchdog("serve_tick", threshold=2.0, min_stall_s=0.05,
                      poll_s=0.01)
    assert wd.threshold == 2.0
    reg.trace.event("request_submit", uid=0)
    wd.beat()
    time.sleep(0.02)
    wd.beat()                      # armed; then silence -> stall
    assert rec.wait_for_dump(timeout=5.0)
    rec.close()
    assert len(rec.dumps) == 1     # one dump per episode, close() raced none
    rings = json.loads((pathlib.Path(rec.dumps[0]) /
                        "rings.json").read_text())
    assert rings["serve"][0]["name"] == "request_submit"
    (c,) = [e for e in reg.snapshot(meta=False)["counters"]
            if e["name"] == "obs_watchdog_stalls_total"]
    assert c["value"] == 1 and c["labels"] == {"watch": "serve_tick"}


def test_recorder_default_threshold_and_tap_chaining(tmp_path):
    rec = FlightRecorder(str(tmp_path), metrics=MetricsRegistry(),
                         watchdog_threshold=3.5)
    wd = rec.watchdog("w", poll_s=30.0)
    assert wd.threshold == 3.5
    wd.stop()
    # attach_trace chains an existing tap instead of clobbering it
    seen = []
    trace = EventTrace()
    trace.tap = seen.append
    rec.attach_trace(trace)
    trace.event("request_x")
    assert len(seen) == 1
    assert [e["name"] for e in rec.rings["serve"]] == ["request_x"]
    rec.close()
    rec.close()                    # idempotent


# ---------------------------------------------------------------------------
# end-to-end: paged run -> trace propagation, export, waste accounting
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paged_run(spec_setup):
    """One paged serve run with an undersized arena (forces preemption)
    and speculative decoding, against a fresh default registry so engine,
    kernel-dispatch, and tune-cache events share one trace."""
    from repro.paged import PagedServeConfig
    cfg, model, packed = spec_setup
    prev = obs.default_registry()
    reg = MetricsRegistry()
    obs.set_default_registry(reg)
    try:
        engine = make_engine(
            model, packed,
            PagedServeConfig(num_slots=4, max_len=96, page_size=8,
                             num_pages=13, prefill_chunk=16),
            policy=POLICY, spec=SpecConfig(draft=DRAFT, gamma=3))
        rng = np.random.default_rng(0)
        for uid, plen in enumerate((5, 23, 11, 37)):
            engine.submit(Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
                max_new_tokens=8))
        engine.run_until_drained()
        yield engine, reg
    finally:
        obs.set_default_registry(prev)


def test_paged_trace_propagation(paged_run):
    engine, reg = paged_run
    events = reg.trace.events
    assert check_propagation(events) == []
    # every completed request has a span tree from submit to complete
    trees = span_trees(events)
    for req in engine.completed:
        assert req.trace_id in trees
        names = [e["name"] for e in trees[req.trace_id]]
        # the request span carries ts at its *start*, so it ties with the
        # submit point event — assert lifecycle membership, not order
        assert "request_submit" in names[:2]
        assert "request_complete" in names
    # chunked prefill and spec verify both attributed to their requests
    assert any(e["name"] == "prefill_chunk" and "trace_id" in e
               for e in events)
    assert any(e["name"].startswith("spec_") and "trace_id" in e
               for e in events)


def test_paged_export_chrome_trace(paged_run):
    engine, reg = paged_run
    chrome = to_chrome_trace(reg.trace.events)
    blob = json.dumps(chrome)            # must be valid JSON end-to-end
    assert json.loads(blob)["displayTimeUnit"] == "ms"
    evs = chrome["traceEvents"]
    assert {e["ph"] for e in evs} >= {"X", "i", "M"}
    # one named virtual thread per request trace
    threads = [e for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"]
    assert len(threads) >= len(engine.completed)
    assert all(e["ts"] >= 0.0 for e in evs if "ts" in e)


def test_paged_preemption_waste_and_slo(paged_run):
    engine, reg = paged_run
    assert any(r.preempts > 0 for r in engine.completed)
    preempted = [r for r in engine.completed if r.preempts]
    assert all(r.wasted_prefill_tokens > 0 for r in preempted)
    assert all(r.preempt_overhead_s > 0.0 for r in preempted)
    wasted = {e["labels"].get("cause"): e["value"]
              for e in reg.snapshot(meta=False)["counters"]
              if e["name"] == "serve_wasted_tokens_total"}
    assert wasted.get("preempt", 0) == sum(
        r.wasted_prefill_tokens for r in engine.completed)
    assert wasted.get("spec_reject", 0) == sum(
        r.rejected_draft_tokens for r in engine.completed)
    rep = slo_report(engine.completed, SLOConfig(e2e_ms=1e7))
    assert rep["preempted_requests"] == len(preempted)
    assert rep["goodput"]["ratio"] < 1.0
    assert rep["slo"]["pass"] == len(engine.completed)
    # engine sketches observed every request
    sketches = {e["name"]: e
                for e in reg.snapshot(meta=False)["sketches"]}
    assert sketches["serve_ttft_seconds_sketch"]["count"] == len(
        engine.completed)
    assert sketches["serve_e2e_seconds_sketch"]["count"] == len(
        engine.completed)


def test_paged_trace_jsonl_round_trips_export(paged_run, tmp_path):
    _, reg = paged_run
    path = tmp_path / "serve_trace.jsonl"
    reg.trace.write(str(path))
    header, events = load_events(str(path))
    assert header is None                      # no overflow in this run
    assert check_propagation(events) == []


# ---------------------------------------------------------------------------
# DP router: merged sketches under speculative decoding
# ---------------------------------------------------------------------------

def test_router_merged_sketches_under_spec(spec_setup):
    cfg, model, packed = spec_setup
    router = make_engine(model, packed, ServeConfig(num_slots=2, max_len=64),
                         policy=POLICY, spec=SpecConfig(draft=DRAFT, gamma=3),
                         replicas=2)
    rng = np.random.default_rng(0)
    for uid in range(4):
        router.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, 5 + uid % 3,
                                dtype=np.int32),
            max_new_tokens=6))
    router.run_until_drained()
    assert sorted(r.uid for r in router.completed) == [0, 1, 2, 3]
    snap = router.metrics.snapshot(meta=False)

    # spec counters survive the merge with replica attribution
    drafts = {e["labels"].get("replica"): e["value"]
              for e in snap["counters"]
              if e["name"] == "spec_draft_tokens_total"}
    assert set(drafts) == {"0", "1"} and all(v > 0 for v in drafts.values())

    for name in ("serve_ttft_seconds_sketch", "serve_e2e_seconds_sketch"):
        entries = [e for e in snap["sketches"] if e["name"] == name]
        per_replica = {e["labels"]["replica"]: e for e in entries
                       if "replica" in e["labels"]}
        (combined,) = [e for e in entries if "replica" not in e["labels"]]
        assert set(per_replica) == {"0", "1"}
        # round-robin: two requests per replica, four combined
        assert all(e["count"] == 2 for e in per_replica.values())
        assert combined["count"] == 4
        # the exact-merge property: the combined instrument's bucket state
        # equals the bucket-wise sum of the replica sketches, so its
        # percentiles are those of one sketch that saw every observation
        manual = QuantileSketch.from_entry(per_replica["0"])
        manual.merge(QuantileSketch.from_entry(per_replica["1"]))
        got = QuantileSketch.from_entry(combined)
        assert got.bins == manual.bins
        assert got.zero_count == manual.zero_count
        for q in (0.5, 0.9, 0.99):
            assert got.quantile(q) == manual.quantile(q)
