"""Test helpers: run a python snippet in a subprocess with N host devices.

Smoke tests must see 1 device (the brief), so multi-device tests spawn a
fresh interpreter with XLA_FLAGS set before jax import.
"""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(snippet: str, n_devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", snippet], env=env, capture_output=True,
        text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout
