"""Tests for ``repro.quant``: int8 quantized packed execution across
pack → kernels → tune → sharding → checkpoint → serve.

Covers the ISSUE-4 acceptance set: float↔int8 parity within the symmetric
quantization error bound for both layouts on ragged and stacked-scan
shapes, the elementwise quantization-error bound, and a
pack→quantize→checkpoint→restore→serve round-trip that preserves the
``qdtype`` tag and the scales child.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import sparse_linear as sl
from repro.core.sparse_linear import ExecPolicy
from repro.core.sparsity import (PackedWeight, SparsityConfig, pack_block,
                                 pack_block_stacked, random_sparse_dense)
from repro.quant import (activation_calibration, amax_scales,
                         dequantize_packed, quantize_packed, quantize_tree)

CFG = SparsityConfig(2, 16)


def _pw(key=0, o=16, k=64, cfg=CFG):
    params = sl.init_sparse(jax.random.PRNGKey(key), k, o, cfg)
    return params, sl.pack_params(params, cfg)


def _block_pw(key=0, o=32, k=64, cfg=CFG, block_r=8):
    w = jnp.asarray(random_sparse_dense(np.random.default_rng(key), o, k,
                                        cfg))
    return w, pack_block(w, cfg, block_r=block_r)


def _parity_tol(q, x):
    """Guaranteed output bound for symmetric round-to-nearest: every weight
    errs by <= scale/2, so |Δy| <= 0.5 * max_scale * max_row ‖x‖₁."""
    return (0.5 * float(jnp.max(q.scales))
            * float(jnp.max(jnp.sum(jnp.abs(x), axis=-1))))


# ---------------------------------------------------------------------------
# Pytree contract
# ---------------------------------------------------------------------------

def test_quantized_pytree_children_and_aux():
    _, pw = _pw()
    q = quantize_packed(pw)
    assert q.qdtype == "int8" and q.values.dtype == jnp.int8
    assert q.scales.shape == (16,) and q.scales.dtype == jnp.float32
    leaves, treedef = jax.tree_util.tree_flatten(q)
    assert len(leaves) == 3      # values, indices, scales
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.qdtype == "int8" and rebuilt.cfg == CFG
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(q)[0]]
    assert paths == [".values", ".indices", ".scales"]
    # block layout: 4 children, scales per (row-block, group, row)
    _, bpw = _block_pw()
    bq = quantize_packed(bpw)
    assert bq.scales.shape == bq.values.shape[:-1]
    leaves_b, treedef_b = jax.tree_util.tree_flatten(bq)
    assert len(leaves_b) == 4    # + active_groups
    assert jax.tree_util.tree_unflatten(treedef_b, leaves_b).qdtype == "int8"


def test_quantized_constructor_validation():
    _, pw = _pw()
    with pytest.raises(ValueError, match="scales"):
        PackedWeight(pw.values, pw.indices, cfg=CFG, dense_shape=(16, 64),
                     qdtype="int8")                      # missing scales
    with pytest.raises(ValueError, match="qdtype"):
        PackedWeight(pw.values, pw.indices, cfg=CFG, dense_shape=(16, 64),
                     scales=jnp.ones((16,)))             # scales w/o qdtype
    with pytest.raises(ValueError, match="unknown qdtype"):
        quantize_packed(pw, "int4")
    with pytest.raises(ValueError, match="scales shape"):
        PackedWeight(jnp.zeros((16, 4, 2), jnp.int8), pw.indices, cfg=CFG,
                     dense_shape=(16, 64), scales=jnp.ones((4,)),
                     qdtype="int8")
    q = quantize_packed(pw)
    with pytest.raises(ValueError, match="already quantized"):
        quantize_packed(q)


def test_quantization_error_bound_and_dequantize():
    """Round-to-nearest symmetric: |w - deq(q(w))| <= scale/2 per row, and
    dequantize_packed returns a float node with no scales child."""
    _, pw = _pw(o=32, k=128)
    q = quantize_packed(pw)
    err = jnp.abs(q.dequantized_values() - pw.values)
    bound = 0.5 * q.scales[:, None, None] * (1 + 1e-6)
    assert bool(jnp.all(err <= bound))
    d = dequantize_packed(q)
    assert d.qdtype is None and d.scales is None
    np.testing.assert_array_equal(np.asarray(d.indices), np.asarray(q.indices))
    # amax calibration really uses the per-row max
    np.testing.assert_allclose(
        np.asarray(amax_scales(pw)),
        np.asarray(jnp.max(jnp.abs(pw.values), axis=(1, 2)) / 127.0),
        rtol=1e-6)


# ---------------------------------------------------------------------------
# Kernel parity (both layouts, ragged + stacked shapes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [5, 8])   # ragged and tile-aligned
def test_xwT_q8_parity_all_backends(batch):
    params, pw = _pw()
    q = quantize_packed(pw)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, 64))
    y_f = np.asarray(sl.apply(pw, x, ExecPolicy(mode="packed")))
    tol = _parity_tol(q, x)
    ys = {}
    for backend in ("reference", "pallas_interpret", "auto"):
        y = np.asarray(sl.apply(
            q, x, ExecPolicy(mode="packed", backend=backend)))
        assert np.max(np.abs(y - y_f)) <= tol, backend
        ys[backend] = y
    # the backends agree with each other to fp precision (same dequant math)
    np.testing.assert_allclose(ys["reference"], ys["pallas_interpret"],
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("batch", [5, 8])
def test_block_q8_parity_all_backends(batch):
    w, bpw = _block_pw()
    q = quantize_packed(bpw)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, 64))
    y_f = np.asarray(sl.apply(bpw, x, ExecPolicy(mode="packed")))
    tol = _parity_tol(q, x)
    ys = {}
    for backend in ("reference", "block_spmm", "auto"):
        y = np.asarray(sl.apply(
            q, x, ExecPolicy(mode="packed", backend=backend)))
        assert np.max(np.abs(y - y_f)) <= tol, backend
        ys[backend] = y
    np.testing.assert_allclose(ys["reference"], ys["block_spmm"],
                               rtol=1e-4, atol=1e-5)


def test_stacked_scan_slicing_quantized():
    """quantize_tree on scan-stacked weights: tree-map layer slicing (what
    lax.scan does) slices the scales child too, for both layouts."""
    from repro.launch.pack_tree import pack_tree
    from repro.core.sparsity import Static

    w = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 32))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32))
    pol = ExecPolicy(mode="packed")
    for layout in ("xwT", "block"):
        tree = pack_tree({"layers": {"w": w, "sparsity": Static(CFG)}},
                         layout=layout, quantize="int8")
        pw = tree["layers"]
        assert pw.qdtype == "int8" and pw.stack_dims == (3,)
        assert pw.scales.shape[0] == 3
        sliced = jax.tree.map(lambda a: a[1], pw)
        # per-slice quantization of the per-slice packing gives the same node
        if layout == "block":
            br, a_max = pw.block_geom
            per = quantize_packed(pack_block(w[1], CFG, block_r=br,
                                             a_max=a_max))
        else:
            per = quantize_packed(sl.pack_params({"w": w[1]}, CFG))
        np.testing.assert_allclose(np.asarray(sl.apply(sliced, x, pol)),
                                   np.asarray(sl.apply(per, x, pol)),
                                   rtol=1e-5, atol=1e-5)


def test_activation_calibration_not_worse_on_calibration_batch():
    """The activation observer minimizes the weighted proxy over a clip grid
    that includes amax (ratio 1.0), so its true output error on the
    calibration batch should not be dramatically worse — and the scales stay
    within the searched grid of the amax baseline."""
    _, pw = _pw(o=32, k=128)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 128))
    q_amax = quantize_packed(pw)
    q_act = quantize_packed(pw, observer=activation_calibration(x))
    ratio = np.asarray(q_act.scales / q_amax.scales)
    assert np.all(ratio <= 1.0 + 1e-6) and np.all(ratio >= 0.8 - 1e-6)
    y = np.asarray(sl.apply(pw, x, ExecPolicy(mode="packed")))
    err_amax = np.abs(np.asarray(
        sl.apply(q_amax, x, ExecPolicy(mode="packed"))) - y).mean()
    err_act = np.abs(np.asarray(
        sl.apply(q_act, x, ExecPolicy(mode="packed"))) - y).mean()
    assert err_act <= err_amax * 1.5


# ---------------------------------------------------------------------------
# tune / dispatch
# ---------------------------------------------------------------------------

def test_quant_tune_cache_keys_distinct_from_float(tmp_path):
    from repro import tune

    _, pw = _pw()
    q = quantize_packed(pw)
    pf = tune.Problem.for_xwT((4, 64), (16, 64), CFG, jnp.float32)
    pq = tune.Problem.for_xwT((4, 64), (16, 64), CFG, jnp.float32,
                              quantized=True)
    assert pq.op == "xwT_q8"
    assert tune.problem_key(pf) != tune.problem_key(pq)
    _, bpw = _block_pw()
    bq = quantize_packed(bpw)
    pb = tune.Problem.for_xwT_block((4, 64), bpw, jnp.float32)
    pbq = tune.Problem.for_xwT_block((4, 64), bq, jnp.float32)
    assert pb.op == "xwT_block" and pbq.op == "xwT_block_q8"
    assert tune.problem_key(pb) != tune.problem_key(pbq)


def test_autotune_packed_tree_quant_nodes(tmp_path):
    """autotune_packed_tree recognizes quantized nodes (xwT and stacked
    block) and tunes them under their own op keys."""
    from repro import tune

    _, pw = _pw()
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 32))
    bq = quantize_packed(pack_block_stacked(w, CFG))
    tree = {"mlp": {"gate": quantize_packed(pw)}, "layers": bq}
    cache = tune.TuneCache(path=str(tmp_path / "cache.json"))
    results = tune.autotune_packed_tree(tree, 4, persist=False, cache=cache,
                                        max_measure=1, warmup=1, iters=1)
    ops = sorted(r.problem.op for r in results.values())
    assert ops == ["xwT_block_q8", "xwT_q8"]
    for r in results.values():
        assert any(c.status == "measured" for c in r.candidates)


# ---------------------------------------------------------------------------
# Sharding
# ---------------------------------------------------------------------------

def test_param_specs_shard_scales_alongside_values():
    from repro.launch.pack_tree import pack_tree
    from repro.models.layers import init_linear
    from repro.sharding.plan import ShardingPlan

    def lin(key):
        return init_linear(jax.random.PRNGKey(key), 64, 32, sparse=CFG)
    tree = pack_tree({"mlp": {"gate": lin(0), "down": lin(1)}},
                     quantize="int8")
    specs = ShardingPlan().param_specs(tree)
    assert specs["mlp"]["gate"].values == P("model", None, None)   # col
    assert specs["mlp"]["gate"].scales == P("model")
    assert specs["mlp"]["down"].values == P(None, "model", None)   # row
    assert specs["mlp"]["down"].scales == P(None)                  # no G axis
    btree = pack_tree({"mlp": {"gate": lin(0), "down": lin(1)}},
                      layout="block", quantize="int8")
    bspecs = ShardingPlan().param_specs(btree)
    assert bspecs["mlp"]["gate"].values == P("model", None, None, None)
    assert bspecs["mlp"]["gate"].scales == P("model", None, None)
    assert bspecs["mlp"]["down"].scales == P(None, None, None)
    # per-group xwT scales (O, G) shard the group axis under row-parallel —
    # it tiles the contraction dim exactly like the values' group axis
    gtree = pack_tree({"mlp": {"gate": lin(0), "down": lin(1)}},
                      quantize="int8", granularity="per_group")
    gspecs = ShardingPlan().param_specs(gtree)
    assert gspecs["mlp"]["gate"].scales == P("model", None)
    assert gspecs["mlp"]["down"].scales == P(None, "model")


@pytest.mark.parametrize("batch", [5, 8])
def test_xwT_q8_per_group_scales(batch):
    """Per-group xwT granularity: scales (O, G), tighter error than
    per-row, full backend parity (reference / Pallas / auto)."""
    params, pw = _pw(o=16, k=64)
    q = quantize_packed(pw, granularity="per_group")
    assert q.scales.shape == (16, 4)
    # per-group error bound: every value errs <= its group scale / 2
    err = jnp.abs(q.dequantized_values() - pw.values)
    assert bool(jnp.all(err <= 0.5 * q.scales[..., None] * (1 + 1e-6)))
    # per-group grids are never coarser than the row grid
    qr = quantize_packed(pw)
    assert bool(jnp.all(q.scales <= qr.scales[:, None] * (1 + 1e-6)))
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, 64))
    ys = {}
    for backend in ("reference", "pallas_interpret", "auto"):
        ys[backend] = np.asarray(sl.apply(
            q, x, ExecPolicy(mode="packed", backend=backend)))
    np.testing.assert_allclose(ys["reference"], ys["pallas_interpret"],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ys["reference"], ys["auto"],
                               rtol=1e-4, atol=1e-5)
    # matches the dequantized dense weight exactly (the oracle)
    np.testing.assert_allclose(
        ys["reference"], np.asarray(jnp.dot(x, q.to_dense().T)),
        rtol=1e-4, atol=1e-4)


def test_per_group_granularity_validation():
    _, bpw = _block_pw()
    with pytest.raises(ValueError, match="granularity"):
        quantize_packed(bpw, granularity="per_group")   # block: already
    params, pw = _pw()
    with pytest.raises(ValueError, match="granularity"):
        quantize_packed(pw, granularity="per_tensor")


# ---------------------------------------------------------------------------
# Checkpoint round-trip + serve (the acceptance regression)
# ---------------------------------------------------------------------------

def test_quant_checkpoint_restore_serve_roundtrip():
    """pack → quantize → save → elastic restore from a shape-only template →
    serve: qdtype and scales survive and outputs are bit-identical."""
    from repro.train import checkpoint as ckpt

    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    pol = ExecPolicy(mode="packed", backend="auto")
    for make in (lambda: _pw()[1], lambda: _block_pw()[1]):
        q = quantize_packed(make())
        y = np.asarray(sl.apply(q, x, pol))
        with tempfile.TemporaryDirectory() as d:
            ckpt.save({"lin": q}, d, 1)
            template = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                {"lin": q})
            restored = ckpt.restore(template, d, 1)["lin"]
        assert restored.qdtype == "int8"
        assert restored.cfg == CFG
        assert restored.values.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(restored.scales),
                                      np.asarray(q.scales))
        np.testing.assert_array_equal(np.asarray(sl.apply(restored, x, pol)),
                                      y)


def test_quantized_decode_step_matches_float_closely():
    """A whole reduced model decodes with quantized packed weights; logits
    stay close to the float packed path (end-to-end w8a16 sanity)."""
    from repro.configs.base import get_arch
    from repro.launch.pack_tree import pack_tree
    from repro.models.families import build_model

    arch = get_arch("gemma3_1b").reduced()
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    packed = pack_tree(params)
    quant = pack_tree(params, quantize="int8")
    state = model.init_decode_state(2, 16, dtype=jnp.float32)
    toks = jnp.zeros((2, 1), jnp.int32)
    pol = ExecPolicy(mode="packed")
    l_f, _ = model.decode_step(packed, state, toks, policy=pol)
    l_q, _ = model.decode_step(quant, state, toks, policy=pol)
    # int8 per-row quantization perturbs logits only slightly
    assert float(jnp.max(jnp.abs(l_q - l_f))) < 0.15 * (
        1 + float(jnp.max(jnp.abs(l_f))))
