"""Config system: all 10 archs load with exact assigned hyper-parameters,
shape registry, skip rules, group alignment, window schedules."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    applicable_shapes,
    choose_group,
    get_arch,
)
from repro.models.transformer import FULL_WINDOW, layer_windows

# the assignment table (arch -> L, d_model, H, kv, d_ff, vocab)
ASSIGNED = {
    "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
    "gemma3_1b": (26, 1152, 4, 1, 6912, 262144),
    "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
    "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
    "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000),
    "olmoe_1b_7b": (16, 2048, 16, 16, 0, 50304),
    "llama4_scout_17b_a16e": (48, 5120, 40, 8, 0, 202048),
    "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
    "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
    "xlstm_125m": (12, 768, 4, 4, 0, 50304),
}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_assigned_hyperparameters(arch_id):
    cfg = get_arch(arch_id)
    l, d, h, kv, ff, v = ASSIGNED[arch_id]
    assert cfg.num_layers == l
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_moe_configs():
    o = get_arch("olmoe_1b_7b")
    assert (o.moe.num_experts, o.moe.experts_per_token,
            o.moe.d_ff_expert) == (64, 8, 1024)
    l4 = get_arch("llama4_scout_17b_a16e")
    assert (l4.moe.num_experts, l4.moe.experts_per_token,
            l4.moe.d_ff_expert) == (16, 1, 8192)


def test_ssm_configs():
    z = get_arch("zamba2_7b")
    assert z.ssm.kind == "mamba2" and z.ssm.state_dim == 64
    assert z.shared_attn_every == 6
    x = get_arch("xlstm_125m")
    assert x.ssm.kind == "xlstm"


def test_shape_registry():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_500k_skip_rules():
    """Brief: long_500k only for sub-quadratic archs."""
    runs = {a for a in ARCH_IDS
            if "long_500k" in applicable_shapes(get_arch(a))}
    assert runs == {"gemma3_1b", "h2o_danube_1_8b", "zamba2_7b",
                    "xlstm_125m"}
    # 34 applicable pairs total -> 68 dry-run cells over two meshes
    total = sum(len(applicable_shapes(get_arch(a))) for a in ARCH_IDS)
    assert total == 34


def test_padded_vocab_tp_divisible():
    for a in ARCH_IDS:
        cfg = get_arch(a)
        assert cfg.padded_vocab % 16 == 0
        assert 0 <= cfg.padded_vocab - cfg.vocab_size < 256


@pytest.mark.parametrize("k,expect_m", [(432, 48), (2048, 128), (160, 80),
                                        (320, 80)])
def test_choose_group_alignment(k, expect_m):
    cfg = choose_group(k, 1.0 / 16.0, 128)
    assert cfg.m == expect_m
    assert k % cfg.m == 0
    assert cfg.n / cfg.m == pytest.approx(1.0 / 16.0)


def test_layer_windows_gemma_pattern():
    cfg = get_arch("gemma3_1b")
    w = np.asarray(layer_windows(cfg))
    # 5 local : 1 global
    for i, wi in enumerate(w):
        if (i % 6) == 5:
            assert wi == int(FULL_WINDOW)
        else:
            assert wi == cfg.local_window
    assert (w == int(FULL_WINDOW)).sum() == 4


def test_layer_windows_swa_and_full():
    h2o = get_arch("h2o_danube_1_8b")
    assert np.all(np.asarray(layer_windows(h2o)) == 4096)
    st = get_arch("stablelm_3b")
    assert np.all(np.asarray(layer_windows(st)) == int(FULL_WINDOW))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_counts_sane(arch_id):
    """Order-of-magnitude sanity for MODEL_FLOPS accounting."""
    cfg = get_arch(arch_id)
    n = cfg.param_count()
    expected = {
        "seamless_m4t_medium": (0.3e9, 2e9),
        "gemma3_1b": (0.7e9, 3e9),
        "internlm2_20b": (15e9, 30e9),
        "stablelm_3b": (2e9, 5e9),
        "h2o_danube_1_8b": (1.2e9, 3e9),
        "olmoe_1b_7b": (4e9, 10e9),
        "llama4_scout_17b_a16e": (60e9, 140e9),
        "internvl2_1b": (0.3e9, 1.5e9),
        "zamba2_7b": (4e9, 12e9),
        "xlstm_125m": (0.08e9, 0.4e9),
    }[arch_id]
    assert expected[0] < n < expected[1], n
    assert cfg.active_param_count() <= n
