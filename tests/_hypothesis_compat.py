"""Minimal deterministic fallback for ``hypothesis`` when it isn't installed.

The tier-1 suite must collect and run in bare environments (the container
ships only jax + pytest).  When the real ``hypothesis`` is available the test
modules import it directly; otherwise they fall back to this shim, which
replays each ``@given`` test over a small deterministic sample of the
declared strategies — property tests degrade to seeded example tests instead
of breaking collection.

Only the strategy combinators the suite actually uses are implemented
(``sampled_from``, ``integers``, ``booleans``).  Install the real package
(``pip install -r requirements-dev.txt``) for true property-based runs.
"""

from __future__ import annotations

import random

_FALLBACK_EXAMPLES = 5   # examples per test when replaying without hypothesis


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # noqa: N801 — mirrors the `hypothesis.strategies` module
    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def settings(max_examples: int = _FALLBACK_EXAMPLES, **_ignored):
    """Records max_examples on the wrapped test; other knobs are no-ops."""

    def deco(fn):
        fn._max_examples = min(max_examples, _FALLBACK_EXAMPLES)
        return fn

    return deco


def given(**strategy_kwargs):
    """Replay the test over a deterministic sample of the strategies."""

    def deco(fn):
        # Deliberately NOT functools.wraps: pytest would follow __wrapped__
        # to the original signature and treat strategy params as fixtures.
        def wrapper():
            rng = random.Random(0xDE77)
            n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                fn(**drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
