"""Observability tests (``repro.obs``, DESIGN.md §12): histogram math,
Prometheus exposition, event-trace ordering, dispatch/tune-cache counters,
the structured logger, supervisor metrics, and the snapshot schema
validator."""

import importlib.util
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, tune
from repro.configs.base import get_arch
from repro.core.sparsity import (PackedWeight, SparsityConfig, pack,
                                 pack_block, prune, random_sparse_dense)
from repro.kernels.ops import demm_matmul_packed
from repro.models.families import build_model
from repro.obs import MetricsRegistry, StructuredLogger
from repro.quant import quantize_packed
from repro.serve.serve_loop import Request, ServeConfig, ServeEngine


@pytest.fixture
def fresh_default_registry():
    """Isolate the process-wide registry (kernel dispatch / tune counters
    land there) and restore the previous one afterwards."""
    prev = obs.default_registry()
    reg = MetricsRegistry()
    obs.set_default_registry(reg)
    yield reg
    obs.set_default_registry(prev)


@pytest.fixture
def fresh_tune_cache(tmp_path):
    prev = tune.default_cache()
    cache = tune.TuneCache(path=str(tmp_path / "tune_cache.json"))
    tune.set_default_cache(cache)
    yield cache
    tune.set_default_cache(prev)


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

def test_histogram_bucket_math():
    reg = MetricsRegistry()
    h = reg.histogram("h", buckets=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.005, 0.05, 5.0):
        h.observe(v)
    assert h.counts == [1, 2, 1, 1]           # last = +Inf overflow
    assert h.cumulative() == [1, 3, 4, 5]
    assert h.count == 5
    assert h.sum == pytest.approx(0.0005 + 0.005 + 0.005 + 0.05 + 5.0)
    # boundary lands in the bucket it equals (le semantics)
    h.observe(0.01)
    assert h.counts == [1, 3, 1, 1]


def test_histogram_rejects_unsorted_buckets():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(0.1, 0.01))


def test_counter_monotonic_and_kind_conflict():
    reg = MetricsRegistry()
    c = reg.counter("c", help="x")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    # same (name, labels) => same instrument; different kind => error
    assert reg.counter("c") is c
    with pytest.raises(ValueError):
        reg.gauge("c")


def test_snapshot_and_prometheus_format():
    reg = MetricsRegistry()
    reg.counter("req_total", help="requests", op="xwT").inc(2)
    reg.gauge("slots").set(3)
    h = reg.histogram("lat_seconds", buckets=(0.01, 0.1))
    h.observe(0.005)
    h.observe(0.05)

    snap = reg.snapshot()
    assert {"meta", "counters", "gauges", "histograms"} <= set(snap)
    (c,) = snap["counters"]
    assert c == {"name": "req_total", "labels": {"op": "xwT"}, "value": 2}
    (hh,) = snap["histograms"]
    assert hh["counts"] == [1, 1, 0] and hh["count"] == 2

    text = reg.to_prometheus()
    assert "# TYPE req_total counter" in text
    assert 'req_total{op="xwT"} 2' in text
    assert "slots 3" in text
    # cumulative le buckets ending in +Inf, plus _sum/_count series
    assert 'lat_seconds_bucket{le="0.01"} 1' in text
    assert 'lat_seconds_bucket{le="0.1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert "lat_seconds_count 2" in text
    assert text.endswith("\n")


def test_registry_write_selects_format(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc()
    p_json = tmp_path / "m.json"
    p_prom = tmp_path / "m.prom"
    reg.write(str(p_json))
    reg.write(str(p_prom))
    assert json.loads(p_json.read_text())["counters"][0]["value"] == 1
    assert "# TYPE c counter" in p_prom.read_text()


# ---------------------------------------------------------------------------
# event trace
# ---------------------------------------------------------------------------

def test_trace_span_and_event_ordering(tmp_path):
    reg = MetricsRegistry()
    tr = reg.trace
    with tr.span("outer", uid=1) as sp:
        tr.event("inner", step=0)
        sp.event("tagged")
    names = [e["name"] for e in tr.events]
    assert names == ["inner", "tagged", "outer"]
    tagged = tr.events[1]
    assert tagged["span"] == "outer" and tagged["uid"] == 1
    span_ev = tr.events[-1]
    assert span_ev["ph"] == "span" and span_ev["dur"] >= 0
    # span ts is the *start* time: before both intra-span point events
    assert span_ev["ts"] <= tr.events[0]["ts"] <= tr.events[1]["ts"]
    # JSONL round-trip
    out = tmp_path / "t.jsonl"
    tr.write(str(out))
    lines = [json.loads(l) for l in out.read_text().splitlines()]
    assert [e["name"] for e in lines] == names


# ---------------------------------------------------------------------------
# serve-engine instrumentation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("stablelm_3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_serve_engine_request_lifecycle_metrics(small_model):
    cfg, model, params = small_model
    reg = MetricsRegistry()
    eng = ServeEngine(model, params, ServeConfig(num_slots=2, max_len=48),
                      metrics=reg)
    rng = np.random.default_rng(0)
    n_req, n_new = 3, 4
    for i in range(n_req):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 5,
                                               dtype=np.int32),
                           max_new_tokens=n_new))
    eng.run_until_drained()

    # counters agree with the engine's own completion list
    assert reg.counter("serve_requests_submitted_total").value == n_req
    assert (reg.counter("serve_requests_completed_total").value
            == len(eng.completed) == n_req)
    assert reg.counter("serve_tokens_total").value == n_req * n_new
    # every generated token was observed in the latency histogram
    assert reg.histogram("serve_decode_token_seconds").count == n_req * n_new
    assert reg.histogram("serve_queue_wait_seconds").count == n_req
    assert reg.histogram("serve_time_to_first_token_seconds").count == n_req
    assert reg.gauge("serve_slots_active").value == 0     # drained
    assert reg.gauge("serve_tokens_per_second").value > 0

    # per-request timestamp ordering: submit <= claim <= first <= complete
    for r in eng.completed:
        assert (r.submit_ts <= r.claim_ts <= r.first_token_ts
                <= r.complete_ts)

    # trace ordering per uid: submit -> claim -> first_token -> complete,
    # closed by one "request" span carrying the token count
    order = {"request_submit": 0, "request_claim": 1,
             "request_first_token": 2, "request_complete": 3}
    by_uid = {}
    spans = {}
    for e in reg.trace.events:
        if e["name"] in order:
            by_uid.setdefault(e["uid"], []).append(e)
        elif e["name"] == "request" and e.get("ph") == "span":
            spans[e["uid"]] = e
    assert set(by_uid) == set(spans) == set(range(n_req))
    for uid, evs in by_uid.items():
        assert [order[e["name"]] for e in evs] == [0, 1, 2, 3]
        ts = [e["ts"] for e in evs]
        assert ts == sorted(ts)
        assert spans[uid]["tokens"] == n_new


# ---------------------------------------------------------------------------
# kernel-dispatch counters (all four packed layouts)
# ---------------------------------------------------------------------------

def _dispatch_counts(reg):
    return {(c["labels"]["op"], c["labels"]["backend"]): c["value"]
            for c in reg.snapshot(meta=False)["counters"]
            if c["name"] == "kernel_dispatch_total"}


def test_dispatch_counters_cover_all_packed_ops(fresh_default_registry):
    reg = fresh_default_registry
    rng = np.random.default_rng(0)
    sp = SparsityConfig(8, 128)
    o, k, b = 128, 256, 4
    w = jnp.asarray(random_sparse_dense(rng, o, k, sp))
    x = jnp.asarray(rng.standard_normal((b, k)), jnp.float32)

    p = pack(w, sp)
    pw = PackedWeight(p.values, p.indices, cfg=sp, dense_shape=(o, k))
    demm_matmul_packed(x, pw, backend="reference")
    demm_matmul_packed(x, quantize_packed(pw), backend="reference")
    blk = pack_block(w, sp)
    demm_matmul_packed(x, blk, backend="reference")
    demm_matmul_packed(x, quantize_packed(blk), backend="reference")

    counts = _dispatch_counts(reg)
    assert counts == {("xwT", "reference"): 1,
                      ("xwT_q8", "reference"): 1,
                      ("xwT_block", "reference"): 1,
                      ("xwT_block_q8", "reference"): 1}

    # dispatch is trace-time: re-running the same jitted computation must
    # not inflate the audit counters (the <=2% overhead guarantee)
    f = jax.jit(lambda xx: demm_matmul_packed(xx, pw, backend="reference"))
    f(x).block_until_ready()
    before = _dispatch_counts(reg)[("xwT", "reference")]
    f(x + 1).block_until_ready()
    assert _dispatch_counts(reg)[("xwT", "reference")] == before


# ---------------------------------------------------------------------------
# tune-cache accounting + atomic save
# ---------------------------------------------------------------------------

def test_tune_cache_hit_miss_accounting(fresh_default_registry,
                                        fresh_tune_cache):
    reg, cache = fresh_default_registry, fresh_tune_cache
    sp = SparsityConfig(8, 128)
    p = tune.Problem.for_xwT((4, 256), (128, 256), sp, jnp.float32)

    cache.resolve(p)   # empty cache -> heuristic fallback
    cache.resolve(p)   # memoized heuristic -> hit
    cache.resolve(p)
    hits = {c["labels"]["op"]: c["value"]
            for c in reg.snapshot(meta=False)["counters"]
            if c["name"] == "tune_cache_hits_total"}
    misses = {c["labels"]["op"]: c["value"]
              for c in reg.snapshot(meta=False)["counters"]
              if c["name"] == "tune_cache_misses_total"}
    assert misses == {"xwT": 1}
    assert hits == {"xwT": 2}


def test_tune_cache_save_is_atomic(tmp_path):
    cache = tune.TuneCache(path=str(tmp_path / "d" / "cache.json"))
    sp = SparsityConfig(8, 128)
    p = tune.Problem.for_xwT((4, 256), (128, 256), sp, jnp.float32)
    cache.put(p, cache.resolve(p), persist=True)
    d = tmp_path / "d"
    assert (d / "cache.json").exists()
    # no temp files left behind, and the file is complete valid JSON
    assert [f.name for f in d.iterdir()] == ["cache.json"]
    blob = json.loads((d / "cache.json").read_text())
    assert blob["version"] == 1 and len(blob["entries"]) == 1
    # a second process-equivalent cache loads it back
    cache2 = tune.TuneCache(path=str(d / "cache.json"))
    assert cache2.load() == 1


# ---------------------------------------------------------------------------
# structured logger
# ---------------------------------------------------------------------------

def test_logger_level_filtering(capsys):
    log = StructuredLogger("t", level="warning", json_lines=False)
    log.info("hidden")
    log.warning("shown", code=7)
    out = capsys.readouterr().out
    assert "hidden" not in out
    assert out == "[warning] shown code=7\n"


def test_logger_json_mode(capsys):
    log = StructuredLogger("t", level="info", json_lines=True)
    log.info("served", tokens=8, tok_s=41.5)
    rec = json.loads(capsys.readouterr().out)
    assert rec["logger"] == "t" and rec["level"] == "info"
    assert rec["msg"] == "served"
    assert rec["tokens"] == 8 and rec["tok_s"] == 41.5


def test_logger_text_quotes_awkward_values(capsys):
    log = StructuredLogger("t", json_lines=False)
    log.info("m", path="a b", eq="x=y")
    out = capsys.readouterr().out
    assert out == 'm path="a b" eq="x=y"\n'


# ---------------------------------------------------------------------------
# training supervisor metrics
# ---------------------------------------------------------------------------

def test_supervisor_metrics_and_restart_counters(tmp_path):
    from repro.data.pipeline import DataConfig
    from repro.train.fault_tolerance import (SupervisorConfig,
                                             TrainingSupervisor,
                                             inject_failure_once)

    reg = MetricsRegistry()

    def train_step(params, opt, batch, step):
        return params + 1, opt, {"loss": 0.0}

    sup = TrainingSupervisor(
        SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                         max_restarts=2),
        train_step,
        DataConfig(vocab_size=16, seq_len=4, global_batch=2),
        metrics=reg)
    sup.run(np.zeros(4), np.zeros(4), 6,
            failure_injector=inject_failure_once(3))

    # the failure at step 3 restores to the step-2 checkpoint and replays
    # step 2, so 7 step *executions* complete the 6-step run
    assert reg.counter("train_steps_total").value == 7
    assert reg.counter("train_failures_total").value == 1
    assert reg.counter("train_restarts_total").value == 1
    assert reg.histogram("train_step_seconds").count == 7
    assert reg.counter("train_checkpoint_saves_total").value \
        == reg.histogram("train_checkpoint_save_seconds").count == 3
    assert reg.histogram("train_checkpoint_restore_seconds").count == 1
    names = [e["name"] for e in reg.trace.events]
    assert names.count("restart") == 1
    assert names.count("checkpoint_save") == 3
    assert names.count("checkpoint_restore") == 1


def test_straggler_monitor_folds_into_registry():
    from repro.train.fault_tolerance import StragglerMonitor

    reg = MetricsRegistry()
    mon = StragglerMonitor(4, metrics=reg)
    mon.record([1.0, 1.0, 1.0, 5.0])
    rep = mon.report()
    assert rep.flagged_hosts == [3]
    assert reg.gauge("train_host_step_seconds", host="3").value == 5.0
    assert reg.gauge("train_straggler_median_step_seconds").value == 1.0
    assert reg.gauge("train_stragglers_flagged").value == 1
    assert any(e["name"] == "stragglers_flagged"
               for e in reg.trace.events)


# ---------------------------------------------------------------------------
# snapshot schema validation (the CI metrics-smoke gate)
# ---------------------------------------------------------------------------

def _load_validator():
    root = pathlib.Path(__file__).resolve().parents[1]
    spec = importlib.util.spec_from_file_location(
        "validate_metrics", root / "benchmarks" / "validate_metrics.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod, root


def test_snapshot_validates_against_checked_in_schema(small_model):
    vm, root = _load_validator()
    schema = json.loads(
        (root / "benchmarks" / "metrics_schema.json").read_text())

    cfg, model, params = small_model
    reg = MetricsRegistry()
    eng = ServeEngine(model, params, ServeConfig(num_slots=1, max_len=32),
                      metrics=reg)
    eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=2))
    eng.run_until_drained()

    snap = reg.snapshot()
    assert vm.validate(snap, schema) == []
    assert vm.check_counter(snap, "serve_requests_completed_total") == []
    assert vm.check_histogram(snap, "serve_decode_token_seconds") == []
    # a required-but-absent family fails
    assert vm.check_counter(snap, "no_such_counter")
    # schema catches shape violations
    broken = json.loads(json.dumps(snap))
    broken["counters"][0]["value"] = -1
    assert vm.validate(broken, schema)
    del broken["meta"]
    assert vm.validate(broken, schema)


def test_validator_histogram_consistency_check():
    vm, _ = _load_validator()
    snap = {"histograms": [{"name": "h", "labels": {}, "buckets": [1.0],
                            "counts": [1, 0], "sum": 0.5, "count": 2}]}
    errs = vm.check_histogram(snap, "h")
    assert any("sum(counts)" in e for e in errs)
