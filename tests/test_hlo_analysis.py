"""Unit tests for the loop-exact HLO analyzer (the roofline's foundation)."""

import textwrap

import pytest

from repro.launch.hlo_analysis import analyze, parse_module

SYNTH = textwrap.dedent("""
    HloModule test

    %body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %arg = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
      %one = s32[] constant(1)
      %ip = s32[] add(%i, %one)
      ROOT %out = (s32[], f32[8,16]) tuple(%ip, %ar)
    }

    %cond (arg2: (s32[], f32[8,16])) -> pred[] {
      %arg2 = (s32[], f32[8,16]) parameter(0)
      %i2 = s32[] get-tuple-element(%arg2), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%i2, %n), direction=LT
    }

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x0: f32[8,16]) -> f32[8,16] {
      %x0 = f32[8,16]{1,0} parameter(0)
      %c0 = s32[] constant(0)
      %t = (s32[], f32[8,16]) tuple(%c0, %x0)
      %loop = (s32[], f32[8,16]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %res = f32[8,16]{1,0} get-tuple-element(%loop), index=1
    }
""")


def test_parse_module_structure():
    comps, symtab, entry = parse_module(SYNTH)
    assert entry == "main"
    assert set(comps) >= {"main", "body", "cond", "add"}
    assert symtab["dot.1"].startswith("f32[8,16]")


def test_trip_count_weighting():
    a = analyze(SYNTH)
    # dot flops = 2*8*16*16 = 4096, executed 5 times
    assert a.flops == pytest.approx(5 * 4096)
    assert a.unknown_trip_loops == 0


def test_all_reduce_ring_weighting():
    a = analyze(SYNTH)
    # AR payload 8*16*4 bytes, 2x ring weighting, 5 iterations
    assert a.collectives["all-reduce"]["bytes"] == pytest.approx(
        5 * 2 * 8 * 16 * 4)
    assert a.collectives["all-reduce"]["count"] == 5


def test_unknown_trip_count_flagged():
    hlo = SYNTH.replace(', backend_config={"known_trip_count":{"n":"5"}}', "")
    a = analyze(hlo)
    assert a.unknown_trip_loops == 1
    assert a.flops == pytest.approx(4096)  # counted once


def test_real_compiled_module_roundtrip():
    """Analyzer on a real jit-compiled scan matches the analytic count."""
    import jax
    import jax.numpy as jnp

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((32, 64), jnp.float32)).compile()
    a = analyze(comp.as_text())
    expected = 7 * 2 * 32 * 64 * 64
    assert a.flops == pytest.approx(expected, rel=0.05)
    assert a.unknown_trip_loops == 0
