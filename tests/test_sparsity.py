"""Unit + property tests for the relaxed N:M sparsity format."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env: deterministic example replay
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.sparsity import (
    PATTERNS,
    SparsityConfig,
    group_nonzero_counts,
    pack,
    prune,
    prune_mask,
    random_sparse_dense,
    reconfigure_k,
    satisfies_pattern,
    unpack_packed,
)

jax.config.update("jax_enable_x64", False)


def test_config_validation():
    with pytest.raises(ValueError):
        SparsityConfig(n=0, m=4)
    with pytest.raises(ValueError):
        SparsityConfig(n=4, m=4, k=2)  # kN > M
    cfg = SparsityConfig(8, 128, 1)
    assert cfg.density == pytest.approx(8 / 128)
    assert cfg.pattern_name() == "8:128"
    assert SparsityConfig(8, 128, 8).pattern_name() == "64:128 (as 8x8:128)"


def test_compression_ratio_8_128():
    cfg = PATTERNS["8:128"]
    # bf16 values + int8 indices: 128*2 / (8*3) ≈ 10.7x
    assert cfg.compression_ratio(2, 1) == pytest.approx(256 / 24)
    # with int32 indices it is 128*2/(8*6)
    assert cfg.compression_ratio(2, 4) == pytest.approx(256 / 48)


def test_prune_satisfies_pattern():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((16, 256)).astype(np.float32))
    for name in ("1:2", "1:4", "1:8", "8:128", "4:64"):
        cfg = PATTERNS[name]
        pruned = prune(a, cfg)
        assert satisfies_pattern(pruned, cfg), name
        counts = group_nonzero_counts(pruned, cfg)
        # dense random input -> pruning keeps exactly n_effective per group
        assert int(counts.min()) == cfg.n_effective


def test_prune_keeps_largest_magnitudes():
    cfg = SparsityConfig(2, 4)
    a = jnp.asarray([[1.0, -5.0, 0.25, 3.0, 0.1, 0.2, -0.3, 0.05]])
    pruned = np.asarray(prune(a, cfg))
    np.testing.assert_allclose(pruned, [[0.0, -5.0, 0.0, 3.0, 0.0, 0.2, -0.3, 0.0]])


def test_prune_is_identity_on_underfull_groups():
    """Relaxed "at most N" groups with fewer than n_effective non-zeros must
    survive pruning untouched (regression: the tie-resolution used to count
    leading zeros against the 0-threshold and drop the real non-zeros)."""
    cfg = SparsityConfig(2, 16)
    a = np.zeros((2, 32), np.float32)
    a[0, 8] = -0.7          # 1 non-zero, late in the group
    a[1, 20] = 0.3          # 1 non-zero in the second group
    a[1, 30] = -0.2
    pruned = np.asarray(prune(jnp.asarray(a), cfg))
    np.testing.assert_array_equal(pruned, a)


def test_pack_unpack_roundtrip_exact():
    rng = np.random.default_rng(2)
    cfg = SparsityConfig(4, 32)
    a = random_sparse_dense(rng, 24, 128, cfg)
    p = pack(jnp.asarray(a), cfg)
    np.testing.assert_allclose(np.asarray(unpack_packed(p)), a, rtol=1e-6)


def test_pack_prunes_nonconforming():
    cfg = SparsityConfig(1, 4)
    a = jnp.asarray([[1.0, -2.0, 0.0, 0.0]])  # 2 nonzeros in a 1:4 group
    p = pack(a, cfg)
    got = np.asarray(unpack_packed(p))
    np.testing.assert_allclose(got, [[0.0, -2.0, 0.0, 0.0]])


def test_reconfigure_k_views():
    rng = np.random.default_rng(3)
    cfg = SparsityConfig(8, 64)  # 8:64 packed
    a = random_sparse_dense(rng, 8, 128, cfg)
    p = pack(jnp.asarray(a), cfg)
    split = reconfigure_k(p, k=4)  # view as 4 passes of 2:64
    assert split.values.shape == (8, 2 * 4, 2)
    assert split.cfg.n == 2 and split.cfg.k == 4
    # the multiset of (value) entries is preserved
    np.testing.assert_allclose(
        np.sort(np.asarray(split.values).ravel()),
        np.sort(np.asarray(p.values).ravel()),
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 2, 4, 8]),
    m=st.sampled_from([8, 16, 32, 128]),
    rows=st.sampled_from([1, 4, 16]),
    groups=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_prune_pack_unpack(n, m, rows, groups, seed):
    """For any dense matrix: prune->pack->unpack is idempotent and satisfies
    the pattern; pack drops nothing that prune kept."""
    if n > m:
        return
    cfg = SparsityConfig(n, m)
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((rows, groups * m)).astype(np.float32))
    pruned = prune(a, cfg)
    assert satisfies_pattern(pruned, cfg)
    roundtrip = unpack_packed(pack(pruned, cfg))
    np.testing.assert_allclose(np.asarray(roundtrip), np.asarray(pruned), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_mask_is_topk(seed):
    cfg = SparsityConfig(4, 16)
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((8, 64)).astype(np.float32)
    mask = np.asarray(prune_mask(jnp.asarray(a), cfg))
    grp = np.abs(a.reshape(8, 4, 16))
    kept = np.where(mask.reshape(8, 4, 16), grp, -1.0)
    dropped = np.where(mask.reshape(8, 4, 16), np.inf, grp)
    # min kept magnitude >= max dropped magnitude, per group
    assert np.all(
        np.min(np.where(kept < 0, np.inf, kept), axis=-1)
        >= np.max(np.where(np.isinf(dropped), -np.inf, dropped), axis=-1)
    )


# ---------------------------------------------------------------------------
# k-reconfigured tiers on block / q8 / stacked-scan layouts (the draft-tier
# correctness foundation, DESIGN.md §15 — only xwT was covered before)
# ---------------------------------------------------------------------------

def _topk_per_group(dense: np.ndarray, m: int, t: int) -> np.ndarray:
    """Keep the magnitude-top-``t`` entries of every 1×m group."""
    *lead, k = dense.shape
    g = dense.reshape(*lead, k // m, m)
    order = np.argsort(-np.abs(g), axis=-1, kind="stable")
    mask = np.zeros_like(g, dtype=bool)
    np.put_along_axis(mask, order[..., :t], True, axis=-1)
    return np.where(mask, g, 0.0).reshape(dense.shape)


def _check_tier_and_reconfig(pw, dense_pruned, t=4):
    from repro.core.sparse_linear import _reconfigure
    from repro.core.sparsity import narrow_tier, tier_sort_packed
    from repro.kernels import ops

    cfg = pw.cfg
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (3, pw.in_features)).astype(np.float32))
    y_full = ops.demm_matmul_packed(x, pw, backend="reference")

    # k-retag round-trip: kN:M <-> (N, M, k) views share buffers and output
    split = _reconfigure(pw, SparsityConfig(cfg.n_effective // 2, cfg.m, 2))
    assert split.values is pw.values and split.indices is pw.indices
    back = _reconfigure(split, cfg)
    assert back.cfg == cfg and back.values is pw.values
    for view in (split, back):
        np.testing.assert_allclose(
            np.asarray(ops.demm_matmul_packed(x, view, backend="reference")),
            np.asarray(y_full), rtol=1e-5, atol=1e-5)

    # tier view: sort once, then the tier_ne prefix IS the magnitude-top-t
    # sub-pattern — and sorting itself never changes full-tier results
    srt = tier_sort_packed(pw)
    np.testing.assert_allclose(np.asarray(srt.to_dense()),
                               np.asarray(pw.to_dense()), rtol=1e-6)
    draft = srt.replace(tier_ne=t)
    assert draft.values is srt.values  # view, not copy
    got = np.asarray(narrow_tier(draft).to_dense())
    want = _topk_per_group(np.asarray(dense_pruned), cfg.m, t)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_reconfigured_tier_block_layout():
    from repro.core.sparsity import LAYOUT_BLOCK, PackedWeight

    rng = np.random.default_rng(7)
    cfg = SparsityConfig(8, 16, 1)
    w = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
    pw = PackedWeight.from_dense(w, cfg, layout=LAYOUT_BLOCK)
    _check_tier_and_reconfig(pw, prune(w, cfg))


def test_reconfigured_tier_q8_layout():
    from repro.core.sparsity import PackedWeight
    from repro.quant import quantize_packed

    rng = np.random.default_rng(8)
    cfg = SparsityConfig(8, 16, 1)
    w = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
    q8 = quantize_packed(PackedWeight.from_dense(w, cfg))
    assert q8.qdtype is not None
    # the tier comparison target is the *dequantized* pruned weight: the
    # per-row scale is constant along Ne, so raw int magnitude order is
    # dequant magnitude order
    _check_tier_and_reconfig(q8, q8.to_dense())


def test_reconfigured_tier_stacked_scan():
    """Layer-stacked (scan) weights: both packed layouts keep the tier and
    k-retag semantics per layer."""
    from repro.core.sparsity import narrow_tier, tier_sort_packed
    from repro.launch.pack_tree import _pack_sparse_linear

    rng = np.random.default_rng(9)
    cfg = SparsityConfig(8, 16, 1)
    w = jnp.asarray(rng.standard_normal((3, 8, 64)).astype(np.float32))
    for layout in ("xwT", "block"):
        pw = _pack_sparse_linear({"w": w}, cfg, layout=layout)
        assert pw.stack_dims == (3,)
        srt = tier_sort_packed(pw)
        np.testing.assert_allclose(np.asarray(srt.to_dense()),
                                   np.asarray(pw.to_dense()), rtol=1e-6)
        got = np.asarray(narrow_tier(srt.replace(tier_ne=4)).to_dense())
        want = np.stack([_topk_per_group(np.asarray(prune(w[i], cfg)),
                                         cfg.m, 4) for i in range(3)])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
