"""Serving engine tests: continuous batching, slot reuse/reset, packed-DeMM
serving equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.sparse_linear import ExecPolicy
from repro.launch.pack_tree import pack_tree
from repro.models.families import build_model
from repro.serve.serve_loop import Request, ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_arch("stablelm_3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_continuous_batching_completes_all(engine_setup):
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, ServeConfig(num_slots=2, max_len=48))
    rng = np.random.default_rng(0)
    for i in range(5):  # more requests than slots -> queueing
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 6,
                                               dtype=np.int32),
                           max_new_tokens=4))
    eng.run_until_drained()
    assert len(eng.completed) == 5
    assert all(len(r.output) == 4 for r in eng.completed)


def test_greedy_decode_is_deterministic(engine_setup):
    cfg, model, params = engine_setup
    prompt = np.arange(5, dtype=np.int32) + 7
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, ServeConfig(num_slots=1, max_len=32))
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
        eng.run_until_drained()
        outs.append(eng.completed[0].output)
    assert outs[0] == outs[1]


def test_slot_reuse_no_contamination(engine_setup):
    """A request decoded after slot reuse must match the same request
    decoded on a fresh engine."""
    cfg, model, params = engine_setup
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 5, dtype=np.int32)

    eng = ServeEngine(model, params, ServeConfig(num_slots=1, max_len=48))
    eng.submit(Request(uid=0, prompt=p1, max_new_tokens=5))
    eng.submit(Request(uid=1, prompt=p2, max_new_tokens=5))
    eng.run_until_drained()
    reused_out = [r for r in eng.completed if r.uid == 1][0].output

    fresh = ServeEngine(model, params, ServeConfig(num_slots=1, max_len=48))
    fresh.submit(Request(uid=1, prompt=p2, max_new_tokens=5))
    fresh.run_until_drained()
    fresh_out = fresh.completed[0].output
    assert reused_out == fresh_out


def test_slot_reuse_ssm_state_reset():
    """Same invariant for a stateful (SSM) arch — exercises _reset_slot."""
    cfg = get_arch("xlstm_125m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)

    eng = ServeEngine(model, params, ServeConfig(num_slots=1, max_len=32))
    eng.submit(Request(uid=0, prompt=p1, max_new_tokens=4))
    eng.submit(Request(uid=1, prompt=p2, max_new_tokens=4))
    eng.run_until_drained()
    reused = [r for r in eng.completed if r.uid == 1][0].output

    fresh = ServeEngine(model, params, ServeConfig(num_slots=1, max_len=32))
    fresh.submit(Request(uid=1, prompt=p2, max_new_tokens=4))
    fresh.run_until_drained()
    assert reused == fresh.completed[0].output


def test_packed_serving_matches_masked(engine_setup):
    """The paper's packed DeMM serving path produces the same generations as
    the masked-dense path (weights already satisfy the pattern)."""
    cfg, model, params = engine_setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)

    outs = {}
    for mode, p in (("masked", params), ("packed", pack_tree(params))):
        eng = ServeEngine(model, p, ServeConfig(num_slots=1, max_len=32),
                          policy=ExecPolicy(mode=mode))
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
        eng.run_until_drained()
        outs[mode] = eng.completed[0].output
    assert outs["masked"] == outs["packed"]


def test_eos_terminates(engine_setup):
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, ServeConfig(num_slots=1, max_len=64))
    # run once to learn what the first generated token will be
    probe = ServeEngine(model, params, ServeConfig(num_slots=1, max_len=64))
    probe.submit(Request(uid=0, prompt=np.asarray([3, 1, 4], np.int32),
                         max_new_tokens=3))
    probe.run_until_drained()
    first = probe.completed[0].output[0]
    eng.submit(Request(uid=0, prompt=np.asarray([3, 1, 4], np.int32),
                       max_new_tokens=10, eos_id=first))
    eng.run_until_drained()
    assert eng.completed[0].output == [first]


def test_legacy_mode_backend_kwargs_removed(engine_setup):
    """The mode=/backend= kwargs completed the PR 4 removal policy (one
    release of DeprecationWarning in PR 8): now a clear ValueError."""
    cfg, model, params = engine_setup
    with pytest.raises(ValueError, match="policy=ExecPolicy"):
        ServeEngine(model, params, ServeConfig(num_slots=1, max_len=32),
                    mode="masked", backend="reference")
    with pytest.raises(ValueError, match="policy=ExecPolicy"):
        ServeEngine(model, params, ServeConfig(num_slots=1, max_len=32),
                    backend="reference")
