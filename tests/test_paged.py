"""repro.paged: page allocator/arena bookkeeping, scheduler policies,
chunked-prefill dispatch accounting, and paged-vs-dense serving equivalence
(including through preemption)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.families import build_model
from repro.obs.metrics import MetricsRegistry
from repro.paged import (
    ChunkedPrefill,
    NULL_PAGE,
    PageAllocator,
    PagedKVCache,
    PagedLayout,
    PagedServeConfig,
    PagedServeEngine,
    SchedConfig,
    Scheduler,
)
from repro.serve.serve_loop import Request, ServeConfig, ServeEngine


# ---------------------------------------------------------------------------
# kv_cache: allocator + arena bookkeeping (no jax involved)
# ---------------------------------------------------------------------------

def test_layout_pages_for():
    layout = PagedLayout(page_size=8, num_pages=17, max_blocks=6)
    assert layout.usable_pages == 16
    assert layout.pages_for(0) == 0
    assert layout.pages_for(1) == 1
    assert layout.pages_for(8) == 1
    assert layout.pages_for(9) == 2


def test_layout_for_serve_fully_provisions_by_default():
    layout = PagedLayout.for_serve(96, page_size=8, num_slots=4)
    # every slot can hold max_len tokens simultaneously (+ the null page)
    assert layout.max_blocks == 12
    assert layout.num_pages == 4 * 12 + 1
    assert layout.tokens_per_seq >= 96


def test_allocator_all_or_none_and_free():
    a = PageAllocator(num_pages=5)          # pages 1..4 usable, 0 reserved
    got = a.alloc(3)
    assert got is not None and len(got) == 3
    assert NULL_PAGE not in got
    assert a.alloc(2) is None               # only 1 left: all-or-none
    assert a.alloc_failures == 1
    a.free(got)
    with pytest.raises(ValueError):
        a.free(got[:1])                     # double free
    assert a.alloc(4) is not None           # everything reusable


def test_arena_capacity_release_and_fragmentation():
    layout = PagedLayout(page_size=4, num_pages=7, max_blocks=4)  # 6 usable
    kv = PagedKVCache(layout, num_slots=2)
    assert kv.ensure_capacity(0, 5)         # 2 pages
    kv.note_tokens(0, 5)
    assert kv.pages_used == 2
    # last page holds 1 of 4 token slots -> 3 slack slots of 8 allocated
    assert kv.fragmentation() == pytest.approx(3 / 8)
    assert kv.ensure_capacity(1, 16)        # the remaining 4 pages
    kv.note_tokens(1, 16)
    assert not kv.ensure_capacity(0, 9)     # would need a 3rd page: none left
    assert kv.release(1) == 4
    assert kv.ensure_capacity(0, 9)
    assert kv.table[0, 0] != NULL_PAGE      # rows point at real pages
    kv.release(0)
    assert kv.pages_used == 0
    assert np.all(kv.table == NULL_PAGE)


# ---------------------------------------------------------------------------
# scheduler: ordering, requeue stability, victim selection
# ---------------------------------------------------------------------------

def _req(uid, priority=1):
    return Request(uid=uid, prompt=np.zeros(4, np.int32), priority=priority,
                   output=[])


def test_scheduler_fcfs_ignores_priority():
    s = Scheduler(SchedConfig(policy="fcfs"))
    for uid, prio in ((0, 2), (1, 0), (2, 1)):
        s.submit(_req(uid, prio))
    assert [s.pop().uid for _ in range(3)] == [0, 1, 2]


def test_scheduler_priority_orders_then_arrival():
    s = Scheduler(SchedConfig(policy="priority"))
    for uid, prio in ((0, 2), (1, 0), (2, 1), (3, 0)):
        s.submit(_req(uid, prio))
    assert [s.pop().uid for _ in range(4)] == [1, 3, 2, 0]


def test_scheduler_requeue_keeps_arrival_seq():
    """A preempted request re-enters ahead of later arrivals — the stable
    arrival sequence is what makes preempt/resume deterministic."""
    s = Scheduler(SchedConfig(policy="fcfs"))
    s.submit(_req(0))
    s.submit(_req(1))
    first = s.pop()
    s.submit(_req(2))
    s.requeue(first)
    assert [s.pop().uid for _ in range(3)] == [0, 1, 2]


def test_scheduler_rejects_duplicate_uid():
    s = Scheduler(SchedConfig())
    s.submit(_req(7))
    with pytest.raises(ValueError):
        s.submit(_req(7))


def test_victim_prefers_worst_priority_then_youngest():
    s = Scheduler(SchedConfig(policy="priority"))
    reqs = [_req(0, 0), _req(1, 2), _req(2, 2)]
    for r in reqs:
        s.submit(r)
    cands = [(i, s.pop()) for i in range(3)]
    assert s.victim(cands) == 2             # worst prio, youngest arrival
    # admission-preempt only evicts a STRICTLY lower-priority victim
    assert s.victim(cands, incoming=_req(9, 1)) == 2
    assert s.victim(cands[:1], incoming=_req(9, 0)) is None


def test_victim_admission_disabled_under_fcfs():
    s = Scheduler(SchedConfig(policy="fcfs"))
    r = _req(0, 2)
    s.submit(r)
    cands = [(0, s.pop())]
    assert s.victim(cands, incoming=_req(9, 0)) is None
    assert s.victim(cands) == 0             # growth-preempt still works


# ---------------------------------------------------------------------------
# engine: equivalence, dispatch accounting, preemption, validation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paged_setup():
    # float32 compute: the equivalence tests compare greedy argmax across
    # two differently-compiled programs; bf16 random-init logits tie often.
    cfg = dataclasses.replace(get_arch("stablelm_3b").reduced(),
                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
            for n in lengths]


def _serve(engine, prompts, max_new=6):
    for i, p in enumerate(prompts):
        engine.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    engine.run_until_drained(max_ticks=2000)
    return {r.uid: list(r.output) for r in engine.completed}


def test_paged_matches_dense_tokens(paged_setup):
    """Mixed prompt lengths, fully provisioned arena: every request decodes
    the exact token sequence the legacy dense-cache engine produces."""
    cfg, model, params = paged_setup
    prompts = _prompts(cfg, (5, 23, 11, 37, 17))
    want = _serve(ServeEngine(model, params,
                              ServeConfig(num_slots=4, max_len=96),
                              metrics=MetricsRegistry()), prompts)
    got = _serve(PagedServeEngine(
        model, params,
        PagedServeConfig(num_slots=4, max_len=96, page_size=8,
                         prefill_chunk=16),
        metrics=MetricsRegistry()), prompts)
    assert got == want


def test_paged_preemption_keeps_tokens_identical(paged_setup):
    """An undersized arena forces page-eviction preemption; resumed requests
    must still emit exactly the uninterrupted token sequence."""
    cfg, model, params = paged_setup
    prompts = _prompts(cfg, (5, 23, 11, 37))
    want = _serve(ServeEngine(model, params,
                              ServeConfig(num_slots=4, max_len=96),
                              metrics=MetricsRegistry()), prompts)
    reg = MetricsRegistry()
    eng = PagedServeEngine(
        model, params,
        PagedServeConfig(num_slots=4, max_len=96, page_size=8, num_pages=13,
                         prefill_chunk=16),
        metrics=reg)
    got = _serve(eng, prompts)
    assert reg.counter("serve_preempt_total").value >= 1
    assert got == want


def test_prefill_dispatch_is_chunked(paged_setup):
    """Chunked prefill issues exactly ceil(prompt_len / K) compiled-program
    invocations per request — O(T/K), not the legacy O(T)."""
    cfg, model, params = paged_setup
    chunk = 16
    prompts = _prompts(cfg, (5, 23, 11, 37))
    reg = MetricsRegistry()
    eng = PagedServeEngine(
        model, params,
        PagedServeConfig(num_slots=4, max_len=96, page_size=8,
                         prefill_chunk=chunk),
        metrics=reg)
    _serve(eng, prompts)
    want = sum(-(-len(p) // chunk) for p in prompts)
    assert eng.prefill.dispatches == want
    snap = reg.snapshot()
    by_prog = {c["labels"]["program"]: c["value"]
               for c in snap["counters"]
               if c["name"] == "serve_step_dispatch_total"}
    assert by_prog["prefill"] == want
    assert by_prog["decode"] >= 1


def test_prefill_program_compiles_once(paged_setup):
    """Every chunk of every prompt length reuses ONE compiled program:
    slot / n_valid / block-table contents are traced values, shapes fixed."""
    cfg, model, params = paged_setup
    eng = PagedServeEngine(
        model, params,
        PagedServeConfig(num_slots=4, max_len=96, page_size=8,
                         prefill_chunk=16),
        metrics=MetricsRegistry())
    _serve(eng, _prompts(cfg, (3, 17, 30, 9)))
    if hasattr(eng.prefill._fn, "_cache_size"):
        assert eng.prefill._fn._cache_size() == 1
        assert eng._decode._cache_size() == 1


def test_kernel_dispatch_constant_across_prompt_lengths(paged_setup):
    """``kernel_dispatch_total`` increments at jit-TRACE time — with the two
    fixed-shape compiled programs (chunk prefill + masked decode), the
    packed-kernel dispatch count is independent of how many prompt tokens
    flow through them: the O(prompt_len / K) property at the kernel level
    (only *invocations* scale, counted by serve_step_dispatch_total)."""
    from repro import obs
    from repro.core.sparse_linear import ExecPolicy
    from repro.launch.pack_tree import pack_tree

    cfg, model, params = paged_setup
    packed = pack_tree(params)

    def dispatch_total():
        return sum(c["value"] for c in obs.metrics().snapshot()["counters"]
                   if c["name"] == "kernel_dispatch_total")

    deltas = []
    for lengths in ((4, 9), (31, 17)):      # very different prompt shapes
        before = dispatch_total()
        eng = PagedServeEngine(
            model, packed,
            PagedServeConfig(num_slots=2, max_len=96, page_size=8,
                             prefill_chunk=16),
            policy=ExecPolicy(mode="packed"), metrics=MetricsRegistry())
        _serve(eng, _prompts(cfg, lengths))
        deltas.append(dispatch_total() - before)
    assert deltas[0] == deltas[1] > 0


def test_scheduling_policy_does_not_change_tokens(paged_setup):
    """Greedy decoding is per-request deterministic, so admission order
    (fcfs vs priority, with preemptions) never changes any output."""
    cfg, model, params = paged_setup
    prompts = _prompts(cfg, (5, 23, 11, 37))
    outs = []
    for pol in ("fcfs", "priority"):
        eng = PagedServeEngine(
            model, params,
            PagedServeConfig(num_slots=2, max_len=96, page_size=8,
                             num_pages=13, prefill_chunk=16,
                             sched=SchedConfig(policy=pol)),
            metrics=MetricsRegistry())
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=6,
                               priority=i % 3))
        eng.run_until_drained(max_ticks=2000)
        outs.append({r.uid: list(r.output) for r in eng.completed})
    assert outs[0] == outs[1]


def test_submit_validation(paged_setup):
    cfg, model, params = paged_setup
    eng = PagedServeEngine(
        model, params,
        PagedServeConfig(num_slots=1, max_len=32, page_size=8, num_pages=3),
        metrics=MetricsRegistry())
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.zeros(0, np.int32)))
    with pytest.raises(ValueError):
        eng.submit(Request(uid=1, prompt=np.zeros(40, np.int32)))
    with pytest.raises(RuntimeError):
        # needs 3 pages at peak; the arena only has 2 usable
        eng.submit(Request(uid=2, prompt=np.zeros(17, np.int32),
                           max_new_tokens=4))


def test_arena_exhaustion_without_preemption_raises(paged_setup):
    cfg, model, params = paged_setup
    eng = PagedServeEngine(
        model, params,
        PagedServeConfig(num_slots=2, max_len=64, page_size=8, num_pages=9,
                         prefill_chunk=16,
                         sched=SchedConfig(preempt=False)),
        metrics=MetricsRegistry())
    for i, p in enumerate(_prompts(cfg, (20, 20))):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=16))
    with pytest.raises(RuntimeError):
        eng.run_until_drained(max_ticks=2000)


def test_paged_init_rejects_non_full_attention():
    cfg = get_arch("h2o_danube_1_8b").reduced()     # swa: ring is O(window)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    layout = PagedLayout.for_serve(32, page_size=8, num_slots=1)
    with pytest.raises(NotImplementedError):
        model.init_decode_state(1, 32, dtype=jnp.float32, paged=layout)
    del params


def test_chunked_prefill_requires_capable_model():
    class NoPrefill:
        pass

    with pytest.raises(NotImplementedError):
        ChunkedPrefill(NoPrefill())


def test_encdec_paged_prefill_matches_decode_steps():
    """EncDecLM: chunked paged prefill of a sequence produces the same
    last-position logits as feeding it token-by-token through the paged
    decode step (cross-attention reads the same dense enc_out)."""
    cfg = dataclasses.replace(get_arch("seamless_m4t_medium").reduced(),
                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    layout = PagedLayout.for_serve(48, page_size=8, num_slots=1)
    tokens = np.arange(1, 12, dtype=np.int32) % cfg.vocab_size

    kv = PagedKVCache(layout, 1)
    assert kv.ensure_capacity(0, len(tokens) + 1)
    table = jnp.asarray(np.array(kv.table))

    st = model.init_decode_state(1, 48, dtype=jnp.float32, paged=layout)
    st["caches"] = {**st["caches"], "block_table": table}
    pf = ChunkedPrefill(model, chunk=4)
    logits_pf, _ = pf.ingest(params, st, tokens, 0)
    assert pf.dispatches == 3

    st = model.init_decode_state(1, 48, dtype=jnp.float32, paged=layout)
    st["caches"] = {**st["caches"], "block_table": table,
                    "active": jnp.ones((1,), bool)}
    step = jax.jit(lambda p, s, t: model.decode_step(p, s, t))
    logits_st = None
    for t in tokens:
        logits_st, st = step(params, st, jnp.asarray([[t]], jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_pf[0, 0], np.float32),
                               np.asarray(logits_st[0, 0], np.float32),
                               rtol=2e-4, atol=2e-4)
