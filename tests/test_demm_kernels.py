"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles.

Kernels run in interpret mode (CPU container; TPU is the lowering target).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env: deterministic example replay
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.sparsity import SparsityConfig, pack, random_sparse_dense
from repro.kernels import ref as kref
from repro.kernels.demm_block_spmm import (
    demm_block_spmm_pallas,
    pack_block_sparse,
)
from repro.kernels.demm_spmm import demm_spmm_pallas, demm_xwT_pallas
from repro.kernels.ops import demm_matmul_xwT, demm_spmm


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-4, atol=1e-5)


SWEEP = [
    # (n, m, rows, groups, cd, block_r, block_c, dtype)
    (1, 8, 16, 2, 32, 8, 16, jnp.float32),
    (2, 16, 32, 4, 64, 16, 32, jnp.float32),
    (4, 32, 64, 4, 128, 32, 64, jnp.float32),
    (8, 128, 128, 2, 128, 64, 128, jnp.float32),
    (4, 64, 64, 2, 64, 64, 64, jnp.bfloat16),
    (8, 128, 256, 1, 256, 128, 256, jnp.bfloat16),
    (1, 2, 16, 8, 32, 16, 32, jnp.float32),   # fine-grained 1:2
    (1, 4, 16, 4, 32, 16, 32, jnp.float32),   # fine-grained 1:4
]


@pytest.mark.parametrize("n,m,rows,groups,cd,br,bc,dtype", SWEEP)
def test_spmm_kernel_vs_oracle(n, m, rows, groups, cd, br, bc, dtype):
    rng = np.random.default_rng(n * 1000 + m)
    cfg = SparsityConfig(n, m)
    a = random_sparse_dense(rng, rows, groups * m, cfg).astype(np.float32)
    b = rng.standard_normal((groups * m, cd)).astype(np.float32)
    p = pack(jnp.asarray(a, dtype), cfg)
    bj = jnp.asarray(b, dtype)
    got = demm_spmm_pallas(p.values, p.indices, bj, cfg,
                           block_r=br, block_c=bc, interpret=True)
    want = kref.spmm_ref(p.values, p.indices, bj, cfg, (rows, groups * m))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("n,m,rows,groups,cd,br,bc,dtype", SWEEP)
def test_xwT_kernel_vs_oracle(n, m, rows, groups, cd, br, bc, dtype):
    rng = np.random.default_rng(n * 7000 + m)
    cfg = SparsityConfig(n, m)
    w = random_sparse_dense(rng, rows, groups * m, cfg).astype(np.float32)
    x = rng.standard_normal((cd, groups * m)).astype(np.float32)
    p = pack(jnp.asarray(w, dtype), cfg)
    xj = jnp.asarray(x, dtype)
    got = demm_xwT_pallas(xj, p.values, p.indices, cfg,
                          block_b=min(bc, cd), block_o=br, interpret=True)
    want = kref.xwT_ref(xj, p.values, p.indices, cfg, (rows, groups * m))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("block_r", [8, 16, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_spmm_kernel_vs_oracle(block_r, dtype):
    rng = np.random.default_rng(99)
    cfg = SparsityConfig(2, 16)
    a = random_sparse_dense(rng, 64, 128, cfg)
    # zero out some whole groups to exercise block skipping
    a = a.reshape(64, 8, 16)
    a[:, 3, :] = 0
    a[:32, 5, :] = 0
    a = a.reshape(64, 128)
    b = rng.standard_normal((128, 64)).astype(np.float32)
    ag, vals, idxs, a_max = pack_block_sparse(a, cfg, block_r=block_r)
    assert a_max < 8, "block skipping must actually skip groups"
    got = demm_block_spmm_pallas(
        jnp.asarray(ag), jnp.asarray(vals, dtype), jnp.asarray(idxs),
        jnp.asarray(b, dtype), cfg, r=64, cd_block=32, interpret=True)
    want = a.astype(np.float32) @ b
    np.testing.assert_allclose(np.asarray(got), want, **_tol(dtype))


def test_block_spmm_all_zero_rowblock():
    cfg = SparsityConfig(2, 16)
    a = np.zeros((32, 64), np.float32)
    b = np.ones((64, 32), np.float32)
    ag, vals, idxs, _ = pack_block_sparse(a, cfg, block_r=16)
    got = demm_block_spmm_pallas(
        jnp.asarray(ag), jnp.asarray(vals), jnp.asarray(idxs),
        jnp.asarray(b), cfg, r=32, cd_block=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), 0.0)


@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([1, 2, 4]),
    groups=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_kernel_matches_oracle(n, groups, seed):
    """Random patterns, random shapes: kernel == oracle."""
    m = 16
    cfg = SparsityConfig(n, m)
    rng = np.random.default_rng(seed)
    rows, cd = 32, 32
    a = random_sparse_dense(rng, rows, groups * m, cfg)
    b = rng.standard_normal((groups * m, cd)).astype(np.float32)
    p = pack(jnp.asarray(a), cfg)
    got = demm_spmm_pallas(p.values, p.indices, jnp.asarray(b), cfg,
                           block_r=16, block_c=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-5)


def test_ops_backend_dispatch_and_grads():
    rng = np.random.default_rng(5)
    cfg = SparsityConfig(4, 32)
    w = random_sparse_dense(rng, 64, 128, cfg)
    x = rng.standard_normal((16, 128)).astype(np.float32)
    p = pack(jnp.asarray(w), cfg)
    outs = {
        be: np.asarray(demm_matmul_xwT(jnp.asarray(x), p.values, p.indices,
                                       cfg, (64, 128), be))
        for be in ("reference", "pallas_interpret")
    }
    np.testing.assert_allclose(outs["reference"], outs["pallas_interpret"],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs["reference"], x @ w.T, rtol=1e-4, atol=1e-5)

    # gradient only lives on the non-zero coordinates
    def loss(v):
        return jnp.sum(
            demm_matmul_xwT(jnp.asarray(x), v, p.indices, cfg, (64, 128),
                            "reference") ** 2)
    gv = np.asarray(jax.grad(loss)(p.values))
    assert np.all((gv != 0) <= (np.asarray(p.values) != 0))

    with pytest.raises(ValueError):
        demm_matmul_xwT(jnp.asarray(x), p.values, p.indices, cfg, (64, 128),
                        "not_a_backend")


def test_spmm_op_backends_agree():
    rng = np.random.default_rng(6)
    cfg = SparsityConfig(2, 16)
    a = random_sparse_dense(rng, 32, 64, cfg)
    b = rng.standard_normal((64, 32)).astype(np.float32)
    p = pack(jnp.asarray(a), cfg)
    r1 = demm_spmm(p.values, p.indices, jnp.asarray(b), cfg, (32, 64),
                   "reference")
    r2 = demm_spmm(p.values, p.indices, jnp.asarray(b), cfg, (32, 64),
                   "pallas_interpret")
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-4,
                               atol=1e-5)
